"""Top-N HBM-traffic instructions of a compiled (arch x shape) program —
the dry-run's stand-in for a profiler. Reuses the loop-aware multiplicities.

Run: PYTHONPATH=src python -m benchmarks.hlo_top --arch gemma3-27b \
        --shape long_500k [--multi-pod] [-n 20]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse

import jax

from repro.configs.registry import get_config, get_shape, list_archs, list_shapes
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import setup_for


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), required=True)
    ap.add_argument("--shape", choices=list_shapes(), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--ce-chunk", type=int, default=0)
    ap.add_argument("-n", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    step_fn, sargs, insh = setup_for(cfg, shape, mesh,
                                     use_kernels=args.use_kernels,
                                     ce_chunk=args.ce_chunk)
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    with mesh:
        compiled = jax.jit(step_fn, in_shardings=insh,
                           donate_argnums=donate).lower(*sargs).compile()
    text = compiled.as_text()
    comps, entry = H.parse_hlo(text)
    mult = H._multiplicities(comps, entry)

    rows = []
    fusion_bodies = set()
    executed = set([entry])
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                t = ins.attr("calls")
                if t:
                    fusion_bodies.add(t)
            if ins.opcode == "while":
                for key in ("body", "condition"):
                    t = ins.attr(key)
                    if t:
                        executed.add(t)
    for cname, comp in comps.items():
        if cname not in executed or cname in fusion_bodies:
            continue
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for ins in comp.instrs:
            if ins.opcode in ("parameter", "constant", "tuple",
                              "get-tuple-element", "bitcast", "while",
                              "conditional"):
                continue
            rb = comp.sizes.get(ins.name, 0)
            ob = sum(comp.sizes.get(nm, 0) for nm in ins.operand_names())
            tot = m * (rb + ob)
            if tot > 0:
                meta = ""
                i = ins.rest.find('op_name="')
                if i >= 0:
                    meta = ins.rest[i + 9:ins.rest.find('"', i + 9)][-70:]
                rows.append((tot, m, ins.opcode, ins.name[:40], meta))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total modeled HBM traffic: {total/2**30:.1f} GiB/device")
    print(f"{'GiB':>9s} {'%':>5s} {'mult':>6s} {'opcode':<22s} op_name")
    for tot, m, op, name, meta in rows[: args.n]:
        print(f"{tot/2**30:9.2f} {100*tot/total:5.1f} {m:6.0f} {op:<22s} "
              f"{meta}")


if __name__ == "__main__":
    main()
