"""Render the §Roofline / §Dry-run markdown tables from
experiments/dryrun/*.json.

Run: PYTHONPATH=src python -m benchmarks.roofline_table [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(f))
        if r.get("mesh") == mesh:
            recs.append(r)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    return recs


def fmt_bytes(b) -> str:
    return f"{b / 2**30:.2f}"


def roofline_table(recs: List[Dict]) -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "bound | useful/HLO | note |\n"
           "|---|---|---:|---:|---:|---|---:|---|")
    rows = [hdr]
    for r in recs:
        if not r.get("applicable", True):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                        f"| — | {r.get('skip_reason', '')[:60]} |")
            continue
        if "roofline" not in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR "
                        f"| — | {r.get('error', '')[:60]} |")
            continue
        t = r["roofline"]
        note = _note(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.1f} | "
            f"{t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} | "
            f"{r['bottleneck'][:-2]} | {r['useful_flops_ratio']:.2f} | "
            f"{note} |")
    return "\n".join(rows)


def _note(r: Dict) -> str:
    t = r["roofline"]
    b = r["bottleneck"]
    if b == "memory_s":
        return ("cut f32 boundaries / fuse (TPU fuses tighter than the "
                "CPU-granularity estimate)")
    if b == "collective_s":
        ar = r.get("collectives", {}).get("all-reduce", {})
        return (f"all-reduce {ar.get('bytes', 0)/2**30:.1f}GiB: "
                "reduce-scatter/SP or 2D-sharded collectives")
    return "increase per-chip batch or reduce remat recompute"


def dryrun_table(recs: List[Dict]) -> str:
    hdr = ("| arch | shape | compile (s) | args GiB/dev | temp GiB/dev | "
           "HLO TFLOP/dev | HBM GiB/dev | coll GiB/dev | coll ops |\n"
           "|---|---|---:|---:|---:|---:|---:|---:|---|")
    rows = [hdr]
    for r in recs:
        if not r.get("applicable", True) or "cost" not in r:
            continue
        mem = r.get("memory", {})
        coll = r.get("collectives", {})
        nops = {k: v["count"] for k, v in coll.items()}
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r.get('compile_s', 0):.0f} | "
            f"{fmt_bytes(mem.get('argument_bytes', 0))} | "
            f"{fmt_bytes(mem.get('temp_bytes', 0))} | "
            f"{r['cost']['device_flops']/1e12:.1f} | "
            f"{fmt_bytes(r['cost']['device_bytes'])} | "
            f"{fmt_bytes(r.get('collective_bytes', 0))} | {nops} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--kind", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    recs = load(args.mesh)
    if not recs:
        print(f"no records for mesh {args.mesh}")
        return
    print(roofline_table(recs) if args.kind == "roofline"
          else dryrun_table(recs))


if __name__ == "__main__":
    main()
