"""Benchmark harness — one function per paper table/figure + kernel/system
micro-benchmarks. Prints ``name,us_per_call,derived`` CSV rows, and appends
each row (with an ISO timestamp) to ``BENCH_<name>.json`` at the repo root —
one JSON object per line, so the perf trajectory accumulates across runs.

Paper mapping:
- table1_generalization_gap  -> Table 1 (SB/LB/+LR/+GBN/+RA val accuracy),
  reduced-scale synthetic analogue (Table 2 is the same protocol on
  ImageNet/Alexnet — data-gated, covered by the same code path).
- figure1_batch_size_error   -> Figure 1 (error vs batch size).
- figure2_weight_distance    -> Figure 2 (log-t weight distance + fits).
- appendixB_random_potential -> Appendix B (loss std vs distance).
- kernel_*                   -> Pallas kernels vs jnp oracles (CPU interpret).
- lm_train_step              -> reduced-LM step throughput with the recipe.
- roofline_from_dryrun       -> reads experiments/dryrun/*.json (§Roofline).

Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import time
from datetime import datetime, timezone
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

ROWS: List[str] = []
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
    # accumulate the perf trajectory: one timestamped JSON line per run,
    # appended so BENCH_<name>.json keeps the full history
    safe = name.replace("/", "_").replace("[", "_").replace("]", "")
    rec = {"ts": datetime.now(timezone.utc).isoformat(timespec="seconds"),
           "name": name, "us_per_call": round(us_per_call, 1),
           "derived": derived}
    with open(os.path.join(REPO_ROOT, f"BENCH_{safe}.json"), "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")


def _timeit(fn: Callable, *args, reps: int = 5) -> float:
    # fully block the warmup: an async-dispatched compile/first call must
    # never still be executing when the timer starts
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def _timeit_pair(fa: Callable, fb: Callable, *args, reps: int = 3,
                 rounds: int = 3) -> "tuple[float, float]":
    """Interleaved best-of-rounds for A/B rows whose margin is thinner than
    this box's run-to-run noise: alternating the sides each round makes
    thermal/background drift hit both equally, and min-of-rounds drops the
    noise floor instead of averaging it in."""
    ta, tb = [], []
    for _ in range(rounds):
        ta.append(_timeit(fa, *args, reps=reps))
        tb.append(_timeit(fb, *args, reps=reps))
    return min(ta), min(tb)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------


def kernel_gbn(quick: bool) -> None:
    from repro.kernels import ops, ref
    G, R, C = (4, 512, 128) if quick else (8, 2048, 256)
    x = jax.random.normal(jax.random.PRNGKey(0), (G, R, C))
    gamma = jnp.ones((C,))
    beta = jnp.zeros((C,))
    f_ref = jax.jit(lambda a: ref.gbn_ref(a, gamma, beta)[0])
    f_ker = jax.jit(lambda a: ops.gbn_forward(a, gamma, beta)[0])
    t_ref = _timeit(f_ref, x)
    t_ker = _timeit(f_ker, x)
    err = float(jnp.abs(f_ref(x) - f_ker(x)).max())
    emit("kernel_gbn_ref", t_ref, f"shape={G}x{R}x{C}")
    emit("kernel_gbn_pallas_interp", t_ker, f"max_err={err:.1e}")


def kernel_gbn_grad(quick: bool) -> None:
    """Fused GBN forward+backward (the custom_vjp Pallas pair) vs autodiff
    of the jnp oracle — the hot loop of large-batch training."""
    from repro.kernels import ops, ref
    G, R, C = (4, 512, 128) if quick else (8, 2048, 256)
    x = jax.random.normal(jax.random.PRNGKey(0), (G, R, C))
    gamma = jnp.linspace(0.5, 1.5, C)
    beta = jnp.zeros((C,))

    def make_loss(f):
        return lambda a, g, b: (f(a, g, b)[0] ** 2).mean()

    g_ref = jax.jit(jax.grad(make_loss(ref.gbn_ref), argnums=(0, 1, 2)))
    g_ker = jax.jit(jax.grad(make_loss(
        lambda a, g, b: ops.gbn_forward(a, g, b)), argnums=(0, 1, 2)))
    t_ref = _timeit(lambda: g_ref(x, gamma, beta)[0], reps=3)
    t_ker = _timeit(lambda: g_ker(x, gamma, beta)[0], reps=3)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(g_ker(x, gamma, beta), g_ref(x, gamma, beta)))
    emit("kernel_gbn_grad_ref", t_ref, f"shape={G}x{R}x{C}")
    emit("kernel_gbn_grad_pallas_interp", t_ker, f"max_err={err:.1e}")


def kernel_flash_attention(quick: bool) -> None:
    from repro.kernels import ops, ref
    B, H, KV, S, hd = (1, 4, 2, 256, 64) if quick else (2, 8, 4, 1024, 64)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, hd))
    f_ref = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, causal=True))
    f_ker = jax.jit(lambda a, b, c: ops.flash_attention_hm(a, b, c,
                                                           causal=True))
    t_ref = _timeit(f_ref, q, k, v, reps=3)
    t_ker = _timeit(f_ker, q, k, v, reps=3)
    err = float(jnp.abs(f_ref(q, k, v) - f_ker(q, k, v)).max())
    emit("kernel_flash_ref", t_ref, f"S={S}")
    emit("kernel_flash_pallas_interp", t_ker, f"max_err={err:.1e}")


def kernel_attention_grad(quick: bool) -> None:
    """Flash attention forward+backward (the custom_vjp Pallas pair:
    lse-residual forward, dq / dkv recomputation kernels) vs autodiff of
    the jnp oracle — the LM mixer's training hot path."""
    from repro.kernels import ops, ref
    B, H, KV, S, hd = (1, 4, 2, 256, 64) if quick else (2, 8, 4, 1024, 64)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, hd))
    w = jax.random.normal(jax.random.PRNGKey(3), (B, H, S, hd))

    def make_loss(f):
        return lambda a, b, c: (f(a, b, c) * w).sum()

    g_ref = jax.jit(jax.grad(make_loss(
        lambda a, b, c: ref.attention_ref(a, b, c, causal=True)),
        argnums=(0, 1, 2)))
    g_ker = jax.jit(jax.grad(make_loss(
        lambda a, b, c: ops.flash_attention_hm(a, b, c, causal=True)),
        argnums=(0, 1, 2)))
    t_ref = _timeit(lambda: g_ref(q, k, v)[0], reps=3)
    t_ker = _timeit(lambda: g_ker(q, k, v)[0], reps=3)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(g_ker(q, k, v), g_ref(q, k, v)))
    emit("kernel_attention_grad_ref", t_ref, f"S={S}")
    emit("kernel_attention_grad_pallas_interp", t_ker, f"max_err={err:.1e}")


def kernel_mamba(quick: bool) -> None:
    from repro.kernels import ops, ref
    B, c, di, ds = (2, 64, 512, 16) if quick else (4, 256, 1024, 16)
    rng = jax.random.PRNGKey(0)
    xc = jax.random.normal(rng, (B, c, di))
    dt = 0.1 * jax.nn.softplus(jax.random.normal(rng, (B, c, di)))
    Bm = jax.random.normal(rng, (B, c, ds))
    Cm = jax.random.normal(rng, (B, c, ds))
    A = -jnp.abs(jax.random.normal(rng, (di, ds)))
    h0 = jnp.zeros((B, di, ds))
    f_ref = jax.jit(lambda *a: ref.mamba_chunk_ref(*a)[0])
    f_ker = jax.jit(lambda *a: ops.mamba_chunk(*a)[0])
    t_ref = _timeit(f_ref, xc, dt, Bm, Cm, A, h0, reps=3)
    t_ker = _timeit(f_ker, xc, dt, Bm, Cm, A, h0, reps=3)
    emit("kernel_mamba_ref", t_ref, f"c={c},di={di}")
    emit("kernel_mamba_pallas_interp", t_ker, "")


def kernel_mamba_grad(quick: bool) -> None:
    """Mamba chunk scan forward+backward (the custom_vjp Pallas pair:
    VMEM-resident forward, reverse-time backward with in-kernel state
    recompute — no oracle forward replay) vs autodiff of the jnp oracle."""
    from repro.kernels import ops, ref
    B, c, di, ds = (2, 64, 512, 16) if quick else (4, 256, 1024, 16)
    rng = jax.random.PRNGKey(0)
    xc = jax.random.normal(rng, (B, c, di))
    dt = 0.1 * jax.nn.softplus(jax.random.normal(rng, (B, c, di)))
    Bm = jax.random.normal(rng, (B, c, ds))
    Cm = jax.random.normal(rng, (B, c, ds))
    A = -jnp.abs(jax.random.normal(rng, (di, ds)))
    h0 = jnp.zeros((B, di, ds))
    w = jax.random.normal(jax.random.PRNGKey(1), (B, c, di))

    def make_loss(f):
        return lambda *a: (f(*a)[0] * w).sum()

    g_ref = jax.jit(jax.grad(make_loss(ref.mamba_chunk_ref),
                             argnums=(0, 1, 2, 3, 4, 5)))
    g_ker = jax.jit(jax.grad(make_loss(ops.mamba_chunk),
                             argnums=(0, 1, 2, 3, 4, 5)))
    t_ref = _timeit(lambda: g_ref(xc, dt, Bm, Cm, A, h0)[0], reps=3)
    t_ker = _timeit(lambda: g_ker(xc, dt, Bm, Cm, A, h0)[0], reps=3)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(g_ker(xc, dt, Bm, Cm, A, h0),
                              g_ref(xc, dt, Bm, Cm, A, h0)))
    emit("kernel_mamba_grad_ref", t_ref, f"c={c},di={di}")
    emit("kernel_mamba_grad_pallas_interp", t_ker, f"max_err={err:.1e}")


def kernel_rmsnorm_residual(quick: bool) -> None:
    """Fused residual-add + RMSNorm (ops.rmsnorm_residual: one pass that
    returns the normed activations AND the new residual stream) vs the
    unfused composition run as separate jitted passes (add materialises s,
    the norm pass re-reads it) — the per-sublayer seam of every block."""
    from repro.kernels import ops, ref
    N, d = (2048, 512) if quick else (8192, 1024)
    x = jax.random.normal(jax.random.PRNGKey(0), (N, d))
    r = jax.random.normal(jax.random.PRNGKey(1), (N, d))
    sc = jnp.linspace(0.5, 1.5, d)
    f_fused = jax.jit(lambda a, b, s: ops.rmsnorm_residual(a, b, s))
    f_add = jax.jit(lambda a, b: a + b)
    f_norm = jax.jit(lambda s, g: s * jax.lax.rsqrt(
        (s * s).mean(-1, keepdims=True) + 1e-6) * g)

    def unfused(a, b, s):
        t = f_add(a, b)
        return f_norm(t, s), t

    t_un, t_f = _timeit_pair(lambda: unfused(x, r, sc)[0],
                             lambda: f_fused(x, r, sc)[0], reps=3, rounds=6)
    y_ref, _ = ref.rmsnorm_residual_ref(x, r, sc, 1e-6)
    err = float(jnp.abs(f_fused(x, r, sc)[0] - y_ref).max())
    emit("kernel_rmsnorm_residual_unfused", t_un, f"N={N},d={d}")
    emit("kernel_rmsnorm_residual", t_f,
         f"max_err={err:.1e};vs_unfused={t_un / max(t_f, 1e-9):.2f}x")


def kernel_swiglu(quick: bool) -> None:
    """Fused SwiGLU front half (ops.swiglu: both GEMMs + the silu gate in
    one call, one saved hidden residual) vs the naive inline composition
    under one jit (silu(x@wg) * (x@wu) — what a block would write without
    the fused op). Off-TPU the fused lowering makes ONE concatenated GEMM
    pass over x with the gate in the epilogue; XLA CPU schedules the naive
    form as two separate GEMM passes."""
    from repro.kernels import ops, ref
    N, d, F = (1024, 512, 1024) if quick else (2048, 1024, 2048)
    x = jax.random.normal(jax.random.PRNGKey(0), (N, d))
    wg = jax.random.normal(jax.random.PRNGKey(1), (d, F)) / d ** 0.5
    wu = jax.random.normal(jax.random.PRNGKey(2), (d, F)) / d ** 0.5
    f_fused = jax.jit(ops.swiglu)
    unfused = jax.jit(lambda a, g, u: jax.nn.silu(a @ g) * (a @ u))

    t_un, t_f = _timeit_pair(unfused, f_fused, x, wg, wu, reps=3, rounds=5)
    h_ref, _ = ref.swiglu_ref(x, wg, wu)
    err = float(jnp.abs(f_fused(x, wg, wu) - h_ref).max())
    emit("kernel_swiglu_unfused", t_un, f"N={N},d={d},F={F}")
    emit("kernel_swiglu", t_f,
         f"max_err={err:.1e};vs_unfused={t_un / max(t_f, 1e-9):.2f}x")


def kernel_rope_fused(quick: bool) -> None:
    """RoPE fused into the decode q load (ops.flash_decode(rope_theta=...))
    vs the rotation as its own jitted pass feeding the same decode kernel —
    the separate apply_rope pass the fused path drops."""
    from repro.kernels import ops, ref
    # latency-bound shapes: the fused path's CPU win is the dropped
    # dispatch + extra q pass, a fixed per-step cost that is visible in the
    # small-batch/short-context serving regime and amortised away at depth
    # (the in-kernel-load fusion is the TPU story); full mode uses bigger
    # model dims (more heads, hd=128), not a deeper cache
    B, H, KV, hd, S = (8, 4, 2, 64, 512) if quick else (4, 16, 8, 128, 128)
    theta = 1e4
    q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, hd))
    pos = jnp.full((B,), S // 2, jnp.int32)
    f_fused = jax.jit(lambda a, b, c, p: ops.flash_decode(
        a, b, c, p, rope_theta=theta))
    f_rot = jax.jit(lambda a, p: ref.rope_ref(
        a.swapaxes(1, 2), p[:, None], theta).swapaxes(1, 2))
    f_plain = jax.jit(lambda a, b, c, p: ops.flash_decode(a, b, c, p))

    def unfused(a, b, c, p):
        return f_plain(f_rot(a, p), b, c, p)

    t_un, t_f = _timeit_pair(unfused, f_fused, q, k, v, pos, reps=20,
                             rounds=5)
    err = float(jnp.abs(f_fused(q, k, v, pos)
                        - unfused(q, k, v, pos)).max())
    emit("kernel_rope_fused_unfused", t_un, f"B={B},S={S}")
    emit("kernel_rope_fused", t_f,
         f"max_err={err:.1e};vs_unfused={t_un / max(t_f, 1e-9):.2f}x")


# ---------------------------------------------------------------------------
# paper tables / figures
# ---------------------------------------------------------------------------


def _vision_setup(quick: bool):
    from repro.configs.paper_models import F1_MNIST
    from repro.data.synthetic import teacher_classification
    cfg = dataclasses.replace(
        F1_MNIST, input_shape=(8, 8, 1),
        hidden_sizes=(96, 96) if quick else (192, 192, 192),
        ghost_batch_size=16)
    data = teacher_classification(
        7, n_train=2048 if quick else 6144, n_test=1024,
        input_shape=(8, 8, 1), n_classes=10, label_noise=0.05)
    return cfg, data


def table1_generalization_gap(quick: bool) -> None:
    """SB / LB / LB+LR / LB+LR+GBN / LB+LR+GBN+RA validation accuracy."""
    from repro.core import Regime, presets
    from repro.models.cnn import model_fns
    from repro.train.trainer import train_vision
    cfg, data = _vision_setup(quick)
    # batch ratio 32 (paper: 128 -> 4096); figure1 locates the gap onset
    # for this task at batch ~1024
    small_steps = 300 if quick else 2400
    small = Regime(base_lr=0.08, total_steps=small_steps,
                   drop_every=small_steps // 3, drop_factor=0.2)
    cols = presets(large_batch=1024, small_batch=32, ghost=16)
    t0 = time.perf_counter()
    accs = {}
    for name, lb in cols.items():
        regime = lb.build_regime(small)
        out = train_vision(model_fns(cfg), cfg, data, lb, regime, seed=5,
                           track_diffusion=False)
        accs[name] = out["final_acc"]
    dt = (time.perf_counter() - t0) * 1e6
    derived = ";".join(f"{k}={v:.4f}" for k, v in accs.items())
    emit("table1_generalization_gap", dt / len(cols), derived)


def figure1_batch_size_error(quick: bool) -> None:
    """Validation error vs batch size (constant epoch budget, no fixes)."""
    from repro.core import LargeBatchConfig, Regime
    from repro.models.cnn import model_fns
    from repro.train.trainer import train_vision
    cfg, data = _vision_setup(quick)
    batches = [32, 128, 512] if quick else [32, 64, 128, 256, 512, 1024]
    epochs_steps = 300 if quick else 1200  # at batch 64
    t0 = time.perf_counter()
    errs = {}
    for bs in batches:
        lb = LargeBatchConfig(batch_size=bs, base_batch_size=bs,
                              lr_rule="none", use_gbn=False,
                              regime_adaptation=False, grad_clip=0.0)
        steps = max(10, epochs_steps * 64 // bs)
        regime = Regime(base_lr=0.08, total_steps=steps,
                        drop_every=max(1, steps // 3))
        out = train_vision(model_fns(cfg), cfg, data, lb, regime, seed=5,
                           track_diffusion=False)
        errs[bs] = 1.0 - out["final_acc"]
    dt = (time.perf_counter() - t0) * 1e6
    emit("figure1_batch_size_error", dt / len(batches),
         ";".join(f"b{k}={v:.4f}" for k, v in errs.items()))


def figure2_weight_distance(quick: bool) -> None:
    """||w_t - w_0|| ~ log t during the initial high-LR phase, per batch."""
    from repro.core import LargeBatchConfig, Regime
    from repro.models.cnn import model_fns
    from repro.train.trainer import train_vision
    cfg, data = _vision_setup(quick)
    batches = [64, 256] if quick else [32, 128, 512]
    steps = 200 if quick else 600
    t0 = time.perf_counter()
    fits = {}
    for bs in batches:
        lb = LargeBatchConfig(batch_size=bs, base_batch_size=bs,
                              grad_clip=0.0)
        regime = Regime(base_lr=0.08, total_steps=steps, drop_every=10**9)
        out = train_vision(model_fns(cfg), cfg, data, lb, regime, seed=5)
        fits[bs] = out["log_fit"]
    dt = (time.perf_counter() - t0) * 1e6
    emit("figure2_weight_distance", dt / len(batches),
         ";".join(f"b{k}:slope={v['slope']:.3f},r2={v['r2']:.3f}"
                  for k, v in fits.items()))


def appendixB_random_potential(quick: bool) -> None:
    """std(L(w)-L(w0)) vs ||w-w0|| on random rays from init."""
    from repro.core.diffusion import random_potential_probe
    from repro.models.cnn import model_fns
    cfg, data = _vision_setup(True)
    init_fn, apply_fn = model_fns(cfg)
    params, state = init_fn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(data.x_train[:256])
    y = jnp.asarray(data.y_train[:256])

    @jax.jit
    def loss(p):
        logits, _ = apply_fn(p, state, cfg, x, training=True,
                             use_gbn=False)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    t0 = time.perf_counter()
    out = random_potential_probe(loss, params, jax.random.PRNGKey(1),
                                 n_samples=60 if quick else 200,
                                 max_radius=10.0, n_bins=6)
    dt = (time.perf_counter() - t0) * 1e6
    d, s = out["distance"], out["loss_std"]
    corr = float(np.corrcoef(d, s)[0, 1]) if len(d) > 2 else float("nan")
    emit("appendixB_random_potential", dt,
         f"linear_corr={corr:.3f};bins={len(d)}")


# ---------------------------------------------------------------------------
# system
# ---------------------------------------------------------------------------


def lm_train_step(quick: bool) -> None:
    from repro.configs.registry import get_config
    from repro.core import LargeBatchConfig, Regime
    from repro.models import transformer as T
    from repro.optim import sgd
    from repro.train.trainer import make_lm_train_step
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    B, S = (4, 64) if quick else (8, 128)
    lb = LargeBatchConfig(batch_size=B, base_batch_size=B, grad_clip=1.0)
    regime = Regime(base_lr=0.01, total_steps=100, drop_every=100)
    step = jax.jit(make_lm_train_step(cfg, lb, regime))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}

    us = _timeit(lambda: step(params, opt, batch, jnp.int32(0),
                              jax.random.PRNGKey(0))[2]["loss"], reps=3)
    toks = B * S
    emit("lm_train_step_reduced", us, f"tok_per_s={toks / (us / 1e6):.0f}")


def mesh_lm_train_step(quick: bool) -> None:
    """The unified 2-D train step (train/parallel.py) vs the plain LM step
    on the degenerate host mesh — the shard_map-layer tax (size-1 psums,
    manual EP dispatch, corrected grad-clip norm) the sharded trajectory
    starts from. Run on an MoE config so the manual dispatch is on the
    timed path."""
    from repro.configs.registry import get_config
    from repro.core import LargeBatchConfig, Regime
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.optim import sgd
    from repro.train.trainer import make_lm_train_step
    cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").reduced(),
                              dtype="float32")
    B, S = (4, 64) if quick else (8, 128)
    lb = LargeBatchConfig(batch_size=B, base_batch_size=B, grad_clip=1.0)
    regime = Regime(base_lr=0.01, total_steps=100, drop_every=100)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    plain = jax.jit(make_lm_train_step(cfg, lb, regime))
    mesh = jax.jit(make_lm_train_step(cfg, lb, regime,
                                      mesh=make_host_mesh(), params=params))
    t_plain = _timeit(lambda: plain(params, opt, batch, jnp.int32(0),
                                    jax.random.PRNGKey(0))[2]["loss"],
                      reps=3)
    t_mesh = _timeit(lambda: mesh(params, opt, batch, jnp.int32(0),
                                  jax.random.PRNGKey(0))[2]["loss"], reps=3)
    emit("mesh_lm_train_step_plain", t_plain, f"B={B},S={S}")
    emit("mesh_lm_train_step", t_mesh,
         f"overhead={(t_mesh - t_plain) / t_plain * 100:.1f}%")


def _mesh_variant_lm_step(name: str, quick: bool, **kw) -> None:
    """Shared body for the TP/FSDP train-step benches: the variant step on
    the degenerate host mesh vs the plain LM step. Single-device the
    collectives are size-1, so the row prices the sharding-layer plumbing
    (Megatron fences / param all-gather + grad reduce-scatter + shard-local
    optimizer) that the real multi-device trajectory starts from — the same
    basis as ``mesh_lm_train_step``."""
    from repro.configs.registry import get_config
    from repro.core import LargeBatchConfig, Regime
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.optim import sgd
    from repro.train.trainer import make_lm_train_step
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    B, S = (4, 64) if quick else (8, 128)
    lb = LargeBatchConfig(batch_size=B, base_batch_size=B, grad_clip=1.0)
    regime = Regime(base_lr=0.01, total_steps=100, drop_every=100)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    plain = jax.jit(make_lm_train_step(cfg, lb, regime))
    mesh = jax.jit(make_lm_train_step(cfg, lb, regime,
                                      mesh=make_host_mesh(), params=params,
                                      **kw))
    t_plain = _timeit(lambda: plain(params, opt, batch, jnp.int32(0),
                                    jax.random.PRNGKey(0))[2]["loss"],
                      reps=3)
    t_mesh = _timeit(lambda: mesh(params, opt, batch, jnp.int32(0),
                                  jax.random.PRNGKey(0))[2]["loss"], reps=3)
    emit(f"{name}_plain", t_plain, f"B={B},S={S}")
    emit(name, t_mesh,
         f"overhead={(t_mesh - t_plain) / t_plain * 100:.1f}%")


def mesh_tp_train_step(quick: bool) -> None:
    """Megatron-in-region tensor-parallel step (tp=True) vs the plain LM
    step on the host mesh."""
    _mesh_variant_lm_step("mesh_tp_train_step", quick, tp=True)


def mesh_fsdp_train_step(quick: bool) -> None:
    """FSDP step (fsdp=True: params/opt-state sharded over dp, gathered
    per step) vs the plain LM step on the host mesh."""
    _mesh_variant_lm_step("mesh_fsdp_train_step", quick, fsdp=True)


def ep_dispatch_2d(quick: bool) -> None:
    """Manual expert-parallel dispatch (shard_map region + combine psum,
    expert_parallel.ep_manual_combine) vs the local scatter/gather fallback
    for the same MoE layer on the host mesh."""
    from jax.sharding import PartitionSpec as P

    from repro.configs.registry import get_config
    from repro.core import expert_parallel as EP
    from repro.core.compat import shard_map
    from repro.launch.mesh import dp_axes, make_host_mesh
    from repro.models import moe as MOE
    cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").reduced(),
                              dtype="float32")
    B, S = (2, 64) if quick else (4, 256)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    f_local = jax.jit(lambda p, a: MOE.moe_apply(p, cfg, a)[0])
    mesh = make_host_mesh()

    def local(p, a):
        with EP.manual_mode("model", mesh.shape["model"], dp_axes(mesh)):
            return MOE.moe_apply(p, cfg, a)[0]

    rep = jax.tree.map(lambda _: P(), params)
    f_manual = jax.jit(shard_map(local, mesh=mesh,
                                 in_specs=(rep, P("data")),
                                 out_specs=P("data"), check_vma=False))
    t_local = _timeit(f_local, params, x, reps=3)
    t_manual = _timeit(f_manual, params, x, reps=3)
    err = float(jnp.abs(f_local(params, x) - f_manual(params, x)).max())
    emit("ep_dispatch_local", t_local,
         f"B={B},S={S},E={cfg.moe.n_experts}")
    emit("ep_dispatch_2d", t_manual, f"max_err={err:.1e}")


def serve_decode_step(quick: bool) -> None:
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.serving import make_serve_step
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    B, S = (4, 256) if quick else (16, 1024)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((B, 1), jnp.int32)

    us = _timeit(lambda: step(params, cache, tok, jnp.int32(S // 2))[0],
                 reps=5)
    emit("serve_decode_step_reduced", us,
         f"tok_per_s={B / (us / 1e6):.0f};cache={S}")


def serve_prefill(quick: bool) -> None:
    """Fused full-sequence prefill (one forward + K/V scatter) vs the
    token-at-a-time decode-step loop it replaced, at a long-ish prompt."""
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.serving import prefill, prefill_fused
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    B, P = (4, 128) if quick else (8, 512)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    mk = lambda: T.init_cache(cfg, B, P + 8, dtype=jnp.float32)
    f_step = jax.jit(lambda p, t, c: prefill(p, cfg, t, c)[0])
    f_fused = jax.jit(lambda p, t, c: prefill_fused(p, cfg, t, c)[0])
    t_step = _timeit(lambda: f_step(params, prompts, mk()), reps=3)
    t_fused = _timeit(lambda: f_fused(params, prompts, mk()), reps=3)
    emit("serve_prefill_stepwise", t_step, f"B={B},P={P}")
    emit("serve_prefill_fused", t_fused,
         f"speedup={t_step / max(t_fused, 1e-9):.1f}x")


def serve_decode_tok_s(quick: bool) -> None:
    """Decode throughput at the decode_32k shape (seq_len-deep cache,
    mid-sequence position): the flash-decode Pallas kernel path (head-major
    cache) vs the grouped-einsum path. Acceptance: kernel no slower."""
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.serving import make_serve_step
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    B, S = (4, 4096) if quick else (8, 32_768)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.int32(S // 2)
    results = {}
    for name, uk in (("ref", False), ("kernel", True)):
        cache = T.init_cache(cfg, B, S, dtype=jnp.float32,
                             layout="head" if uk else "seq")
        step = jax.jit(make_serve_step(cfg, use_kernels=uk))
        results[name] = _timeit(lambda: step(params, cache, tok, pos)[0],
                                reps=3)
        del cache
    emit("serve_decode_tok_s_ref", results["ref"],
         f"tok_per_s={B / (results['ref'] / 1e6):.0f};cache={S}")
    emit("serve_decode_tok_s", results["kernel"],
         f"tok_per_s={B / (results['kernel'] / 1e6):.0f};"
         f"vs_ref={results['ref'] / results['kernel']:.2f}x")


def serve_decode_tok_s_int8(quick: bool) -> None:
    """Decode throughput at EQUAL paged-pool payload memory: a bf16 pool
    with B slots vs an int8 pool (cache_dtype="int8": per-slot symmetric
    codes + f32 scale planes) with 2B slots — int8 halves the kp/vp bytes
    per slot, so the same pool memory serves twice the rows. Decode
    attention is cache-bandwidth-bound, so equal pool bytes per step at 2x
    tokens should approach 2x useful tok/s. Acceptance: the int8 engine
    sustains >= 2x the bf16 slot count at >= parity per-step time."""
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.serving import make_serve_step
    from repro.serving.engine import _write_pt
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    B, S, page = (2, 4096, 64) if quick else (4, 32_768, 64)
    nb = S // page
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    pos_val = S // 2
    results, slots_of, bytes_of = {}, {}, {}
    for name, cache_dtype, slots in (("bf16", None, B), ("int8", "int8", 2 * B)):
        n_pages = 1 + slots * nb
        cache = T.init_cache(cfg, slots, S, dtype=jnp.bfloat16,
                             layout="paged", page_size=page,
                             total_pages=n_pages, cache_dtype=cache_dtype)
        # back every row's blocks with distinct physical pages (page 0
        # stays the trash page), as the engine would mid-flight
        pt = 1 + np.arange(slots * nb, dtype=np.int32).reshape(slots, nb)
        cache = _write_pt(cache, jnp.asarray(pt))
        kp = jax.tree_util.tree_flatten_with_path(cache)[0]
        bytes_of[name] = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize for p, l in kp
            if str(p[-1].key if hasattr(p[-1], "key") else p[-1])
            in ("kp", "vp"))
        step = jax.jit(make_serve_step(cfg, use_kernels=True))
        tok = jnp.zeros((slots, 1), jnp.int32)
        pos = jnp.full((slots,), pos_val, jnp.int32)
        results[name] = _timeit(lambda: step(params, cache, tok, pos)[0],
                                reps=3)
        slots_of[name] = slots
        del cache
    emit("serve_decode_tok_s_bf16_paged", results["bf16"],
         f"tok_per_s={slots_of['bf16'] / (results['bf16'] / 1e6):.0f};"
         f"slots={slots_of['bf16']};pool_mb={bytes_of['bf16'] / 2**20:.1f}")
    tps_b = slots_of["bf16"] / (results["bf16"] / 1e6)
    tps_i = slots_of["int8"] / (results["int8"] / 1e6)
    emit("serve_decode_tok_s_int8", results["int8"],
         f"tok_per_s={tps_i:.0f};slots={slots_of['int8']};"
         f"pool_mb={bytes_of['int8'] / 2**20:.1f};"
         f"vs_bf16={tps_i / max(tps_b, 1e-9):.2f}x")


def serve_continuous_tok_s(quick: bool) -> None:
    """Continuous-batching engine (paged KV cache, per-row positions,
    EOS retirement + mid-flight admission) vs the static lockstep baseline
    over the SAME Poisson arrival trace at equal cache memory (num_slots
    static rows of depth max_len == the paged pool). Acceptance: the
    continuous engine sustains more useful tok/s."""
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.serving import (ContinuousEngine, poisson_trace,
                               run_static_trace)
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    slots, page = (3, 8) if quick else (4, 16)
    n_req = 10 if quick else 24
    max_len = 64 if quick else 128
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = poisson_trace(cfg, n_req, rate=0.5, seed=0,
                         prompt_len_choices=(8, 16),
                         new_token_choices=(8, 16) if quick else (8, 32))
    n_blocks = max_len // page
    eng = ContinuousEngine(params, cfg, num_slots=slots, max_len=max_len,
                           layout="paged", page_size=page,
                           total_pages=1 + slots * n_blocks)
    eng.run(reqs)                                 # warm
    t0 = time.perf_counter()
    comps = eng.run(reqs)
    t_cont = (time.perf_counter() - t0) * 1e6
    useful = sum(len(c.tokens) for c in comps.values())
    run_static_trace(params, cfg, reqs, batch=slots, max_len=max_len)  # warm
    t0 = time.perf_counter()
    static_useful = run_static_trace(params, cfg, reqs, batch=slots,
                                     max_len=max_len)
    t_stat = (time.perf_counter() - t0) * 1e6
    emit("serve_static_tok_s", t_stat / max(static_useful, 1),
         f"tok_per_s={static_useful / (t_stat / 1e6):.0f};slots={slots}")
    emit("serve_continuous_tok_s", t_cont / max(useful, 1),
         f"tok_per_s={useful / (t_cont / 1e6):.0f};"
         f"vs_static={t_stat / max(t_cont, 1e-9):.2f}x;"
         f"pages={1 + slots * n_blocks}")


def sweep_runner_overhead(quick: bool) -> None:
    """experiments.runner (spec expansion + JSONL store + checkpointing
    plumbing) vs calling train_vision directly for the same run — the
    subsystem tax on a short run."""
    import shutil
    import tempfile

    from repro.experiments import get_sweep, run_sweep
    from repro.models.cnn import model_fns
    from repro.train.trainer import train_vision
    steps = 20 if quick else 60
    sweep = get_sweep("generalization-gap", steps=steps)
    spec = sweep.expand()[0]                      # the SB column
    regime = spec.regime()
    data = spec.data.build()

    def direct():
        return train_vision(model_fns(spec.model), spec.model, data,
                            spec.lb, regime, seed=spec.seed,
                            track_diffusion=spec.track_diffusion)

    direct()                   # absorb first-call tracing/import overheads
    t0 = time.perf_counter()
    direct()
    t_direct = (time.perf_counter() - t0) * 1e6

    out = tempfile.mkdtemp(prefix="sweep_bench_")
    try:
        one = dataclasses.replace(sweep, methods={"SB": sweep.methods["SB"]})
        t0 = time.perf_counter()
        run_sweep(one, out, checkpoint_every=max(1, steps // 2))
        t_runner = (time.perf_counter() - t0) * 1e6
    finally:
        shutil.rmtree(out, ignore_errors=True)
    emit("sweep_runner_direct", t_direct, f"steps={steps}")
    emit("sweep_runner_overhead", t_runner,
         f"overhead={(t_runner - t_direct) / t_direct * 100:.1f}%")


def roofline_from_dryrun(quick: bool) -> None:
    files = sorted(glob.glob("experiments/dryrun/*.json"))
    if not files:
        emit("roofline_from_dryrun", 0.0, "no dryrun records; run "
             "python -m repro.launch.dryrun --all first")
        return
    for f in files:
        rec = json.load(open(f))
        if "roofline" not in rec:
            continue
        r = rec["roofline"]
        emit(f"roofline[{rec['arch']}|{rec['shape']}|{rec['mesh']}]",
             r[rec["bottleneck"]] * 1e6,
             f"compute={r['compute_s']*1e3:.1f}ms;"
             f"memory={r['memory_s']*1e3:.1f}ms;"
             f"collective={r['collective_s']*1e3:.1f}ms;"
             f"bound={rec['bottleneck'][:-2]};"
             f"useful={rec.get('useful_flops_ratio', 0):.2f}")


BENCHES: Dict[str, Callable] = {
    "kernel_gbn": kernel_gbn,
    "kernel_gbn_grad": kernel_gbn_grad,
    "kernel_flash_attention": kernel_flash_attention,
    "kernel_attention_grad": kernel_attention_grad,
    "kernel_mamba": kernel_mamba,
    "kernel_mamba_grad": kernel_mamba_grad,
    "kernel_rmsnorm_residual": kernel_rmsnorm_residual,
    "kernel_swiglu": kernel_swiglu,
    "kernel_rope_fused": kernel_rope_fused,
    "table1_generalization_gap": table1_generalization_gap,
    "figure1_batch_size_error": figure1_batch_size_error,
    "figure2_weight_distance": figure2_weight_distance,
    "appendixB_random_potential": appendixB_random_potential,
    "lm_train_step": lm_train_step,
    "mesh_lm_train_step": mesh_lm_train_step,
    "mesh_tp_train_step": mesh_tp_train_step,
    "mesh_fsdp_train_step": mesh_fsdp_train_step,
    "ep_dispatch_2d": ep_dispatch_2d,
    "serve_decode_step": serve_decode_step,
    "serve_prefill": serve_prefill,
    "serve_decode_tok_s": serve_decode_tok_s,
    "serve_decode_tok_s_int8": serve_decode_tok_s_int8,
    "serve_continuous_tok_s": serve_continuous_tok_s,
    "sweep_runner_overhead": sweep_runner_overhead,
    "roofline_from_dryrun": roofline_from_dryrun,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / few steps (CI mode)")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    ap.add_argument("--gate", action="store_true",
                    help="after the run, diff each new BENCH_*.json row "
                         "against its trailing median and exit 1 on "
                         "regression (repro.analysis bench gate)")
    ap.add_argument("--gate-tol", type=float, default=None,
                    help="--gate: fractional regression tolerance "
                         "(default 0.5 = 50%%)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name](args.quick)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(ROWS) + "\n")
    if args.gate:
        from repro.analysis.bench_gate import check_bench_regressions
        from repro.analysis.findings import render
        ran = {row.split(",", 1)[0] for row in ROWS}
        kw = {} if args.gate_tol is None else {"tol": args.gate_tol}
        findings = check_bench_regressions(names=sorted(ran), **kw)
        print(render(findings))
        if findings:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
