"""Figure-2 analogue: ultra-slow (logarithmic) diffusion of the weights.

Trains the same model at several batch sizes with a constant high LR and
shows ||w_t - w_0|| against log t: the log-law fit (R^2 near 1) with
batch-dependent slopes is the paper's evidence for the "random walk on a
random potential" model with alpha = 2. Also runs the Appendix-B probe
(loss std vs distance on random rays — ~linear for alpha = 2).

Run:  PYTHONPATH=src python examples/diffusion_walk.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import F1_MNIST
from repro.core import LargeBatchConfig, Regime
from repro.core.diffusion import random_potential_probe
from repro.data.synthetic import teacher_classification
from repro.models.cnn import model_fns
from repro.train.trainer import train_vision


def main():
    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(128, 128), ghost_batch_size=16)
    data = teacher_classification(3, n_train=4096, n_test=512,
                                  input_shape=(8, 8, 1), n_classes=10)

    print("== weight distance vs log t (constant high LR, no drops) ==")
    print(f"{'batch':>6s} {'slope':>7s} {'log R^2':>8s} {'pow exp':>8s} "
          f"{'pow R^2':>8s}")
    for bs in (32, 128, 512):
        lb = LargeBatchConfig(batch_size=bs, base_batch_size=bs,
                              grad_clip=0.0)
        regime = Regime(base_lr=0.08, total_steps=400, drop_every=10**9)
        out = train_vision(model_fns(cfg), cfg, data, lb, regime, seed=11)
        lf, pf = out["log_fit"], out["power_fit"]
        print(f"{bs:6d} {lf['slope']:7.3f} {lf['r2']:8.4f} "
              f"{pf['power']:8.3f} {pf['r2']:8.4f}")
    print("(log fit R^2 ~ 1 with exponent << 0.5 == ultra-slow diffusion)")

    print("\n== Appendix B: random-potential probe ==")
    init_fn, apply_fn = model_fns(cfg)
    params, state = init_fn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(data.x_train[:512])
    y = jnp.asarray(data.y_train[:512])

    @jax.jit
    def loss(p):
        logits, _ = apply_fn(p, state, cfg, x, training=True, use_gbn=False)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    out = random_potential_probe(loss, params, jax.random.PRNGKey(1),
                                 n_samples=150, max_radius=10.0, n_bins=8)
    print(f"{'distance':>9s} {'loss std':>9s}")
    for d, s in zip(out["distance"], out["loss_std"]):
        bar = "#" * int(40 * s / (out['loss_std'].max() + 1e-9))
        print(f"{d:9.2f} {s:9.4f}  {bar}")
    corr = np.corrcoef(out["distance"], out["loss_std"])[0, 1]
    print(f"corr(distance, loss-std) = {corr:.3f} "
          f"(~linear growth == alpha = 2)")


if __name__ == "__main__":
    main()
