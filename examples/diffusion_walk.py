"""Figure-2 analogue: ultra-slow (logarithmic) diffusion of the weights —
a thin wrapper over :mod:`repro.experiments`.

Runs the ``diffusion`` sweep (the same model at several batch sizes with a
constant high LR) through the resumable runner and prints the log-t vs
power-law fits of ||w_t - w_0|| re-fit from the stored distance series: the
log-law fit (R^2 near 1) with batch-dependent slopes is the paper's evidence
for the "random walk on a random potential" model with alpha = 2. Also runs
the Appendix-B probe directly (loss std vs distance on random rays —
~linear for alpha = 2).

Run:  PYTHONPATH=src python examples/diffusion_walk.py
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import F1_MNIST
from repro.core.diffusion import random_potential_probe
from repro.data.synthetic import teacher_classification
from repro.experiments import get_sweep, run_sweep
from repro.experiments.metrics import diffusion_view, format_diffusion
from repro.models.cnn import model_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--out", default="experiments/runs")
    ap.add_argument("--burn-in", type=int, default=2)
    args = ap.parse_args()

    print("== weight distance vs log t (constant high LR, no drops) ==")
    sweep = get_sweep("diffusion", steps=args.steps)
    records = run_sweep(sweep, args.out, log_fn=print)
    print()
    print(format_diffusion(diffusion_view(records, burn_in=args.burn_in)))
    print("(log fit R^2 ~ 1 with exponent << 0.5 == ultra-slow diffusion)")

    print("\n== Appendix B: random-potential probe ==")
    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(128, 128), ghost_batch_size=16)
    data = teacher_classification(3, n_train=4096, n_test=512,
                                  input_shape=(8, 8, 1), n_classes=10)
    init_fn, apply_fn = model_fns(cfg)
    params, state = init_fn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(data.x_train[:512])
    y = jnp.asarray(data.y_train[:512])

    @jax.jit
    def loss(p):
        logits, _ = apply_fn(p, state, cfg, x, training=True, use_gbn=False)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    out = random_potential_probe(loss, params, jax.random.PRNGKey(1),
                                 n_samples=150, max_radius=10.0, n_bins=8)
    print(f"{'distance':>9s} {'loss std':>9s}")
    for d, s in zip(out["distance"], out["loss_std"]):
        bar = "#" * int(40 * s / (out['loss_std'].max() + 1e-9))
        print(f"{d:9.2f} {s:9.4f}  {bar}")
    corr = np.corrcoef(out["distance"], out["loss_std"])[0, 1]
    print(f"corr(distance, loss-std) = {corr:.3f} "
          f"(~linear growth == alpha = 2)")


if __name__ == "__main__":
    main()
