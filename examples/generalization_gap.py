"""The paper's Table-1 experiment (reduced scale): the generalization gap and
its elimination.

Trains the F1-style MLP on a synthetic classification task with the five
method columns — SB, LB, LB+LR, LB+LR+GBN, LB+LR+GBN+RA — and prints the
validation-accuracy table. Expected qualitative result (matches the paper):

    SB > LB              (the generalization gap appears)
    LB+LR > LB           (sqrt LR scaling closes much of it)
    LB+LR+GBN >= LB+LR   (ghost batch norm helps further)
    LB+..+RA ~ SB        (regime adaptation eliminates it)

Run:  PYTHONPATH=src python examples/generalization_gap.py [--steps 1200]
"""
import argparse
import dataclasses
import time

from repro.configs.paper_models import F1_MNIST
from repro.core import Regime, presets
from repro.data.synthetic import teacher_classification
from repro.models.cnn import model_fns
from repro.train.trainer import train_vision


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=2400,
                    help="small-batch step budget")
    ap.add_argument("--large-batch", type=int, default=1024)
    ap.add_argument("--small-batch", type=int, default=32)
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()

    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(192, 192, 192),
                              ghost_batch_size=16)
    data = teacher_classification(7, n_train=6144, n_test=1024,
                                  input_shape=(8, 8, 1), n_classes=10,
                                  label_noise=0.05)
    small = Regime(base_lr=0.08, total_steps=args.steps,
                   drop_every=args.steps // 3, drop_factor=0.2)
    cols = presets(args.large_batch, args.small_batch, ghost=16)

    print(f"{'method':>14s} {'steps':>6s} {'val_acc':>8s} {'train_acc':>9s} "
          f"{'|w-w0|':>7s}")
    results = {}
    for name, lb in cols.items():
        accs, dists, steps = [], [], 0
        for seed in range(args.seeds):
            regime = lb.build_regime(small)
            t0 = time.time()
            out = train_vision(model_fns(cfg), cfg, data, lb, regime,
                               seed=5 + seed)
            accs.append(out["final_acc"])
            dists.append(out["history"]["distance"][-1])
            steps = out["steps"]
        acc = sum(accs) / len(accs)
        results[name] = acc
        print(f"{name:>14s} {steps:6d} {acc:8.4f} "
              f"{out['train_acc']:9.4f} {sum(dists)/len(dists):7.3f}")

    gap = results["SB"] - results["LB"]
    closed = results["LB+LR+GBN+RA"] - results["LB"]
    print(f"\ngeneralization gap (SB - LB):        {gap:+.4f}")
    print(f"recovered by LR+GBN+RA (vs LB):      {closed:+.4f}")
    print(f"final (RA) vs small batch:           "
          f"{results['LB+LR+GBN+RA'] - results['SB']:+.4f}")


if __name__ == "__main__":
    main()
