"""The paper's Table-1 experiment (reduced scale): the generalization gap and
its elimination — a thin wrapper over :mod:`repro.experiments`.

Runs the ``generalization-gap`` sweep (method columns SB, LB, LB+LR,
LB+LR+GBN, LB+LR+GBN+RA) through the resumable runner and prints the
aggregated Table-1 view. Expected qualitative result (matches the paper):

    SB > LB              (the generalization gap appears)
    LB+LR > LB           (sqrt LR scaling closes much of it)
    LB+LR+GBN >= LB+LR   (ghost batch norm helps further)
    LB+..+RA ~ SB        (regime adaptation eliminates it)

Records accumulate in ``--out``/generalization-gap/records.jsonl; rerunning
skips finished runs and resumes an interrupted one from its checkpoint.

Run:  PYTHONPATH=src python examples/generalization_gap.py [--steps 1200]
"""
import argparse

from repro.experiments import get_sweep, run_sweep
from repro.experiments.metrics import format_table1, table1_view


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=2400,
                    help="small-batch step budget")
    ap.add_argument("--large-batch", type=int, default=1024)
    ap.add_argument("--small-batch", type=int, default=32)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--out", default="experiments/runs")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore existing records and rerun")
    ap.add_argument("--mesh", action="store_true",
                    help="fan runs over the ('data',) mesh when usable")
    args = ap.parse_args()

    sweep = get_sweep("generalization-gap", steps=args.steps,
                      large_batch=args.large_batch,
                      small_batch=args.small_batch,
                      seeds=tuple(range(args.seeds)), use_mesh=args.mesh)
    records = run_sweep(sweep, args.out, resume=not args.fresh,
                        checkpoint_every=max(100, args.steps // 8),
                        log_fn=print)

    rows = table1_view(records)
    print()
    print(format_table1(rows))

    acc = {r["method"]: r["val_acc_mean"] for r in rows}
    gap = acc["SB"] - acc["LB"]
    closed = acc["LB+LR+GBN+RA"] - acc["LB"]
    print(f"\ngeneralization gap (SB - LB):        {gap:+.4f}")
    print(f"recovered by LR+GBN+RA (vs LB):      {closed:+.4f}")
    print(f"final (RA) vs small batch:           "
          f"{acc['LB+LR+GBN+RA'] - acc['SB']:+.4f}")


if __name__ == "__main__":
    main()
