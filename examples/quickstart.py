"""Quickstart: train a small LM with the paper's large-batch recipe.

The five lines that matter:

    lb     = LargeBatchConfig(batch_size=64, base_batch_size=16,
                              lr_rule="sqrt", regime_adaptation=True)
    regime = lb.build_regime(small_batch_regime)
    step   = make_lm_train_step(cfg, lb, regime)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import DiffusionTracker, LargeBatchConfig, Regime
from repro.data.synthetic import lm_sequences, token_lm
from repro.models import transformer as T
from repro.optim import sgd
from repro.train.trainer import make_lm_train_step


def main():
    # a reduced variant of one of the assigned architectures
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    # the paper's recipe: sqrt LR scaling + clipping + regime adaptation
    lb = LargeBatchConfig(batch_size=64, base_batch_size=16, lr_rule="sqrt",
                          regime_adaptation=True, grad_clip=1.0)
    small = Regime(base_lr=0.02, total_steps=60, drop_every=25)
    regime = lb.build_regime(small)
    print(f"large-batch regime: lr={regime.base_lr:.4f} "
          f"(sqrt-scaled from {small.base_lr}), {regime.total_steps} steps")

    # synthetic Markov token data
    stream = token_lm(0, vocab_size=cfg.vocab_size, n_tokens=64 * 64 * 40)
    seqs = lm_sequences(stream, 64)

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    step = jax.jit(make_lm_train_step(cfg, lb, regime))
    tracker = DiffusionTracker(params)

    rng = np.random.RandomState(0)
    for i in range(regime.total_steps):
        idx = rng.randint(0, seqs.shape[0], lb.batch_size)
        batch = {"tokens": jnp.asarray(seqs[idx])}
        params, opt, m = step(params, opt, batch, jnp.int32(i),
                              jax.random.PRNGKey(i))
        if i % 10 == 0 or i == regime.total_steps - 1:
            d = tracker.record(i + 1, params)
            print(f"step {i:3d}  ce={float(m['ce']):.4f}  "
                  f"lr={float(m['lr']):.4f}  |w-w0|={d:.3f}")

    fit = tracker.log_fit(burn_in=2)
    print(f"\nultra-slow diffusion check: distance ~ "
          f"{fit['slope']:.2f}*log(t)+{fit['intercept']:.2f} "
          f"(R^2={fit['r2']:.3f})")


if __name__ == "__main__":
    main()
