"""Batched serving demo across architecture families: decoder-only, MoE,
SSM (mamba), and the cross-attention VLM path — all through the same
``serve_step`` the decode dry-run shapes lower.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.serving import generate


def demo(arch: str, batch: int = 4, prompt_len: int = 8, new: int = 12):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                 0, cfg.vocab_size)
    memory = None
    if cfg.vision is not None:
        memory = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2),
            (batch, cfg.vision.n_image_tokens, cfg.d_model))
    if cfg.encoder is not None:
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (batch, 16, cfg.encoder.d_model))
        memory = T.encode(params, cfg, frames)
    t0 = time.time()
    out = generate(params, cfg, prompts, max_new_tokens=new, memory=memory)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"{arch:>24s} [{cfg.family:6s}]  out={tuple(out.shape)}  "
          f"{batch * new / dt:7.1f} tok/s   first row: "
          f"{out[0, prompt_len:prompt_len + 6].tolist()}")


def main():
    print("batched greedy serving (reduced configs, CPU):")
    for arch in ("qwen3-1.7b",            # dense GQA
                 "h2o-danube-3-4b",       # sliding-window ring cache
                 "qwen2-moe-a2.7b",       # MoE with shared experts
                 "falcon-mamba-7b",       # recurrent SSM state
                 "jamba-v0.1-52b",        # hybrid mamba+attn+MoE
                 "llama-3.2-vision-11b",  # cross-attention to image stub
                 "seamless-m4t-large-v2"  # enc-dec (audio stub)
                 ):
        demo(arch)


if __name__ == "__main__":
    main()
