"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the full large-batch recipe (sqrt LR + clipping + RA), checkpointing
and diffusion logging included.

This wraps launch/train.py's loop with a custom ~100M config built from the
qwen3 family. On this CPU container the default is a shortened run; pass
--steps 300 --batch 32 for the full driver (hours on 1 core, minutes on a
real accelerator).

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 40]
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save as ckpt_save
from repro.configs.base import LayerSpec, ModelConfig
from repro.core import DiffusionTracker, LargeBatchConfig, Regime
from repro.data.synthetic import lm_sequences, token_lm
from repro.models import transformer as T
from repro.optim import sgd
from repro.train.trainer import make_lm_train_step


def build_100m() -> ModelConfig:
    return ModelConfig(
        name="repro-100m",
        family="dense",
        d_model=640,
        n_heads=10,
        n_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=16_384,
        body_pattern=(LayerSpec(mixer="attn", ff="dense"),),
        body_repeats=12,
        qk_norm=True,
        tie_embeddings=True,
        dtype="float32",
        citation="in-house 100M config (qwen3-style)",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--base-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt", default="experiments/ckpt_100m")
    args = ap.parse_args()

    cfg = build_100m()
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{cfg.n_layers} layers")

    lb = LargeBatchConfig(batch_size=args.batch,
                          base_batch_size=args.base_batch,
                          lr_rule="sqrt", regime_adaptation=True,
                          grad_clip=1.0)
    regime = lb.build_regime(Regime(base_lr=0.01, total_steps=args.steps,
                                    drop_every=max(1, args.steps // 3)))

    stream = token_lm(0, vocab_size=cfg.vocab_size,
                      n_tokens=args.batch * args.seq_len * 64)
    seqs = lm_sequences(stream, args.seq_len)
    held = seqs[:8]
    train = seqs[8:]

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    step = jax.jit(make_lm_train_step(cfg, lb, regime))
    tracker = DiffusionTracker(params)

    rng = np.random.RandomState(0)
    t0 = time.time()
    for i in range(regime.total_steps):
        idx = rng.randint(0, train.shape[0], args.batch)
        batch = {"tokens": jnp.asarray(train[idx])}
        params, opt, m = step(params, opt, batch, jnp.int32(i),
                              jax.random.PRNGKey(i))
        if i % 10 == 0 or i == regime.total_steps - 1:
            d = tracker.record(i + 1, params)
            toks = args.batch * args.seq_len * (i + 1)
            print(f"step {i:4d}  ce={float(m['ce']):.4f}  "
                  f"lr={float(m['lr']):.4f}  |w-w0|={d:.2f}  "
                  f"({toks / (time.time() - t0):.0f} tok/s)", flush=True)

    # held-out eval
    from repro.models.transformer import lm_loss
    _, metrics = jax.jit(lambda p: lm_loss(p, cfg, {"tokens": jnp.asarray(
        held)}))(params)
    print(f"held-out ce: {float(metrics['ce']):.4f}")
    fit = tracker.log_fit(burn_in=2)
    print(f"diffusion fit: slope={fit['slope']:.3f} r2={fit['r2']:.3f}")
    ckpt_save(args.ckpt, regime.total_steps, params, opt,
              extra={"arch": cfg.name})
    print(f"checkpoint: {args.ckpt}")


if __name__ == "__main__":
    main()
