#!/usr/bin/env bash
# Static correctness suite: AST lint over src/, Pallas kernel contract
# checker, and the jaxpr/HLO trace auditor over the hot jitted entry
# points. Exit 1 on any finding (see docs/analysis.md for the rule
# catalog and the # repro: ignore[rule-id] suppression syntax).
#
# Usage:
#   scripts/lint.sh                  # full default suite
#   scripts/lint.sh --lint           # AST rules only (instant, jax-free)
#   scripts/lint.sh --bench-gate     # opt-in BENCH_*.json regression gate
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m repro.analysis "$@"
