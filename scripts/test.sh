#!/usr/bin/env bash
# Tier-1 gate (the exact command from ROADMAP.md), with an explicit
# collection pass first so import regressions (like the jax shard_map move)
# fail loudly on their own, before any test runs.
#
# Usage:
#   scripts/test.sh              # full tier-1 suite (~20 min)
#   scripts/test.sh --quick      # tier-0 quick gate (seconds-scale subset)
#   scripts/test.sh -m tier1     # just the tier1-marked core subset
#   scripts/test.sh tests/test_kernels.py -k gbn   # any pytest args
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

args=()
for a in "$@"; do
  if [[ "$a" == "--quick" ]]; then
    args+=(-m tier0)
  else
    args+=("$a")
  fi
done

echo "== collect =="
python -m pytest --collect-only -q >/dev/null

echo "== run =="
exec python -m pytest -x -q "${args[@]+"${args[@]}"}"
