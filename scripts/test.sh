#!/usr/bin/env bash
# Tier-1 gate (the exact command from ROADMAP.md), with an explicit
# collection pass first so import regressions (like the jax shard_map move)
# fail loudly on their own, before any test runs.
#
# The bare full run executes as TWO concurrent file batches: the two
# heaviest files (test_decode ~8 min; test_parallel_2d's 4-device
# subprocess equivalence suite) anchor batch A while every other file runs
# alongside in batch B — roughly halving wall clock without oversubscribing
# the box. Any explicit pytest args fall back to a single serial
# invocation.
#
# Usage:
#   scripts/test.sh              # full tier-1 suite, 2 concurrent batches
#   scripts/test.sh --quick      # tier-0 quick gate (seconds-scale subset)
#   scripts/test.sh -m tier1     # just the tier1-marked core subset
#   scripts/test.sh tests/test_kernels.py -k gbn   # any pytest args
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

args=()
for a in "$@"; do
  if [[ "$a" == "--quick" ]]; then
    args+=(-m tier0)
  else
    args+=("$a")
  fi
done

echo "== collect =="
python -m pytest --collect-only -q >/dev/null

echo "== run =="
if [[ ${#args[@]} -eq 0 ]]; then
  # test_analysis rides batch A: its repo-wide gates (lint + kernel
  # contracts + trace audit) compile the hot entry points, which overlaps
  # the decode suite's long pole instead of stretching batch B
  batch_a=(tests/test_decode.py tests/test_parallel_2d.py tests/test_serving_continuous.py tests/test_analysis.py tests/test_fused_kernels.py)
  # batch C: the multi-process jax.distributed tests, under a hard wall
  # clock — a hung coordinator handshake must fail the suite loudly, not
  # wedge it (the in-test subprocess waits have their own timeouts; this
  # is the outer belt-and-braces bound)
  batch_c=(tests/test_distributed.py)
  batch_c_timeout=900
  batch_b=()
  for f in tests/test_*.py; do
    case " ${batch_a[*]} ${batch_c[*]} " in
      *" $f "*) ;;
      *) batch_b+=("$f") ;;
    esac
  done
  log_a=$(mktemp) log_b=$(mktemp) log_c=$(mktemp)
  trap 'rm -f "$log_a" "$log_b" "$log_c"' EXIT
  # repro.obs.trace --label wraps each batch and prints its wall time
  python -m repro.obs --label "batch A" -- \
    python -m pytest -x -q "${batch_a[@]}" >"$log_a" 2>&1 &
  pid_a=$!
  python -m repro.obs --label "batch B" -- \
    python -m pytest -x -q "${batch_b[@]}" >"$log_b" 2>&1 &
  pid_b=$!
  timeout --signal=TERM --kill-after=30 "$batch_c_timeout" \
    python -m repro.obs --label "batch C" -- \
    python -m pytest -x -q "${batch_c[@]}" >"$log_c" 2>&1 &
  pid_c=$!
  rc=0
  wait "$pid_a" || rc=$?
  wait "$pid_b" || rc=$?
  rc_c=0
  wait "$pid_c" || rc_c=$?
  if [[ "$rc_c" -ne 0 ]]; then
    rc=${rc_c}
    if [[ "${rc_c}" -ge 124 ]]; then
      echo "batch C exceeded ${batch_c_timeout}s (distributed init hang?)" >>"$log_c"
    fi
  fi
  echo "== batch A (${batch_a[*]}) =="
  cat "$log_a"
  echo "== batch B (${#batch_b[@]} files) =="
  cat "$log_b"
  echo "== batch C (${batch_c[*]}) =="
  cat "$log_c"
  exit "$rc"
fi
exec python -m pytest -x -q "${args[@]}"
