"""Static correctness suite: AST lint, jaxpr/HLO trace auditor, kernel
contract checker, bench regression gate.

Run it::

    PYTHONPATH=src python -m repro.analysis            # lint+contracts+trace
    PYTHONPATH=src python -m repro.analysis --lint     # one layer only
    PYTHONPATH=src python -m repro.analysis --bench-gate

Exit status 1 when any finding survives suppression
(``# repro: ignore[rule-id]``). ``docs/analysis.md`` has the rule catalog;
``tests/test_analysis.py`` enforces the repo-wide gate in tier 1.
"""
from repro.analysis.findings import (Finding, filter_suppressed, render,
                                     suppressions, to_json)

__all__ = ["Finding", "filter_suppressed", "render", "suppressions",
           "to_json"]
