"""Bench regression gate: newest ``BENCH_*.json`` row vs trailing median.

Each benchmark appends one JSONL row ``{ts, name, us_per_call, derived}``
to ``BENCH_<name>.json`` at the repo root (:mod:`benchmarks.run`). The gate
compares the newest ``us_per_call`` against the median of up to ``window``
preceding rows and emits a ``bench-regression`` finding when it is more
than ``tol`` slower (fractional: 0.5 = 50%). Benchmarks with fewer than
``min_history`` prior rows are skipped — one noisy cold row must not brick
the gate, which is also why this check is opt-in (``--bench-gate`` /
``benchmarks.run --gate``) rather than part of the default suite: it
judges timing on whatever machine ran it, not code.
"""
from __future__ import annotations

import json
import statistics
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.findings import Finding
from repro.analysis.lint import REPO_ROOT

DEFAULT_TOL = 0.5          # generous: container timings are noisy
DEFAULT_WINDOW = 8
DEFAULT_MIN_HISTORY = 3


def _load_rows(path: Path) -> List[dict]:
    rows = []
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        line = line.strip()
        if not line:
            continue
        try:
            row = json.loads(line)
        except json.JSONDecodeError:
            rows.append({"_bad_line": i})
            continue
        rows.append(row)
    return rows


def check_bench_regressions(root: Path = REPO_ROOT, *,
                            tol: float = DEFAULT_TOL,
                            window: int = DEFAULT_WINDOW,
                            min_history: int = DEFAULT_MIN_HISTORY,
                            names: Optional[Sequence[str]] = None
                            ) -> List[Finding]:
    out: List[Finding] = []
    for path in sorted(root.glob("BENCH_*.json")):
        rel = path.name
        rows = _load_rows(path)
        for row in rows:
            if "_bad_line" in row:
                out.append(Finding(rel, row["_bad_line"],
                                   "bench-regression",
                                   "unparseable JSONL row"))
        rows = [r for r in rows
                if "_bad_line" not in r and "us_per_call" in r]
        if not rows:
            continue
        name = rows[-1].get("name", path.stem)
        if names and name not in names:
            continue
        if len(rows) - 1 < min_history:
            continue                      # not enough history to judge
        newest = float(rows[-1]["us_per_call"])
        prior = [float(r["us_per_call"]) for r in rows[:-1]][-window:]
        base = statistics.median(prior)
        if base > 0 and newest > base * (1.0 + tol):
            out.append(Finding(
                rel, len(rows), "bench-regression",
                f"{name}: {newest:.1f} us/call vs trailing median "
                f"{base:.1f} (+{100 * (newest / base - 1):.0f}%, "
                f"tol {100 * tol:.0f}%, n={len(prior)})"))
    return out
