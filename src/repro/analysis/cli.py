"""``python -m repro.analysis`` — run the static suite, exit 1 on findings.

With no layer flags the default set runs: AST lint over ``src/``, the
kernel contract checker, and the trace auditor (which traces/compiles the
hot entry points, a few seconds). Layer flags select subsets; the bench
gate is opt-in only (``--bench-gate``) because it judges wall-clock
history, not code — it also backs ``benchmarks/run.py --gate``.
"""
from __future__ import annotations

import argparse
from typing import List, Optional, Sequence

from repro.analysis.findings import Finding, render, to_json


def run_suite(*, lint: bool = True, contracts: bool = True,
              trace_audit: bool = True, bench_gate: bool = False,
              tol: Optional[float] = None) -> List[Finding]:
    """Lazy per-layer imports: ``--lint`` stays jax-free and instant."""
    findings: List[Finding] = []
    if lint:
        from repro.analysis.lint import run_repo_lint
        findings += run_repo_lint()
    if contracts:
        from repro.analysis.kernel_contracts import run_kernel_contracts
        findings += run_kernel_contracts()
    if trace_audit:
        from repro.analysis.trace_audit import run_trace_audit
        findings += run_trace_audit()
    if bench_gate:
        from repro.analysis import bench_gate as bg
        kw = {} if tol is None else {"tol": tol}
        findings += bg.check_bench_regressions(**kw)
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static correctness suite (see docs/analysis.md)")
    ap.add_argument("--lint", action="store_true",
                    help="AST lint rules over src/")
    ap.add_argument("--contracts", action="store_true",
                    help="Pallas kernel contract checker")
    ap.add_argument("--trace-audit", action="store_true",
                    help="jaxpr/HLO audit of the hot jitted entry points")
    ap.add_argument("--bench-gate", action="store_true",
                    help="BENCH_*.json newest-vs-trailing-median gate "
                         "(opt-in; never part of the default set)")
    ap.add_argument("--tol", type=float, default=None,
                    help="bench gate: fractional regression tolerance "
                         "(default 0.5 = 50%%)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON instead of text")
    args = ap.parse_args(argv)

    any_layer = args.lint or args.contracts or args.trace_audit \
        or args.bench_gate
    findings = run_suite(
        lint=args.lint or not any_layer,
        contracts=args.contracts or not any_layer,
        trace_audit=args.trace_audit or not any_layer,
        bench_gate=args.bench_gate,
        tol=args.tol)
    print(to_json(findings) if args.json else render(findings))
    return 1 if findings else 0
