"""Structured findings + suppression handling for the static suite.

Every layer of :mod:`repro.analysis` (AST lint, trace auditor, kernel
contract checker, bench gate) reports the same record: a repo-relative
``file:line``, a stable rule id, and a one-line message. Suppression is
per-line and per-rule::

    x = float(m["lr"])  # repro: ignore[host-sync]
    x = foo()           # repro: ignore[host-sync,prng-reuse]

A suppression comment silences ONLY the named rule(s) on that physical
line — there is no file- or block-level escape hatch on purpose: every
accepted violation stays visible at the line that carries it.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass
from typing import Dict, Iterable, List, Set

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*ignore\[([\w\-,\s]+)\]")


@dataclass(frozen=True)
class Finding:
    path: str        # repo-relative file (or BENCH_*.json for the gate)
    line: int        # 1-based; 0 when the finding is file-scoped
    rule: str        # stable rule id, e.g. "host-sync"
    message: str

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"


def suppressions(source: str) -> Dict[int, Set[str]]:
    """Map 1-based line number -> set of rule ids suppressed on that line."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out

def filter_suppressed(findings: Iterable[Finding],
                      source_by_path: Dict[str, str]) -> List[Finding]:
    """Drop findings whose line carries a matching suppression comment."""
    out: List[Finding] = []
    for f in findings:
        src = source_by_path.get(f.path)
        if src is not None and f.rule in suppressions(src).get(f.line, ()):
            continue
        out.append(f)
    return out


def render(findings: Iterable[Finding]) -> str:
    fs = sorted(findings, key=lambda f: (f.path, f.line, f.rule))
    if not fs:
        return "clean: 0 findings"
    lines = [f.format() for f in fs]
    lines.append(f"{len(fs)} finding(s)")
    return "\n".join(lines)


def to_json(findings: Iterable[Finding]) -> str:
    return json.dumps([asdict(f) for f in findings], indent=2,
                      sort_keys=True)
