"""Layer 3: kernel contract checker — the oracle-per-kernel discipline and
TPU tile alignment, verified statically.

Three checks over ``src/repro/kernels/`` + ``docs/kernels.md``:

- **kernel-oracle**: every module-level ``*_pallas`` function must appear
  in a docs/kernels.md contract-table row that also names a ``ref.*``
  oracle, and every ``ref.*`` name the docs cite must exist as a function
  in ``kernels/ref.py``. A kernel without an oracle (or docs citing a
  deleted oracle) breaks the repo's kernel == oracle test discipline.
- **kernel-doc**: every ``*_pallas`` function is mentioned in
  docs/kernels.md at all (as ``<module>.<name>``) — an undocumented kernel
  has no written contract to test against.
- **kernel-tile**: the tile-size helpers are swept over ragged shapes and
  both kernel dtypes: :func:`flash_attention._block_sizes` must return
  sublane-aligned (bq, bk) for any (T, S) — the PR 3 ``T=100 -> bq=104``
  bug class — and :func:`ops._mamba_tile` must return a 128-multiple
  divisor, the whole axis (<= its VMEM bound), or ``None`` (oracle
  fallback); anything else is an illegal BlockSpec off-interpret. The
  DEFAULT_BLOCK_* constants must themselves be lane-aligned.

Pure AST + pure-Python sweeps: nothing here traces or compiles, so the
check runs in milliseconds and catches misalignment before any TPU sees
the kernel.
"""
from __future__ import annotations

import ast
import re
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.lint import REPO_ROOT

KERNELS_DIR = REPO_ROOT / "src" / "repro" / "kernels"
KERNELS_DOC = REPO_ROOT / "docs" / "kernels.md"

_REF_TOKEN_RE = re.compile(r"`ref\.(\w+)`")
_PALLAS_TOKEN_RE = re.compile(r"`(\w+)\.(\w+_pallas)`")

# ragged + aligned sequence lengths; 100 is the historical repro case
_SWEEP_LENS = (1, 7, 8, 100, 128, 129, 257, 1000, 1024)
_SWEEP_DI = (64, 100, 128, 256, 384, 500, 512, 640, 768, 1000, 1024,
             1100, 1536, 2048, 4096)


def _rel(path: Path) -> str:
    p = path.resolve()
    return p.relative_to(REPO_ROOT).as_posix() \
        if p.is_relative_to(REPO_ROOT) else p.as_posix()


def _module_defs(path: Path) -> Dict[str, int]:
    """Module-level function defs: name -> lineno."""
    tree = ast.parse(path.read_text(), filename=str(path))
    return {n.name: n.lineno for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def collect_pallas_kernels(kernels_dir: Path = KERNELS_DIR
                           ) -> List[Tuple[str, str, Path, int]]:
    """All module-level ``*_pallas`` defs: (module_stem, name, path, line)."""
    out = []
    for path in sorted(kernels_dir.glob("*.py")):
        for name, line in _module_defs(path).items():
            if name.endswith("_pallas"):
                out.append((path.stem, name, path, line))
    return out


def check_oracle_pairing(kernels_dir: Path = KERNELS_DIR,
                         doc_path: Path = KERNELS_DOC) -> List[Finding]:
    out: List[Finding] = []
    doc_rel = _rel(doc_path)
    if not doc_path.exists():
        return [Finding(doc_rel, 0, "kernel-doc", "docs/kernels.md missing")]
    doc = doc_path.read_text()
    ref_defs = _module_defs(kernels_dir / "ref.py")

    # docs -> code: every cited ref.X oracle must exist
    for i, line in enumerate(doc.splitlines(), start=1):
        for m in _REF_TOKEN_RE.finditer(line):
            if m.group(1) not in ref_defs:
                out.append(Finding(
                    doc_rel, i, "kernel-oracle",
                    f"docs cite `ref.{m.group(1)}` but kernels/ref.py has "
                    "no such function"))

    # contract-table rows that pair pallas kernels with oracles
    paired: Set[str] = set()          # pallas names on a row with a ref.*
    mentioned: Set[Tuple[str, str]] = set(_PALLAS_TOKEN_RE.findall(doc))
    for line in doc.splitlines():
        if not line.lstrip().startswith("|"):
            continue
        row_pallas = [m.group(2) for m in _PALLAS_TOKEN_RE.finditer(line)]
        if row_pallas and _REF_TOKEN_RE.search(line):
            paired.update(row_pallas)

    # code -> docs: every *_pallas def documented and oracle-paired
    for stem, name, path, lineno in collect_pallas_kernels(kernels_dir):
        rel = _rel(path)
        if (stem, name) not in mentioned:
            out.append(Finding(
                rel, lineno, "kernel-doc",
                f"`{stem}.{name}` has no contract entry in docs/kernels.md"))
        elif name not in paired:
            out.append(Finding(
                rel, lineno, "kernel-oracle",
                f"`{stem}.{name}` appears in docs/kernels.md but not on a "
                "contract-table row naming a `ref.*` oracle"))
    return out


def check_tile_alignment() -> List[Finding]:
    import jax.numpy as jnp

    from repro.kernels import flash_attention as fa
    from repro.kernels import flash_decode as fd
    from repro.kernels import ops

    out: List[Finding] = []
    fa_rel = "src/repro/kernels/flash_attention.py"
    ops_rel = "src/repro/kernels/ops.py"

    for const, mod, rel in (("DEFAULT_BLOCK_Q", fa, fa_rel),
                            ("DEFAULT_BLOCK_K", fa, fa_rel),
                            ("DEFAULT_BLOCK_K", fd,
                             "src/repro/kernels/flash_decode.py")):
        v = getattr(mod, const)
        if v % 128 != 0:
            out.append(Finding(rel, 0, "kernel-tile",
                               f"{const}={v} is not lane-aligned "
                               "(128-multiple)"))

    for dtype in (jnp.float32, jnp.bfloat16):
        sub = fa._sublane(dtype)
        for T in _SWEEP_LENS:
            for S in _SWEEP_LENS:
                bq, bk = fa._block_sizes(T, S, fa.DEFAULT_BLOCK_Q,
                                         fa.DEFAULT_BLOCK_K, dtype)
                for axis, b, n in (("bq", bq, T), ("bk", bk, S)):
                    if b % sub != 0 or b <= 0:
                        out.append(Finding(
                            fa_rel, 0, "kernel-tile",
                            f"_block_sizes(T={T}, S={S}, "
                            f"{jnp.dtype(dtype).name}): {axis}={b} not a "
                            f"multiple of sublane {sub}"))
                    # a block longer than the padded axis reads OOB
                    if b > max(fa._round_up(n, sub), sub):
                        out.append(Finding(
                            fa_rel, 0, "kernel-tile",
                            f"_block_sizes(T={T}, S={S}): {axis}={b} "
                            f"exceeds the {sub}-padded axis"))

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # the sweep hits the warn paths
        # fused rmsnorm/swiglu lane gate: a non-128-multiple (100, 1100)
        # or an axis past _MAX_FUSED_LANE must fall back to the oracle
        # (None), never mis-tile; any 128-multiple within the bound must
        # pass through whole.
        for di in _SWEEP_DI + (ops._MAX_FUSED_LANE,
                               ops._MAX_FUSED_LANE + 128):
            ft = ops._fused_tile(di, "contract-sweep")
            legal = di % 128 == 0 and di <= ops._MAX_FUSED_LANE
            if legal and ft != di:
                out.append(Finding(
                    ops_rel, 0, "kernel-tile",
                    f"_fused_tile({di}) fell back to the oracle though the "
                    "axis is lane-aligned and within _MAX_FUSED_LANE"))
            elif not legal and ft is not None:
                out.append(Finding(
                    ops_rel, 0, "kernel-tile",
                    f"_fused_tile({di})={ft} would mis-tile a non-aligned "
                    "or oversized axis (must be None -> oracle fallback)"))
        for di in _SWEEP_DI:
            tile = ops._mamba_tile(di)
            if tile is None:
                if di % 128 == 0 or di <= ops._MAX_UNTILED_DI:
                    out.append(Finding(
                        ops_rel, 0, "kernel-tile",
                        f"_mamba_tile({di}) fell back to the oracle though "
                        "a legal tile exists"))
            elif tile == di:
                if di > ops._MAX_UNTILED_DI:
                    out.append(Finding(
                        ops_rel, 0, "kernel-tile",
                        f"_mamba_tile({di}) returned an untiled axis past "
                        f"_MAX_UNTILED_DI={ops._MAX_UNTILED_DI}"))
            elif tile % 128 != 0 or di % tile != 0:
                out.append(Finding(
                    ops_rel, 0, "kernel-tile",
                    f"_mamba_tile({di})={tile} is not a 128-multiple "
                    "divisor of d_inner"))
    return out


def run_kernel_contracts() -> List[Finding]:
    return check_oracle_pairing() + check_tile_alignment()
