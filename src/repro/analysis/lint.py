"""Layer 1: AST lint rules over ``src/``.

Each rule encodes one standing invariant that used to live only in ROADMAP
prose / reviewer memory (see docs/analysis.md for the catalog, suppression
syntax, and how to add a rule):

- ``shard-map-import`` — ``shard_map`` must be imported through
  ``core/compat.py`` (the version shim), never straight from jax.
- ``host-sync`` — hot-path code (trainer step loops, the serving engine,
  ``kernels/``) must not fan one device pytree out into per-element host
  syncs (``float(m["lr"])``, ``float(m["loss"])``, ... each block the
  dispatch queue separately) and must never call ``.item()``. Fetch once
  with ``jax.device_get`` and read the host copy.
- ``obs-contract`` — any function taking ``obs=`` defaults it to ``None``
  (the zero-cost-when-absent contract), span names are
  ``<subsystem>.<signal>`` and metric names ``<subsystem>/<signal>``
  (docs/observability.md grammar).
- ``prng-reuse`` — a PRNG key fed to two ``jax.random.*`` consumers
  without an intervening ``split``/``fold_in`` silently correlates the
  two draws.

Rules are pure AST passes: no imports of the linted code, so a broken
module still lints. Findings are suppressed per line with
``# repro: ignore[rule-id]`` (:mod:`repro.analysis.findings`).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.findings import Finding, filter_suppressed

REPO_ROOT = Path(__file__).resolve().parents[3]
SRC_ROOT = REPO_ROOT / "src"


@dataclass(frozen=True)
class LintConfig:
    """Paths are matched as substrings of the repo-relative posix path."""
    # modules whose loops interleave with device dispatch (rule host-sync)
    hot_paths: Sequence[str] = ("train/trainer.py", "serving/engine.py",
                                "kernels/")
    # the one module allowed to touch jax's shard_map directly
    compat_paths: Sequence[str] = ("core/compat.py",)


DEFAULT_CONFIG = LintConfig()


def _matches(relpath: str, patterns: Sequence[str]) -> bool:
    return any(p in relpath for p in patterns)


# ---------------------------------------------------------------------------
# rule: shard-map-import
# ---------------------------------------------------------------------------


def rule_shard_map_import(tree: ast.AST, relpath: str,
                          cfg: LintConfig) -> List[Finding]:
    if _matches(relpath, cfg.compat_paths):
        return []
    out = []
    msg = ("raw shard_map import — route through core/compat.py "
           "(version shim for the namespace/kwarg moves)")
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            names = {a.name for a in node.names}
            if mod.startswith("jax") and ("shard_map" in mod
                                          or "shard_map" in names):
                out.append(Finding(relpath, node.lineno,
                                   "shard-map-import", msg))
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith("jax") and "shard_map" in a.name:
                    out.append(Finding(relpath, node.lineno,
                                       "shard-map-import", msg))
    return out


# ---------------------------------------------------------------------------
# rule: host-sync
# ---------------------------------------------------------------------------

_HOST_FETCHERS = {"device_get"}          # jax.device_get(...)


def _scope_walk(fn: ast.AST) -> Iterable[ast.AST]:
    """Nodes of a function's own scope — nested def/class bodies excluded
    (they are linted as their own scopes); lambdas stay in the enclosing
    scope."""
    stack = list(fn.body)  # type: ignore[attr-defined]
    while stack:
        n = stack.pop()
        yield n
        for c in ast.iter_child_nodes(n):
            if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                continue
            stack.append(c)


def _subscript_base(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
        return node.value.id
    return None


def _assigned_names(target: ast.AST) -> Iterable[str]:
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _assigned_names(elt)


def rule_host_sync(tree: ast.AST, relpath: str,
                   cfg: LintConfig) -> List[Finding]:
    if not _matches(relpath, cfg.hot_paths):
        return []
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        host_names: Set[str] = set()     # fetched once via jax.device_get
        conversions: Dict[str, List[ast.AST]] = {}
        for node in _scope_walk(fn):
            if isinstance(node, ast.Assign):
                v = node.value
                if (isinstance(v, ast.Call)
                        and isinstance(v.func, ast.Attribute)
                        and v.func.attr in _HOST_FETCHERS):
                    for t in node.targets:
                        host_names.update(_assigned_names(t))
                continue
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            # any .item() is a per-element device sync — never on a hot path
            if isinstance(f, ast.Attribute) and f.attr == "item" \
                    and not node.args:
                out.append(Finding(
                    relpath, node.lineno, "host-sync",
                    ".item() forces a device sync on a hot path — batch "
                    "the fetch with jax.device_get"))
                continue
            # float(m["x"]) / int(m["x"]) / np.asarray(m["x"]) — group by m
            base = None
            if isinstance(f, ast.Name) and f.id in ("float", "int") \
                    and len(node.args) == 1:
                base = _subscript_base(node.args[0])
            elif (isinstance(f, ast.Attribute)
                  and f.attr in ("asarray", "array")
                  and isinstance(f.value, ast.Name)
                  and f.value.id in ("np", "numpy") and node.args):
                base = _subscript_base(node.args[0])
            if base is not None:
                conversions.setdefault(base, []).append(node)
        for name, sites in conversions.items():
            if len(sites) < 2 or name in host_names:
                continue
            for site in sorted(sites, key=lambda n: (n.lineno,
                                                     n.col_offset))[1:]:
                out.append(Finding(
                    relpath, site.lineno, "host-sync",
                    f"{len(sites)} separate host syncs on '{name}' in one "
                    f"scope — fetch the pytree once with jax.device_get "
                    f"and read floats from the host copy"))
    return out


# ---------------------------------------------------------------------------
# rule: obs-contract
# ---------------------------------------------------------------------------

SPAN_NAME_RE = re.compile(r"^[a-z0-9_]+(\.[a-z0-9_]+)+$")
METRIC_NAME_RE = re.compile(r"^[a-z0-9_]+(/[a-z0-9_]+)+$")
_METRIC_METHODS = {"observe", "set", "inc"}


def _arg_default(fn: ast.AST, name: str):
    """(found, default_node_or_None_if_missing) for a parameter by name."""
    a = fn.args  # type: ignore[attr-defined]
    pos = list(a.posonlyargs) + list(a.args)
    n_def = len(a.defaults)
    for i, arg in enumerate(pos):
        if arg.arg == name:
            j = i - (len(pos) - n_def)
            return True, (a.defaults[j] if j >= 0 else None)
    for i, arg in enumerate(a.kwonlyargs):
        if arg.arg == name:
            return True, a.kw_defaults[i]
    return False, None


def rule_obs_contract(tree: ast.AST, relpath: str,
                      cfg: LintConfig) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            found, default = _arg_default(node, "obs")
            if found and not (isinstance(default, ast.Constant)
                              and default.value is None):
                out.append(Finding(
                    relpath, node.lineno, "obs-contract",
                    f"'{node.name}' takes obs= but does not default it to "
                    f"None — call sites must stay zero-cost un-observed"))
            continue
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute) and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            continue
        name = node.args[0].value
        if node.func.attr == "span" and not SPAN_NAME_RE.match(name):
            out.append(Finding(
                relpath, node.lineno, "obs-contract",
                f"span name '{name}' violates the <subsystem>.<signal> "
                f"grammar (docs/observability.md)"))
        elif node.func.attr in _METRIC_METHODS \
                and not METRIC_NAME_RE.match(name):
            out.append(Finding(
                relpath, node.lineno, "obs-contract",
                f"metric name '{name}' violates the <subsystem>/<signal> "
                f"grammar (docs/observability.md)"))
    return out


# ---------------------------------------------------------------------------
# rule: prng-reuse
# ---------------------------------------------------------------------------

# jax.random functions that derive keys rather than consuming them
_KEY_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "key_data",
                 "wrap_key_data", "clone"}
_RANDOM_ALIASES = {"jrandom", "jr"}      # `from jax import random as jrandom`


def _consumed_key_name(call: ast.Call) -> Optional[str]:
    """Bare-Name key passed to a consuming jax.random.* call, else None."""
    f = call.func
    if not isinstance(f, ast.Attribute) or f.attr in _KEY_DERIVERS:
        return None
    base = f.value
    is_jax_random = (
        (isinstance(base, ast.Attribute) and base.attr == "random"
         and isinstance(base.value, ast.Name) and base.value.id == "jax")
        or (isinstance(base, ast.Name) and base.id in _RANDOM_ALIASES))
    if not is_jax_random:
        return None
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    return None


def rule_prng_reuse(tree: ast.AST, relpath: str,
                    cfg: LintConfig) -> List[Finding]:
    out = []
    seen: Set = set()            # dedup loop second-pass findings

    def visit_stmt(st: ast.stmt, state: Dict[str, int]) -> None:
        for call in sorted(
                (n for n in ast.walk(st) if isinstance(n, ast.Call)),
                key=lambda n: (n.lineno, n.col_offset)):
            name = _consumed_key_name(call)
            if name is None:
                continue
            if state.get(name, 0) >= 1:
                key = (relpath, call.lineno, name)
                if key not in seen:
                    seen.add(key)
                    out.append(Finding(
                        relpath, call.lineno, "prng-reuse",
                        f"key '{name}' already consumed by a jax.random "
                        f"call on this path — split or fold_in first"))
            state[name] = state.get(name, 0) + 1
        if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = st.targets if isinstance(st, ast.Assign) \
                else [st.target]
            for t in targets:
                for name in _assigned_names(t):
                    state[name] = 0      # rebound — fresh key

    def scan(stmts: Sequence[ast.stmt], state: Dict[str, int]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue                 # separate scope
            if isinstance(st, ast.If):
                s_then, s_else = dict(state), dict(state)
                scan(st.body, s_then)
                scan(st.orelse, s_else)
                for k in set(s_then) | set(s_else):
                    state[k] = max(s_then.get(k, 0), s_else.get(k, 0))
            elif isinstance(st, (ast.For, ast.While)):
                # two passes over the body: a key consumed once per
                # iteration without a rebind is cross-iteration reuse;
                # the loop TARGET rebinds every iteration (`for g, r in
                # zip(grads, rngs)` — each r is fresh)
                loop_targets = list(_assigned_names(st.target)) \
                    if isinstance(st, ast.For) else []
                for _ in range(2):
                    for name in loop_targets:
                        state[name] = 0
                    scan(st.body, state)
                scan(st.orelse, state)
            elif isinstance(st, ast.With):
                for item in st.items:
                    visit_stmt(ast.Expr(item.context_expr), state)
                scan(st.body, state)
            elif isinstance(st, ast.Try):
                scan(st.body, state)
                for h in st.handlers:
                    scan(h.body, dict(state))
                scan(st.orelse, state)
                scan(st.finalbody, state)
            else:
                visit_stmt(st, state)

    for fn in ast.walk(tree):
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan(fn.body, {})
    return out


# ---------------------------------------------------------------------------
# rule: axis-name-literal
# ---------------------------------------------------------------------------

# collective ops whose axis argument is the SECOND positional (value first)
_COLLECTIVES_ARG1 = {"psum", "pmean", "pmax", "pmin", "all_gather",
                     "psum_scatter", "all_to_all", "ppermute"}
# ops whose axis argument is the FIRST positional
_COLLECTIVES_ARG0 = {"axis_index"}


def _has_str_literal(node: ast.AST) -> bool:
    """A string constant, or a tuple/list literal containing one."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_has_str_literal(e) for e in node.elts)
    return False


def rule_axis_name_literal(tree: ast.AST, relpath: str,
                           cfg: LintConfig) -> List[Finding]:
    """Collective axis names must come from the ``launch.mesh`` constants
    (``POD_AXIS`` / ``DATA_AXIS`` / ``MODEL_AXIS``), not inline strings —
    a mesh-layout rename must be one edit, not a repo-wide grep. Applies to
    the axis argument of jax collectives (psum/pmean/all_gather/...), by
    position or as ``axis_name=``."""
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        attr = node.func.attr
        if attr in _COLLECTIVES_ARG1:
            pos = 1
        elif attr in _COLLECTIVES_ARG0:
            pos = 0
        else:
            continue
        axis_args = [kw.value for kw in node.keywords
                     if kw.arg == "axis_name"]
        if len(node.args) > pos:
            axis_args.append(node.args[pos])
        for a in axis_args:
            if _has_str_literal(a):
                out.append(Finding(
                    relpath, node.lineno, "axis-name-literal",
                    f"string-literal axis name in {attr}() — use the "
                    f"repro.launch.mesh axis constants (POD_AXIS / "
                    f"DATA_AXIS / MODEL_AXIS)"))
    return out


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

RuleFn = Callable[[ast.AST, str, LintConfig], List[Finding]]

RULES: Dict[str, RuleFn] = {
    "shard-map-import": rule_shard_map_import,
    "host-sync": rule_host_sync,
    "obs-contract": rule_obs_contract,
    "prng-reuse": rule_prng_reuse,
    "axis-name-literal": rule_axis_name_literal,
}

CATALOG: Dict[str, str] = {
    "shard-map-import": "shard_map imported outside core/compat.py",
    "host-sync": "per-metric device syncs / .item() on a hot path",
    "obs-contract": "obs= without None default, or span/metric name "
                    "off the naming grammar",
    "prng-reuse": "PRNG key consumed twice without split/fold_in",
    "axis-name-literal": "collective axis name spelled as a string literal "
                         "instead of a launch.mesh constant",
}


def lint_source(source: str, relpath: str,
                cfg: LintConfig = DEFAULT_CONFIG,
                rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one file's source; suppressions already applied."""
    tree = ast.parse(source, filename=relpath)
    findings: List[Finding] = []
    for rule_id in (rules or RULES):
        findings.extend(RULES[rule_id](tree, relpath, cfg))
    return filter_suppressed(findings, {relpath: source})


def lint_paths(paths: Iterable[Path], *, root: Path = REPO_ROOT,
               cfg: LintConfig = DEFAULT_CONFIG,
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for path in sorted(paths):
        rel = path.resolve().relative_to(root).as_posix() \
            if path.resolve().is_relative_to(root) else path.as_posix()
        findings.extend(lint_source(path.read_text(), rel, cfg,
                                    rules=rules))
    return findings


def run_repo_lint(cfg: LintConfig = DEFAULT_CONFIG) -> List[Finding]:
    """The repo gate: every lint rule over every module under ``src/``."""
    return lint_paths(SRC_ROOT.rglob("*.py"), root=REPO_ROOT, cfg=cfg)
