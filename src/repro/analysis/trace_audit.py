"""Layer 2: trace auditor — lower the hot jitted entry points and assert
tracing-level invariants that the AST lint cannot see.

For every entry in :data:`ENTRIES` (vision/LM train step, decode step,
fused prefill, paged flash-decode) the auditor builds reduced-size real
arguments, traces the function, and checks:

- **no host callbacks** (``trace-callback``): no ``*_callback`` /
  ``outside_call`` primitive anywhere in the jaxpr (recursing into scan /
  cond / custom-vjp sub-jaxprs). A stray ``jax.debug.print`` or
  ``pure_callback`` in a decode loop serialises every step on the host.
- **no f64 promotion** (``trace-f64``): no equation output carries
  ``float64``/``complex128``. With x64 disabled this is belt-and-braces;
  with it enabled (some debugging flows) a bare Python float in the wrong
  place silently doubles every buffer downstream.
- **donation actually aliased** (``trace-donation``): compile with the
  entry's ``donate_argnums`` and require one ``input_output_alias`` header
  entry per donated flat leaf (via
  :func:`repro.launch.hlo_analysis.parse_input_output_aliases`), with no
  "donated buffer unused" warnings. Donation that silently fails to alias
  doubles the optimizer-state working set — invisible until OOM.
- **recompile-hazard census** (``recompile-hazard``): each entry declares
  the static knobs that multiply its compile-cache entries
  (``use_kernels`` x sampling mode x ...); the declared variant product
  must stay within the entry's budget. New static axes must be accounted
  for here, which is the point.

Entries are lazy: each ``build()`` imports and constructs on demand, so
``python -m repro.analysis --lint`` never pays for model init.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding

_BAD_DTYPES = ("float64", "complex128")


@dataclass
class Built:
    """A concrete traceable entry: fn + reduced-size real args."""
    fn: Callable
    args: tuple
    donate_argnums: Tuple[int, ...] = ()


@dataclass
class Entry:
    name: str
    path: str                    # repo-relative source the finding points at
    build: Callable[[], Built]
    compile_check: bool = True   # False: jaxpr-only (Pallas entries — the
    #                              TPU kernel path doesn't XLA-compile here)
    static_knobs: dict = field(default_factory=dict)   # knob -> n variants
    variant_budget: int = 8


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(params: dict):
    """Yield every Jaxpr nested in an eqn's params (scan/cond/custom-vjp/
    pjit bodies), whatever key it hides under."""
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if hasattr(x, "jaxpr"):        # ClosedJaxpr
                yield x.jaxpr
            elif hasattr(x, "eqns"):       # raw Jaxpr
                yield x


def iter_eqns(jaxpr):
    """All equations in ``jaxpr``, recursing into nested sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def audit_jaxpr(fn: Callable, args: tuple, *, name: str, path: str
                ) -> List[Finding]:
    """Callback + f64 audit on the traced jaxpr of ``fn(*args)``."""
    out: List[Finding] = []
    closed = jax.make_jaxpr(fn)(*args)
    bad_dtypes = set()
    callbacks = set()
    for eqn in iter_eqns(closed.jaxpr):
        pname = eqn.primitive.name
        if "callback" in pname or "outside_call" in pname:
            callbacks.add(pname)
        for v in eqn.outvars:
            dt = str(getattr(v.aval, "dtype", ""))
            if dt in _BAD_DTYPES:
                bad_dtypes.add((pname, dt))
    for pname in sorted(callbacks):
        out.append(Finding(path, 0, "trace-callback",
                           f"{name}: host callback primitive '{pname}' "
                           "in the traced program"))
    for pname, dt in sorted(bad_dtypes):
        out.append(Finding(path, 0, "trace-f64",
                           f"{name}: '{pname}' produces {dt} — check for "
                           "accidental wide promotion"))
    return out


def audit_donation(fn: Callable, args: tuple,
                   donate_argnums: Sequence[int], *, name: str, path: str
                   ) -> List[Finding]:
    """Compile with donation and assert the alias header covers every
    donated flat leaf."""
    from repro.launch.hlo_analysis import parse_input_output_aliases
    out: List[Finding] = []
    n_leaves = sum(len(jax.tree.leaves(args[i])) for i in donate_argnums)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        compiled = jax.jit(fn, donate_argnums=tuple(donate_argnums)
                           ).lower(*args).compile()
    for w in caught:
        if "donat" in str(w.message).lower():
            out.append(Finding(path, 0, "trace-donation",
                               f"{name}: {w.message}"))
    aliases = parse_input_output_aliases(compiled.as_text())
    if len(aliases) < n_leaves:
        out.append(Finding(
            path, 0, "trace-donation",
            f"{name}: {n_leaves} donated leaves but only {len(aliases)} "
            "input_output_alias entries — donation not fully aliased"))
    return out


def audit_variants(entry: Entry) -> List[Finding]:
    n = math.prod(entry.static_knobs.values()) if entry.static_knobs else 1
    if n > entry.variant_budget:
        knobs = " x ".join(f"{k}:{v}" for k, v in entry.static_knobs.items())
        return [Finding(entry.path, 0, "recompile-hazard",
                        f"{entry.name}: {n} static-arg variants ({knobs}) "
                        f"> budget {entry.variant_budget}")]
    return []


# ---------------------------------------------------------------------------
# entry registry
# ---------------------------------------------------------------------------


def _vision_train_step() -> Built:
    from repro.configs.paper_models import F1_MNIST
    from repro.core import LargeBatchConfig, Regime
    from repro.models.cnn import model_fns
    from repro.optim import sgd
    from repro.train.trainer import make_vision_train_step

    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(32,), ghost_batch_size=8)
    lb = LargeBatchConfig(batch_size=16, base_batch_size=16,
                          ghost_batch_size=8)
    regime = Regime(base_lr=0.1, total_steps=4, drop_every=4)
    init_fn, apply_fn = model_fns(cfg)
    params, bn = init_fn(jax.random.PRNGKey(0), cfg)
    fn = make_vision_train_step(apply_fn, cfg, lb, regime)
    args = (params, bn, sgd.init(params),
            jnp.zeros((16, 8, 8, 1), jnp.float32),
            jnp.zeros((16,), jnp.int32), jnp.int32(0),
            jax.random.PRNGKey(1))
    return Built(fn, args, donate_argnums=(0, 1, 2))


def _lm_cfg():
    from repro.configs.registry import get_config
    return dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                               dtype="float32")


def _lm_train_step() -> Built:
    from repro.core import LargeBatchConfig, Regime
    from repro.models import transformer as T
    from repro.optim import sgd
    from repro.train.trainer import make_lm_train_step

    cfg = _lm_cfg()
    lb = LargeBatchConfig(batch_size=2, base_batch_size=2,
                          ghost_batch_size=2)
    regime = Regime(base_lr=0.05, total_steps=4, drop_every=4)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32)}
    fn = make_lm_train_step(cfg, lb, regime)
    args = (params, sgd.init(params), batch, jnp.int32(0),
            jax.random.PRNGKey(1))
    return Built(fn, args, donate_argnums=(0, 1))


def _decode_step() -> Built:
    from repro.models import transformer as T
    from repro.serving.engine import make_serve_step

    cfg = _lm_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, 2, 64, dtype=jnp.float32)
    fn = make_serve_step(cfg)
    args = (params, cache, jnp.zeros((2, 1), jnp.int32), jnp.int32(5))
    return Built(fn, args, donate_argnums=(1,))


def _prefill_fused() -> Built:
    from repro.models import transformer as T
    from repro.serving.engine import prefill_fused

    cfg = _lm_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, 2, 64, dtype=jnp.float32)

    def fn(params, cache, prompts):
        return prefill_fused(params, cfg, prompts, cache)

    args = (params, cache, jnp.zeros((2, 16), jnp.int32))
    return Built(fn, args, donate_argnums=(1,))


def _flash_decode_paged() -> Built:
    from repro.kernels import ops

    B, H, KV, hd = 2, 4, 2, 64
    page, n_pages, n_blocks = 16, 9, 4

    def fn(q, kp, vp, pt, pos):
        return ops.flash_decode_paged(q, kp, vp, pt, pos)

    args = (jnp.zeros((B, 1, H, hd), jnp.float32),
            jnp.zeros((n_pages, KV, page, hd), jnp.float32),
            jnp.zeros((n_pages, KV, page, hd), jnp.float32),
            jnp.zeros((B, n_blocks), jnp.int32),
            jnp.full((B,), 17, jnp.int32))
    return Built(fn, args)


def _flash_decode_paged_int8() -> Built:
    from repro.kernels import ops

    B, H, KV, hd = 2, 4, 2, 64
    page, n_pages, n_blocks = 16, 9, 4

    def fn(q, kp, vp, pt, pos, ks, vs):
        return ops.flash_decode_paged(q, kp, vp, pt, pos, k_scale=ks,
                                      v_scale=vs, rope_theta=1e4)

    args = (jnp.zeros((B, 1, H, hd), jnp.float32),
            jnp.zeros((n_pages, KV, page, hd), jnp.int8),
            jnp.zeros((n_pages, KV, page, hd), jnp.int8),
            jnp.zeros((B, n_blocks), jnp.int32),
            jnp.full((B,), 17, jnp.int32),
            jnp.ones((n_pages, KV, page), jnp.float32),
            jnp.ones((n_pages, KV, page), jnp.float32))
    return Built(fn, args)


def _decode_step_kernels() -> Built:
    from repro.models import transformer as T
    from repro.serving.engine import make_serve_step

    cfg = _lm_cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, 2, 64, dtype=jnp.float32, layout="head")
    fn = make_serve_step(cfg, use_kernels=True)
    args = (params, cache, jnp.zeros((2, 1), jnp.int32), jnp.int32(5))
    return Built(fn, args, donate_argnums=(1,))


ENTRIES: List[Entry] = [
    Entry("vision_train_step", "src/repro/train/trainer.py",
          _vision_train_step,
          static_knobs={"use_kernels": 2, "use_gbn": 2}),
    Entry("lm_train_step", "src/repro/train/trainer.py", _lm_train_step,
          static_knobs={"use_kernels": 2, "remat": 2, "seq_parallel": 2}),
    Entry("decode_step", "src/repro/serving/engine.py", _decode_step,
          static_knobs={"use_kernels": 2, "sampling": 2, "ragged": 2}),
    Entry("prefill_fused", "src/repro/serving/engine.py", _prefill_fused,
          static_knobs={"use_kernels": 2, "ragged": 2}),
    # Pallas kernel: jaxpr-only — the TPU kernel path is not XLA-compiled
    # on this backend, and the kernel takes no donated state.
    Entry("flash_decode_paged", "src/repro/kernels/ops.py",
          _flash_decode_paged, compile_check=False,
          static_knobs={"window": 2, "ragged": 2}),
    Entry("flash_decode_paged_int8", "src/repro/kernels/ops.py",
          _flash_decode_paged_int8, compile_check=False,
          static_knobs={"window": 2, "ragged": 2, "rope": 2}),
    # decode_step with the fused-kernel stack (fused RoPE q rotation,
    # rmsnorm+residual, SwiGLU) over a head-major cache. The sampling /
    # ragged axes are shared with the base decode_step entry; cache_dtype
    # covers the int8-paged serving variant.
    Entry("decode_step_kernels", "src/repro/serving/engine.py",
          _decode_step_kernels,
          static_knobs={"sampling": 2, "ragged": 2, "cache_dtype": 2}),
]


def run_trace_audit(entries: Optional[Sequence[Entry]] = None,
                    *, names: Optional[Sequence[str]] = None
                    ) -> List[Finding]:
    """Run every audit for every (selected) registry entry."""
    out: List[Finding] = []
    for entry in entries if entries is not None else ENTRIES:
        if names and entry.name not in names:
            continue
        out.extend(audit_variants(entry))
        b = entry.build()
        out.extend(audit_jaxpr(b.fn, b.args, name=entry.name,
                               path=entry.path))
        if entry.compile_check and b.donate_argnums:
            out.extend(audit_donation(b.fn, b.args, b.donate_argnums,
                                      name=entry.name, path=entry.path))
    return out
