"""Sharding-aware npz checkpointing.

Parameters/optimizer pytrees are flattened to path-keyed arrays; on restore
the arrays are placed back with the caller-provided shardings (device_put
with a NamedSharding reshards transparently).

Two save layouts:

- **consolidated** (the default): every leaf is gathered to a full numpy
  array on the saving host — fine single-process, where ``np.asarray`` on a
  sharded jax.Array is just a device_get.
- **sharded** (``save(..., sharded=True)``): each process writes ONLY its
  addressable shards to its own ``{kind}_{step}.shard{proc}.npz``, with the
  global index baked into each entry name — no gather, no cross-host
  traffic, and it works under a multi-process runtime where no single host
  can even address the full array. ``meta``/``latest`` are written by
  process 0 only (all hosts save the same step, so the pointer is shared).
  :func:`restore` finds shard files automatically and reassembles full
  arrays before placing them with the caller's shardings — which makes
  restore geometry-free: a checkpoint saved on a (2 data, 2 model) mesh
  restores onto (4, 1), a different process count, or a single device.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _index_tag(idx: Tuple[slice, ...], shape: Tuple[int, ...]) -> str:
    """Encode a shard's global index as ``start:stop`` per dim."""
    parts = []
    for sl, dim in zip(idx, shape):
        start = 0 if sl.start is None else sl.start
        stop = dim if sl.stop is None else sl.stop
        parts.append(f"{start}:{stop}")
    return ",".join(parts)


def _flatten_shards(tree: Any) -> Dict[str, np.ndarray]:
    """Path-keyed ADDRESSABLE shards: entry names are
    ``<leaf-path>##<start:stop,...>`` (deduped per distinct index, so
    replicated leaves cost one copy per file, not one per device)."""
    flat: Dict[str, np.ndarray] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        shards = getattr(leaf, "addressable_shards", None)
        if shards is None:                     # plain numpy / host scalar
            tag = _index_tag((slice(None),) * np.ndim(leaf), np.shape(leaf))
            flat[f"{key}##{tag}"] = np.asarray(leaf)
            continue
        for sh in shards:
            tag = _index_tag(sh.index, leaf.shape)
            name = f"{key}##{tag}"
            if name not in flat:
                flat[name] = np.asarray(sh.data)
    return flat


_KIND_PREFIX = {"params": "params", "opt": "opt", "state": "state"}


def save(path: str, step: int, params: Any, opt_state: Any = None,
         extra: Optional[Dict[str, Any]] = None,
         bn_state: Any = None, *, sharded: bool = False) -> None:
    os.makedirs(path, exist_ok=True)
    if sharded:
        proc = jax.process_index()
        suffix = f"_{step}.shard{proc}.npz"
        flatten = _flatten_shards
    else:
        proc = 0
        suffix = f"_{step}.npz"
        flatten = _flatten
    np.savez(os.path.join(path, "params" + suffix), **flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt" + suffix), **flatten(opt_state))
    if bn_state is not None:
        np.savez(os.path.join(path, "state" + suffix), **flatten(bn_state))
    if proc != 0:
        return
    meta = {"step": step, **(extra or {})}
    if sharded:
        meta["sharded"] = True
        meta["num_processes"] = jax.process_count()
    with open(os.path.join(path, f"meta_{step}.json"), "w") as f:
        json.dump(meta, f)
    # write the pointer last and atomically (temp + rename), so a kill at
    # any point mid-save leaves either the previous pointer or the new one
    # — never a truncated/partial "latest"
    tmp = os.path.join(path, "latest.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(path, "latest"))


def load_meta(path: str, step: Optional[int] = None) -> Dict[str, Any]:
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    with open(os.path.join(path, f"meta_{step}.json")) as f:
        return json.load(f)


def latest_step(path: str) -> Optional[int]:
    p = os.path.join(path, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def _assemble_sharded(files: List[str]) -> Dict[str, np.ndarray]:
    """Reassemble full arrays from per-process shard files. Every shard
    carries its global index in the entry name, so assembly is just
    "allocate max extent, paste each piece" — no mesh/topology knowledge."""
    pieces: Dict[str, List[Tuple[List[Tuple[int, int]], np.ndarray]]] = {}
    for fname in files:
        with np.load(fname) as data:
            for name in data.files:
                key, _, tag = name.partition("##")
                spans = [tuple(int(x) for x in p.split(":"))
                         for p in tag.split(",")] if tag else []
                pieces.setdefault(key, []).append((spans, data[name]))
    out: Dict[str, np.ndarray] = {}
    for key, parts in pieces.items():
        spans0, arr0 = parts[0]
        if not spans0:                                    # 0-d scalar
            out[key] = arr0
            continue
        shape = tuple(max(sp[d][1] for sp, _ in parts)
                      for d in range(len(spans0)))
        full = np.zeros(shape, dtype=arr0.dtype)
        for spans, piece in parts:
            full[tuple(slice(a, b) for a, b in spans)] = piece
        out[key] = full
    return out


def restore(path: str, template: Any, *, step: Optional[int] = None,
            kind: str = "params", shardings: Any = None) -> Tuple[Any, int]:
    """Restore a pytree shaped like ``template``. Returns (tree, step).

    Looks for the consolidated ``{kind}_{step}.npz`` first, then falls back
    to globbing ``{kind}_{step}.shard*.npz`` and reassembling — so the
    caller never needs to know which layout (or mesh geometry, or process
    count) produced the checkpoint.
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    fname = os.path.join(path, f"{_KIND_PREFIX[kind]}_{step}.npz")
    if os.path.exists(fname):
        data = dict(np.load(fname))
    else:
        shard_files = sorted(glob.glob(os.path.join(
            path, f"{_KIND_PREFIX[kind]}_{step}.shard*.npz")))
        if not shard_files:
            raise FileNotFoundError(fname)
        data = _assemble_sharded(shard_files)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in flat_t:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step
