"""Sharding-aware npz checkpointing.

Parameters/optimizer pytrees are flattened to path-keyed arrays; on restore
the arrays are placed back with the caller-provided shardings (device_put
with a NamedSharding reshards transparently)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


_KIND_PREFIX = {"params": "params", "opt": "opt", "state": "state"}


def save(path: str, step: int, params: Any, opt_state: Any = None,
         extra: Optional[Dict[str, Any]] = None,
         bn_state: Any = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, f"params_{step}.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, f"opt_{step}.npz"), **_flatten(opt_state))
    if bn_state is not None:
        np.savez(os.path.join(path, f"state_{step}.npz"),
                 **_flatten(bn_state))
    meta = {"step": step, **(extra or {})}
    with open(os.path.join(path, f"meta_{step}.json"), "w") as f:
        json.dump(meta, f)
    # write the pointer last and atomically (temp + rename), so a kill at
    # any point mid-save leaves either the previous pointer or the new one
    # — never a truncated/partial "latest"
    tmp = os.path.join(path, "latest.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(path, "latest"))


def load_meta(path: str, step: Optional[int] = None) -> Dict[str, Any]:
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    with open(os.path.join(path, f"meta_{step}.json")) as f:
        return json.load(f)


def latest_step(path: str) -> Optional[int]:
    p = os.path.join(path, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(path: str, template: Any, *, step: Optional[int] = None,
            kind: str = "params", shardings: Any = None) -> Tuple[Any, int]:
    """Restore a pytree shaped like ``template``. Returns (tree, step)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    fname = os.path.join(path, f"{_KIND_PREFIX[kind]}_{step}.npz")
    data = np.load(fname)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in flat_t:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step
