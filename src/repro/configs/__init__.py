from repro.configs.base import (EncoderConfig, InputShape, INPUT_SHAPES,
                                LayerSpec, ModelConfig, MoEConfig, NormConfig,
                                SSMConfig, VisionStubConfig, shape_applicable)

__all__ = [
    "EncoderConfig", "InputShape", "INPUT_SHAPES", "LayerSpec", "ModelConfig",
    "MoEConfig", "NormConfig", "SSMConfig", "VisionStubConfig",
    "shape_applicable",
]
