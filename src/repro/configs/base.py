"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig`` built from a
small algebra of layer specifications:

- ``LayerSpec`` describes one layer: its sequence mixer (full attention,
  sliding-window attention, mamba SSM, or none), its feed-forward kind
  (dense, MoE, or none) and whether a cross-attention sublayer precedes the
  self/sequence mixer (VLM / enc-dec decoder layers).
- A model is ``head_pattern`` + ``body_pattern * body_repeats`` +
  ``tail_pattern``.  The body is executed as a ``lax.scan`` over stacked
  parameters (one stack per body slot) so HLO size stays flat in depth.

The full production configs are exercised only through the dry-run
(ShapeDtypeStruct, no allocation); ``reduced()`` produces the CPU-smoke
variant of the same family (<=2 body repeats, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts feed-forward configuration."""

    n_experts: int
    top_k: int
    d_expert: int                 # per-expert hidden width
    n_shared_experts: int = 0     # always-on experts (Kimi/Qwen2-MoE style)
    d_shared: int = 0             # hidden width of the fused shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2   # load-balance auxiliary loss weight
    router_z_weight: float = 0.0      # router logit z-loss
    # "expert": shard the expert axis over the model axis (E % model == 0)
    # "ffn":    shard each expert's hidden dim instead (e.g. qwen2's 60 experts)
    shard_axis: str = "expert"

    def tokens_capacity(self, n_tokens: int) -> int:
        cap = int(self.capacity_factor * n_tokens * self.top_k / self.n_experts)
        return max(cap, self.top_k)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 style selective SSM configuration."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> d_model // 16

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else max(1, d_model // 16)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the network."""

    mixer: str = "attn"        # "attn" | "swa" | "ssm" | "none"
    ff: str = "dense"          # "dense" | "moe" | "none"
    cross_attn: bool = False   # prepend a cross-attention sublayer

    def __post_init__(self):
        assert self.mixer in ("attn", "swa", "ssm", "none"), self.mixer
        assert self.ff in ("dense", "moe", "none"), self.ff


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for encoder-decoder models (audio/seq2seq)."""

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    # the modality frontend is a STUB per assignment: input_specs() provides
    # precomputed frame embeddings of shape (B, frames(S), d_model).
    frame_ratio: int = 4  # encoder frames = seq_len // frame_ratio


@dataclass(frozen=True)
class VisionStubConfig:
    """Vision frontend stub: precomputed patch/projector embeddings."""

    n_image_tokens: int = 1600   # e.g. (448/14)^2 + specials, projector output
    d_embed: int = 0             # 0 -> d_model (already projected)


@dataclass(frozen=True)
class NormConfig:
    kind: str = "rmsnorm"   # "rmsnorm" | "layernorm" | "gbn"
    eps: float = 1e-6
    # GBN options (only used when kind == "gbn"; vision/MLP paper models)
    ghost_batch_size: int = 128
    momentum: float = 0.1


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # layer layout --------------------------------------------------------
    head_pattern: Tuple[LayerSpec, ...] = ()
    body_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    body_repeats: int = 1
    tail_pattern: Tuple[LayerSpec, ...] = ()

    # attention -----------------------------------------------------------
    rope_theta: float = 1e4
    sliding_window: int = 4096
    qk_norm: bool = False
    causal: bool = True

    # optional subsystems ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionStubConfig] = None

    norm: NormConfig = field(default_factory=NormConfig)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # sub-quadratic decode capability: archs whose decode step scales to 500k
    supports_long_context: bool = False
    citation: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.n_kv_heads == 0, (
            self.n_heads, self.n_kv_heads)

    # ------------------------------------------------------------------
    @property
    def layers(self) -> Tuple[LayerSpec, ...]:
        """Flat layer list (head + body*repeats + tail), in execution order."""
        return (tuple(self.head_pattern)
                + tuple(self.body_pattern) * self.body_repeats
                + tuple(self.tail_pattern))

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_attn_layers(self) -> int:
        return sum(1 for s in self.layers if s.mixer in ("attn", "swa"))

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so it shards evenly over 16-way model parallelism."""
        mult = 256
        return (self.vocab_size + mult - 1) // mult * mult

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic total parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.head_dim
        n = self.padded_vocab * d                       # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * d                  # unembedding
        for spec in self.layers:
            n += self._mixer_params(spec) + self._ff_params(spec) + 2 * d
        n += d                                          # final norm
        if self.encoder is not None:
            e = self.encoder
            per = (4 * e.d_model * e.n_heads * (e.d_model // e.n_heads)
                   + 3 * e.d_model * e.d_ff + 2 * e.d_model)
            n += e.n_layers * per + e.d_model
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        total = self.param_count()
        n_moe_layers = sum(1 for s in self.layers if s.ff == "moe")
        all_expert = n_moe_layers * m.n_experts * 3 * self.d_model * m.d_expert
        active_expert = n_moe_layers * m.top_k * 3 * self.d_model * m.d_expert
        return total - all_expert + active_expert

    def _mixer_params(self, spec: LayerSpec) -> int:
        d, hd = self.d_model, self.head_dim
        n = 0
        if spec.mixer in ("attn", "swa"):
            n += d * self.n_heads * hd              # q
            n += 2 * d * self.n_kv_heads * hd       # k, v
            n += self.n_heads * hd * d              # o
            if self.qk_norm:
                n += 2 * hd
        elif spec.mixer == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            dtr = s.resolved_dt_rank(d)
            n += d * 2 * di                          # in_proj (x, z)
            n += di * s.d_conv                       # conv
            n += di * (dtr + 2 * s.d_state)          # x_proj
            n += dtr * di + di                       # dt_proj
            n += di * s.d_state + di                 # A_log, D
            n += di * d                              # out_proj
        if spec.cross_attn:
            n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            n += self.n_heads * hd * d + d          # + extra norm
        return n

    def _ff_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        if spec.ff == "dense":
            return 3 * d * self.d_ff                 # swiglu: gate,up,down
        if spec.ff == "moe":
            m = self.moe
            n = m.n_experts * 3 * d * m.d_expert
            n += d * m.n_experts                     # router
            if m.n_shared_experts:
                n += 3 * d * m.d_shared
            return n
        return 0

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 body repeats,
        d_model<=512, <=4 experts, small vocab."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, 2))
        head_dim = d_model // n_heads
        kw = dict(
            name=self.name + "-reduced",
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_pattern=self.head_pattern[:1],
            body_pattern=self.body_pattern,
            body_repeats=min(self.body_repeats, 2) if len(self.body_pattern) <= 4
            else 1,
            tail_pattern=self.tail_pattern[:1],
            sliding_window=min(self.sliding_window, 16),
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=min(self.moe.d_expert, 256),
                d_shared=min(self.moe.d_shared, 256) if self.moe.d_shared else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 8), dt_rank=8)
        if self.encoder is not None:
            kw["encoder"] = dataclasses.replace(
                self.encoder, n_layers=2, d_model=d_model, n_heads=n_heads,
                n_kv_heads=n_kv, d_ff=min(self.encoder.d_ff, 512))
        if self.vision is not None:
            kw["vision"] = dataclasses.replace(self.vision, n_image_tokens=16)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is runnable; returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: no sub-quadratic decode path "
                       "(see DESIGN.md §Decode-shape applicability)")
    return True, ""
