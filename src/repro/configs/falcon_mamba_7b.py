"""falcon-mamba-7b [ssm] — attention-free Mamba-1. [arXiv:2410.05355]

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16.
Mamba-1 blocks: the mixer *is* the FF (no separate MLP), d_inner = 2*d_model.
"""
from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    d_model=4096,
    n_heads=1,                 # attention-free; placeholders
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65_024,
    body_pattern=(LayerSpec(mixer="ssm", ff="none"),),
    body_repeats=64,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
    tie_embeddings=False,
    supports_long_context=True,   # O(1)/token recurrent decode
    citation="arXiv:2410.05355",
)
