"""gemma3-27b [dense] — 5:1 local:global attention, 128k. [hf:google/gemma-3-1b-pt]

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
Pattern: 5 sliding-window (1024) layers then 1 global layer, repeated;
62 = 6*10 + 2 leaves a 2-local tail.
"""
from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec(mixer="swa", ff="dense")
_GLOBAL = LayerSpec(mixer="attn", ff="dense")

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    body_pattern=(_LOCAL,) * 5 + (_GLOBAL,),
    body_repeats=10,
    tail_pattern=(_LOCAL, _LOCAL),
    sliding_window=1024,
    rope_theta=1e6,
    qk_norm=True,
    # locals keep 1024-token caches; globals keep the full cache but decode
    # attention is a linear matvec — long_500k runs (DESIGN.md §Decode-shape).
    supports_long_context=True,
    citation="hf:google/gemma-3-1b-pt",
)
