"""h2o-danube-3-4b [dense] — llama+mistral mix, sliding-window attention. [arXiv:2401.16818]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32_000,
    body_pattern=(LayerSpec(mixer="swa", ff="dense"),),
    body_repeats=24,
    sliding_window=4096,
    rope_theta=5e5,
    supports_long_context=True,    # SWA: decode cache bounded by the window
    citation="arXiv:2401.16818",
)
