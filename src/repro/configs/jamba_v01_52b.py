"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave + MoE. [arXiv:2403.19887]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Jamba period-8 block: attention at offset 4, MoE on every other layer.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig, SSMConfig

_M_D = LayerSpec(mixer="ssm", ff="dense")   # mamba + dense MLP
_M_E = LayerSpec(mixer="ssm", ff="moe")     # mamba + MoE
_A_D = LayerSpec(mixer="attn", ff="dense")  # attention + dense MLP

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65_536,
    # 1 attention : 7 mamba per 8 layers; MoE every second layer
    body_pattern=(_M_D, _M_E, _M_D, _M_E, _A_D, _M_E, _M_D, _M_E),
    body_repeats=4,
    moe=MoEConfig(
        n_experts=16,
        top_k=2,
        d_expert=14336,
        capacity_factor=1.25,
        shard_axis="expert",   # 16 % 16 == 0
    ),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
    supports_long_context=True,   # hybrid: 4 attn layers keep caches, 28 are O(1)
    citation="arXiv:2403.19887",
)
