"""kimi-k2-1t-a32b [moe] — Kimi K2, trillion-param MoE. [arXiv:2501.kimi2]

61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840,
MoE 384 experts top-8, 1 shared expert, first layer dense.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,              # 7168 / 64
    d_ff=18432,                # dense first layer (K2 model card)
    vocab_size=163_840,
    # layer 0 is dense (DeepSeek-V3-style), remaining 60 layers are MoE
    head_pattern=(LayerSpec(mixer="attn", ff="dense"),),
    body_pattern=(LayerSpec(mixer="attn", ff="moe"),),
    body_repeats=60,
    moe=MoEConfig(
        n_experts=384,
        top_k=8,
        d_expert=2048,
        n_shared_experts=1,
        d_shared=2048,
        capacity_factor=1.25,
        shard_axis="expert",   # 384 % 16 == 0
    ),
    rope_theta=5e6,
    supports_long_context=False,   # full attention: long_500k skipped
    citation="arXiv:2501.kimi2 (paper-table)",
)
