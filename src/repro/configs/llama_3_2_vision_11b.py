"""llama-3.2-vision-11b [vlm] — cross-attn image layers. [hf:meta-llama/Llama-3.2-11B-Vision]

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
Every 5th layer carries a cross-attention sublayer into the (stubbed) vision
embeddings; the ViT + projector frontend is a STUB per the assignment —
input_specs() provides precomputed projected patch embeddings
(B, n_image_tokens, d_model).
"""
from repro.configs.base import LayerSpec, ModelConfig, VisionStubConfig

_X = LayerSpec(mixer="attn", ff="dense", cross_attn=True)
_S = LayerSpec(mixer="attn", ff="dense")

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    # 8 cross-attention layers interleaved into 40 decoder layers
    body_pattern=(_X, _S, _S, _S, _S),
    body_repeats=8,
    vision=VisionStubConfig(n_image_tokens=1600),
    rope_theta=5e5,
    supports_long_context=False,   # full attention: long_500k skipped
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
)
