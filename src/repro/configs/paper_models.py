"""The paper's own experimental models (Table 1 / Table 2).

These are the vision/MLP models on which the generalization-gap experiments
run — they carry Batch Normalization, so they are the models that exercise
Ghost Batch Normalization end-to-end. Per the "implement the baseline too"
rule, we implement the representative set: F1 (MNIST MLP), C1/C3 (shallow
convnets), and a ResNet44-style residual CNN. All are built from
``repro.models.mlp`` / ``repro.models.cnn``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class VisionModelConfig:
    name: str
    kind: str                       # "mlp" | "convnet" | "resnet"
    input_shape: Tuple[int, int, int]   # (H, W, C)
    n_classes: int
    # mlp
    hidden_sizes: Tuple[int, ...] = ()
    # convnet / resnet
    channels: Tuple[int, ...] = ()
    blocks_per_stage: int = 0       # resnet: n per stage (44 = 3*2*7 + 2)
    norm: str = "gbn"               # "gbn" | "batchnorm" | "none"
    ghost_batch_size: int = 128
    bn_momentum: float = 0.1
    citation: str = ""


# F1 (Keskar et al. 2017): fully-connected MNIST net.
F1_MNIST = VisionModelConfig(
    name="f1-mnist",
    kind="mlp",
    input_shape=(28, 28, 1),
    n_classes=10,
    hidden_sizes=(512, 512, 512, 512),
    citation="Keskar et al. 2017 (F1); Hoffer et al. 2017 Table 1",
)

# C1 (Keskar et al. 2017): shallow convnet for CIFAR-10.
C1_CIFAR10 = VisionModelConfig(
    name="c1-cifar10",
    kind="convnet",
    input_shape=(32, 32, 3),
    n_classes=10,
    channels=(64, 128, 256),
    citation="Keskar et al. 2017 (C1); Hoffer et al. 2017 Table 1",
)

# C3 (Keskar et al. 2017): deeper convnet for CIFAR-100.
C3_CIFAR100 = VisionModelConfig(
    name="c3-cifar100",
    kind="convnet",
    input_shape=(32, 32, 3),
    n_classes=100,
    channels=(64, 128, 256, 512),
    citation="Keskar et al. 2017 (C3); Hoffer et al. 2017 Table 1",
)

# ResNet44 (He et al. 2016) — the paper's main topology.
RESNET44_CIFAR10 = VisionModelConfig(
    name="resnet44-cifar10",
    kind="resnet",
    input_shape=(32, 32, 3),
    n_classes=10,
    channels=(16, 32, 64),
    blocks_per_stage=7,            # 6*7 + 2 = 44 layers
    citation="He et al. 2016; Hoffer et al. 2017 Table 1",
)

# WResnet16-4 style (Zagoruyko 2016) for CIFAR-100.
WRESNET16_CIFAR100 = VisionModelConfig(
    name="wresnet16-4-cifar100",
    kind="resnet",
    input_shape=(32, 32, 3),
    n_classes=100,
    channels=(64, 128, 256),
    blocks_per_stage=2,            # 6*2 + 4 ~ 16 layers, 4x width
    citation="Zagoruyko 2016; Hoffer et al. 2017 Table 1",
)

PAPER_MODELS = {
    m.name: m
    for m in (F1_MNIST, C1_CIFAR10, C3_CIFAR100, RESNET44_CIFAR10,
              WRESNET16_CIFAR100)
}
