"""phi3-medium-14b [dense] — RoPE SwiGLU GQA. [arXiv:2404.14219]

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100_352,
    body_pattern=(LayerSpec(mixer="attn", ff="dense"),),
    body_repeats=40,
    rope_theta=1e4,
    supports_long_context=False,   # full attention: long_500k skipped
    citation="arXiv:2404.14219",
)
