"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4. [hf:Qwen/Qwen1.5-MoE-A2.7B]

24L d_model=2048 16H (GQA kv=16) d_ff=1408(expert) vocab=151936, MoE 60e top-4.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=5632,                 # dense-equivalent width (unused: all layers MoE)
    vocab_size=151_936,
    body_pattern=(LayerSpec(mixer="attn", ff="moe"),),
    body_repeats=24,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        d_expert=1408,
        n_shared_experts=4,
        d_shared=5632,          # 4 shared experts fused: 4 * 1408
        capacity_factor=1.25,
        shard_axis="ffn",       # 60 % 16 != 0 -> shard each expert's hidden dim
    ),
    rope_theta=1e6,
    supports_long_context=False,   # full attention: long_500k skipped
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
