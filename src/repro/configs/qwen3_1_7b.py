"""qwen3-1.7b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B]

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151_936,
    body_pattern=(LayerSpec(mixer="attn", ff="dense"),),
    body_repeats=28,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    supports_long_context=False,   # full attention: long_500k skipped
    citation="hf:Qwen/Qwen3-8B",
)
