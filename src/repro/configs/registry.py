"""Architecture registry: ``--arch <id>`` resolution.

Maps the assigned (dashed) architecture ids to their ModelConfig.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                shape_applicable)
from repro.configs import (falcon_mamba_7b, gemma3_27b, h2o_danube_3_4b,
                           jamba_v01_52b, kimi_k2_1t_a32b,
                           llama_3_2_vision_11b, phi3_medium_14b,
                           qwen2_moe_a27b, qwen3_1_7b, seamless_m4t_large_v2)
from repro.configs.paper_models import PAPER_MODELS

_ARCHS: Dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in (
        kimi_k2_1t_a32b.CONFIG,
        falcon_mamba_7b.CONFIG,
        gemma3_27b.CONFIG,
        jamba_v01_52b.CONFIG,
        seamless_m4t_large_v2.CONFIG,
        qwen2_moe_a27b.CONFIG,
        qwen3_1_7b.CONFIG,
        llama_3_2_vision_11b.CONFIG,
        phi3_medium_14b.CONFIG,
        h2o_danube_3_4b.CONFIG,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch.endswith("-reduced"):
        return get_config(arch[: -len("-reduced")]).reduced()
    if arch not in _ARCHS:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(_ARCHS)}")
    return _ARCHS[arch]


def list_archs() -> List[str]:
    return sorted(_ARCHS)


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def list_shapes() -> List[str]:
    return sorted(INPUT_SHAPES)


def combos(include_inapplicable: bool = False):
    """Yield (arch, shape, applicable, reason) for the 10x4 assignment grid."""
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_inapplicable:
                yield arch, sname, ok, reason


__all__ = [
    "get_config", "list_archs", "get_shape", "list_shapes", "combos",
    "PAPER_MODELS",
]
