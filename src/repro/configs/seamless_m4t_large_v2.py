"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal. [arXiv:2308.11596]

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
The speech frontend (mel-spectrogram + conformer feature extractor) is a STUB
per the assignment: input_specs() provides precomputed frame embeddings
(B, seq_len // frame_ratio, d_model). We implement the text decoder (24L,
self-attn + cross-attn) and a 24L transformer encoder over the stub frames.
"""
from repro.configs.base import EncoderConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    body_pattern=(LayerSpec(mixer="attn", ff="dense", cross_attn=True),),
    body_repeats=24,
    encoder=EncoderConfig(
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
        frame_ratio=4),
    rope_theta=1e4,
    supports_long_context=False,   # full-attention decoder: long_500k skipped
    citation="arXiv:2308.11596",
)
