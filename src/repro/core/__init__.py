"""The paper's contribution as composable JAX modules."""
from repro.core.clipping import clip_by_global_norm, global_norm
from repro.core.diffusion import (DiffusionTracker, fit_log_diffusion,
                                  fit_power_diffusion,
                                  random_potential_probe, weight_distance)
from repro.core.gbn import equal_weight_bn_apply, gbn_apply, gbn_init
from repro.core.large_batch import LargeBatchConfig, presets
from repro.core.lr_scaling import noise_sigma, scale_lr
from repro.core.metrics import MetricsLogger
from repro.core.noise import ghost_noise_grads, multiplicative_noise_grads
from repro.core.regime import (BatchSchedule, Regime, adapt_regime,
                               batch_size_increase, epochs_to_steps)

__all__ = [
    "clip_by_global_norm", "global_norm", "DiffusionTracker",
    "fit_log_diffusion", "fit_power_diffusion", "random_potential_probe",
    "weight_distance", "equal_weight_bn_apply", "gbn_apply", "gbn_init",
    "LargeBatchConfig", "presets", "noise_sigma", "scale_lr",
    "MetricsLogger",
    "ghost_noise_grads", "multiplicative_noise_grads", "Regime",
    "BatchSchedule", "adapt_regime", "batch_size_increase",
    "epochs_to_steps",
]
