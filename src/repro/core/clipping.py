"""Gradient clipping (paper §4: "for the first few iterations, we had to clip
or normalize the gradients to prevent divergence")."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float, *,
                        norm: Optional[jax.Array] = None
                        ) -> Tuple[Any, jax.Array]:
    """Returns (clipped grads, pre-clip global norm).

    ``norm`` overrides the local computation — the sharded train step
    (:mod:`repro.train.parallel`) passes the collective-corrected global
    norm, since leaves sharded over the model axis contribute only their
    local slice here."""
    if norm is None:
        norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm
