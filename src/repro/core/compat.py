"""Version-compat shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` (jax 0.4.x) to a
top-level ``jax.shard_map`` export, and its replication-check kwarg was
renamed ``check_rep`` -> ``check_vma`` along the way. Importing through this
module keeps every call site working on either side of the move — callers
pass whichever kwarg name they like and it is translated to what the
installed jax accepts.
"""
from __future__ import annotations

import inspect

try:                                   # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:                    # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """`shard_map(f, mesh=..., in_specs=..., out_specs=..., ...)` with the
    `check_vma` / `check_rep` kwarg translated for the installed jax."""
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, **kwargs)
