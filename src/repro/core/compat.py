"""Version-compat shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` (jax 0.4.x) to a
top-level ``jax.shard_map`` export, and its replication-check kwarg was
renamed ``check_rep`` -> ``check_vma`` along the way. Importing through this
module keeps every call site working on either side of the move — callers
pass whichever kwarg name they like and it is translated to what the
installed jax accepts.

``distributed_initialize`` is the one place the repo touches
``jax.distributed``: it drops ``None`` arguments (jax's auto-detection
kwargs changed defaults across 0.4.x) and is idempotent, so a launcher that
already initialized the runtime (SLURM plugin, test harness) composes with
library code that defensively calls it again.
"""
from __future__ import annotations

import inspect
from typing import Optional

try:                                   # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:                    # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """`shard_map(f, mesh=..., in_specs=..., out_specs=..., ...)` with the
    `check_vma` / `check_rep` kwarg translated for the installed jax."""
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, **kwargs)


_DIST_INITIALIZED = False


def distributed_initialize(coordinator_address: Optional[str] = None,
                           num_processes: Optional[int] = None,
                           process_id: Optional[int] = None) -> None:
    """Idempotent ``jax.distributed.initialize``.

    ``None`` arguments are dropped so jax's environment auto-detection
    applies; a second call (from this shim or from an external launcher
    that beat us to it) is a no-op instead of the RuntimeError jax raises
    on double initialization. Must run before any jax device use.
    """
    global _DIST_INITIALIZED
    if _DIST_INITIALIZED:
        return
    import jax
    kwargs = {"coordinator_address": coordinator_address,
              "num_processes": num_processes, "process_id": process_id}
    kwargs = {k: v for k, v in kwargs.items() if v is not None}
    try:
        jax.distributed.initialize(**kwargs)
    except RuntimeError as e:
        # jax: "jax.distributed.initialize should only be called once"
        if "once" not in str(e):
            raise
    _DIST_INITIALIZED = True


def process_index() -> int:
    """This host's index in the distributed runtime (0 single-process)."""
    import jax
    return jax.process_index()


def process_count() -> int:
    """Number of processes in the distributed runtime (1 single-process)."""
    import jax
    return jax.process_count()
