"""Ultra-slow diffusion instrumentation (paper §3, Figure 2, Appendix B).

The paper models the initial high-LR phase as a random walk on a random
potential with ``E||w_t - w_0||^2 ~ (log t)^(4/alpha)`` and finds alpha = 2
empirically, i.e. ``||w_t - w_0|| ~ log t``.

This module provides:
- weight-distance tracking against the initialization snapshot,
- a log-t regression (slope + R^2) to verify the ultra-slow diffusion law,
- the Appendix-B random-potential probe: sample w = w0 + z*v for random unit
  directions v, and check std(L(w) - L(w0)) grows ~ ||w - w0|| (alpha = 2).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clipping import global_norm


def weight_distance(params: Any, params0: Any) -> jax.Array:
    """Euclidean distance ||w - w0|| over the whole parameter pytree."""
    diff = jax.tree.map(lambda a, b: a.astype(jnp.float32)
                        - b.astype(jnp.float32), params, params0)
    return global_norm(diff)


def fit_log_diffusion(steps: Sequence[int], distances: Sequence[float],
                      burn_in: int = 1) -> Dict[str, float]:
    """Fit ``d(t) = slope * log(t) + intercept``; returns slope/intercept/R^2.

    A good fit (R^2 near 1, positive slope) over the initial high-LR phase is
    the paper's Figure-2 signature of ultra-slow diffusion with alpha = 2.
    """
    t = np.asarray(steps, dtype=np.float64)
    d = np.asarray(distances, dtype=np.float64)
    keep = t >= burn_in
    t, d = t[keep], d[keep]
    if t.size < 3:
        return {"slope": float("nan"), "intercept": float("nan"),
                "r2": float("nan")}
    x = np.log(t)
    A = np.stack([x, np.ones_like(x)], axis=1)
    (slope, intercept), res, *_ = np.linalg.lstsq(A, d, rcond=None)
    pred = A @ np.array([slope, intercept])
    ss_res = float(np.sum((d - pred) ** 2))
    ss_tot = float(np.sum((d - d.mean()) ** 2)) or 1e-12
    return {"slope": float(slope), "intercept": float(intercept),
            "r2": 1.0 - ss_res / ss_tot}


def fit_power_diffusion(steps: Sequence[int], distances: Sequence[float],
                        burn_in: int = 1) -> Dict[str, float]:
    """Fit standard diffusion d(t) = c * t^p (log-log regression) for
    comparison: flat-potential diffusion predicts p = 0.5; ultra-slow
    diffusion shows p << 0.5 with a worse fit than the log law."""
    t = np.asarray(steps, dtype=np.float64)
    d = np.asarray(distances, dtype=np.float64)
    keep = (t >= burn_in) & (d > 0)
    t, d = t[keep], d[keep]
    if t.size < 3:
        return {"power": float("nan"), "r2": float("nan")}
    x, y = np.log(t), np.log(d)
    A = np.stack([x, np.ones_like(x)], axis=1)
    (p, c), *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = A @ np.array([p, c])
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - y.mean()) ** 2)) or 1e-12
    return {"power": float(p), "r2": 1.0 - ss_res / ss_tot}


class DiffusionTracker:
    """Accumulates (step, ||w_t - w_0||) pairs during training.

    ``record`` only enqueues the distance computation on device and returns
    the (async) scalar array — it never blocks the dispatch loop on a host
    transfer. The host-side floats are materialized in one batched sync the
    first time ``distances`` is read (typically at fit/report time).
    """

    def __init__(self, params0: Any):
        # a real copy, not an alias: same-dtype astype is a no-op, and an
        # aliased w_0 would be deleted under it by donated train steps
        # (launch.train donates params into the jitted step)
        self.params0 = jax.tree.map(
            lambda a: jnp.array(a, dtype=jnp.float32, copy=True), params0)
        self.steps: List[int] = []
        self._pending: List[jax.Array] = []   # device scalars, not yet synced
        self._host: List[float] = []
        self._dist_fn = jax.jit(weight_distance)

    def record(self, step: int, params: Any) -> jax.Array:
        d = self._dist_fn(params, self.params0)
        self.steps.append(step)
        self._pending.append(d)
        return d

    @property
    def distances(self) -> List[float]:
        if self._pending:
            jax.block_until_ready(self._pending)      # one sync for the batch
            self._host.extend(float(d) for d in self._pending)
            self._pending.clear()
        return self._host

    def load(self, steps: Sequence[int], distances: Sequence[float]) -> None:
        """Restore a previously recorded series (checkpoint resume)."""
        _ = self.distances                            # flush pending first
        self.steps = list(steps)
        self._host = [float(d) for d in distances]

    def log_fit(self, burn_in: int = 1) -> Dict[str, float]:
        return fit_log_diffusion(self.steps, self.distances, burn_in)

    def power_fit(self, burn_in: int = 1) -> Dict[str, float]:
        return fit_power_diffusion(self.steps, self.distances, burn_in)


# ---------------------------------------------------------------------------
# Appendix-B probe: loss std vs weight distance on random rays
# ---------------------------------------------------------------------------


def random_potential_probe(loss_fn: Callable[[Any], jax.Array], params0: Any,
                           rng: jax.Array, *, n_samples: int = 200,
                           max_radius: float = 10.0, n_bins: int = 10
                           ) -> Dict[str, np.ndarray]:
    """Paper Appendix B: sample w = w0 + z*v (v random unit direction,
    z ~ U[0, c]); estimate std(L(w) - L(w0)) per distance bin. Under the
    alpha=2 random-potential model the std grows ~ linearly with distance."""
    leaves, treedef = jax.tree.flatten(
        jax.tree.map(lambda a: a.astype(jnp.float32), params0))
    l0 = float(loss_fn(params0))
    dists, dlosses = [], []
    for i in range(n_samples):
        r = jax.random.fold_in(rng, i)
        rd, rz = jax.random.split(r)
        dirs = [jax.random.normal(jax.random.fold_in(rd, j), l.shape)
                for j, l in enumerate(leaves)]
        nrm = float(jnp.sqrt(sum(jnp.sum(jnp.square(d)) for d in dirs)))
        z = float(jax.random.uniform(rz, (), minval=0.0, maxval=max_radius))
        new_leaves = [l + (z / nrm) * d for l, d in zip(leaves, dirs)]
        w = jax.tree.unflatten(treedef, new_leaves)
        dists.append(z)
        dlosses.append(float(loss_fn(w)) - l0)
    dists_a = np.asarray(dists)
    dl = np.asarray(dlosses)
    edges = np.linspace(0.0, max_radius, n_bins + 1)
    centers, stds = [], []
    for b in range(n_bins):
        m = (dists_a >= edges[b]) & (dists_a < edges[b + 1])
        if m.sum() >= 3:
            centers.append(0.5 * (edges[b] + edges[b + 1]))
            stds.append(float(np.sqrt(np.mean(dl[m] ** 2))))
    return {"distance": np.asarray(centers), "loss_std": np.asarray(stds)}
