"""Expert-parallel MoE dispatch via shard_map (the production path).

Global-view (pjit-auto) scatter/gather into an expert-sharded buffer makes
GSPMD materialise / all-reduce the full (B, E, C, d) dispatch tensor — for
kimi-k2 (384 experts) that is ~9 GiB *per layer per device* and tens of TB
of collective traffic per step (measured; see EXPERIMENTS.md §Perf).

The EP formulation exploits that at the MoE boundary the token activations
are data-sharded and *replicated over the model axis*: every model shard
already holds all tokens of its data row, so each shard

  1. masks the (token, k) assignments routed to its local E/msize experts,
  2. scatters them into its local (B_loc, E_loc, C, d) buffer,
  3. runs the local expert GEMMs,
  4. gathers + weights its partial outputs, and
  5. ``psum`` s partials over the model axis (one activation-sized
     all-reduce per layer — the same cost as a Megatron MLP block).

No all-to-all is needed in this replicated-activation layout; the psum IS
the combine. This mirrors device-local routing in deployed MoE systems (and
echoes the paper's own observation that per-device batch statistics — their
"ghost batches" — are the natural distributed unit).

Routing (top-k, capacity slots) happens OUTSIDE in the global view — it is
purely data-parallel bookkeeping.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.configs.base import MoEConfig

Params = Dict[str, Any]


def ep_applicable(m: MoEConfig, mesh, batch: int, batch_axis: int) -> bool:
    if mesh is None or "model" not in mesh.axis_names:
        return False
    if m.shard_axis != "expert":
        return False
    return m.n_experts % mesh.shape["model"] == 0


def _dp_axes(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def ep_dispatch_combine(params: Params, m: MoEConfig, x: jax.Array,
                        topi: jax.Array, topw: jax.Array, slot: jax.Array,
                        keep: jax.Array, C: int, mesh, *,
                        batch_axis: int = 0) -> jax.Array:
    """x: (B, S, d); topi/topw/slot/keep: (B, S, k). ``batch_axis`` marks
    which of the two leading dims carries the data-sharded batch (0 normally;
    1 for decode, where the batch was folded into the token axis)."""
    msize = mesh.shape["model"]
    E_loc = m.n_experts // msize
    dp = _dp_axes(mesh)
    nb = x.shape[batch_axis]
    dpsize = 1
    if dp is not None:
        for a in (dp if isinstance(dp, tuple) else (dp,)):
            dpsize *= mesh.shape[a]
    if nb % dpsize != 0:
        dp = None
    sp3 = [None, None, None]
    sp3[batch_axis] = dp
    tok_spec = P(*sp3)

    dt = x.dtype

    def local_fn(xb, tib, twb, slb, kpb, wg, wu, wd):
        midx = jax.lax.axis_index("model")
        lo = midx * E_loc
        local = (tib >= lo) & (tib < lo + E_loc) & kpb       # (Bl, S, k)
        Bl, S, k = tib.shape
        d = xb.shape[-1]
        e_loc = jnp.where(local, tib - lo, 0)
        s_idx = jnp.where(local, slb, 0)
        b_idx = jnp.broadcast_to(jnp.arange(Bl)[:, None], (Bl, S)).reshape(-1)
        # scatter one k-assignment at a time: peak extra memory is one
        # (Bl, S, d) masked copy, not the (Bl, S, k, d) broadcast.
        buf = jnp.zeros((Bl, E_loc, C, d), dtype=dt)
        for j in range(k):
            xj = xb * local[:, :, j, None].astype(dt)
            buf = buf.at[b_idx, e_loc[:, :, j].reshape(-1),
                         s_idx[:, :, j].reshape(-1)].add(
                xj.reshape(-1, d), mode="drop")
        g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg[0].astype(dt)))
        u = jnp.einsum("becd,edf->becf", buf, wu[0].astype(dt))
        y_buf = jnp.einsum("becf,efd->becd", g * u, wd[0].astype(dt))
        y = jnp.zeros((Bl, S, d), dtype=dt)
        for j in range(k):
            yj = y_buf[b_idx, e_loc[:, :, j].reshape(-1),
                       s_idx[:, :, j].reshape(-1)].reshape(Bl, S, d)
            y = y + yj * (twb[:, :, j].astype(dt)
                          * local[:, :, j].astype(dt))[..., None]
        return jax.lax.psum(y, "model")

    # expert weights carry a leading dummy axis so the sharded E dim stays
    # explicit: (1, E, d, f) sharded on dim1.
    wg = params["w_gate"][None]
    wu = params["w_up"][None]
    wd = params["w_down"][None]
    w_spec = P(None, "model", None, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, tok_spec, tok_spec,
                  w_spec, w_spec, w_spec),
        out_specs=tok_spec,
        check_vma=False)
    return fn(x, topi, topw, slot, keep, wg, wu, wd)
