"""Expert-parallel MoE dispatch via shard_map (the production path).

Global-view (pjit-auto) scatter/gather into an expert-sharded buffer makes
GSPMD materialise / all-reduce the full (B, E, C, d) dispatch tensor — for
kimi-k2 (384 experts) that is ~9 GiB *per layer per device* and tens of TB
of collective traffic per step (measured; see EXPERIMENTS.md §Perf).

The EP formulation exploits that at the MoE boundary the token activations
are data-sharded and *replicated over the model axis*: every model shard
already holds all tokens of its data row, so each shard

  1. masks the (token, k) assignments routed to its local E/msize experts,
  2. scatters them into its local (B_loc, E_loc, C, d) buffer,
  3. runs the local expert GEMMs,
  4. gathers + weights its partial outputs, and
  5. ``psum`` s partials over the model axis (one activation-sized
     all-reduce per layer — the same cost as a Megatron MLP block).

No all-to-all is needed in this replicated-activation layout; the psum IS
the combine. This mirrors device-local routing in deployed MoE systems (and
echoes the paper's own observation that per-device batch statistics — their
"ghost batches" — are the natural distributed unit).

Routing (top-k, capacity slots) happens OUTSIDE in the global view — it is
purely data-parallel bookkeeping.

Two entry points share the local math:

- :func:`ep_dispatch_combine` — the pjit-context path: a self-contained
  shard_map over the ambient mesh (global arrays in, global arrays out).
- :func:`ep_manual_combine` — the already-manual path: called INSIDE an
  enclosing shard_map region (the unified train step,
  :mod:`repro.train.parallel`), where the expert weights arrive pre-sliced
  and only the psum crosses the wire.

Differentiability: manual collectives do not transpose the way replicated
global math does, so the expert region is fenced by an adjoint pair —
:func:`region_in` (identity forward / psum backward) on every replicated
tensor entering the partial computation, and :func:`region_out` (psum
forward / identity backward) on the combine. With the fence, gradients of
both the sharded expert weights and every replicated upstream parameter
match the single-device step exactly (tested in tests/test_parallel_2d.py).
"""
from __future__ import annotations

from contextlib import contextmanager
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map
from repro.launch.mesh import MODEL_AXIS, dp_spec_entry

from repro.configs.base import MoEConfig

Params = Dict[str, Any]


def ep_applicable(m: MoEConfig, mesh, batch: int, batch_axis: int) -> bool:
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return False
    if m.shard_axis != "expert":
        return False
    return m.n_experts % mesh.shape[MODEL_AXIS] == 0


# ---------------------------------------------------------------------------
# manual-region context (set while tracing a shard_map body)
# ---------------------------------------------------------------------------

_MANUAL: List[Tuple[Optional[str], int, Tuple[str, ...]]] = []


@contextmanager
def manual_mode(model_axis: Optional[str], model_size: int = 1,
                dp: Tuple[str, ...] = ()):
    """Trace-time marker: "we are inside a shard_map region whose mesh has
    ``model_axis`` of ``model_size`` and data axes ``dp``". The MoE layer
    (:func:`repro.models.moe.moe_apply`) checks it to route dispatch through
    :func:`ep_manual_combine` instead of the pjit/global paths."""
    _MANUAL.append((model_axis, model_size, tuple(dp)))
    try:
        yield
    finally:
        _MANUAL.pop()


def manual_state() -> Optional[Tuple[Optional[str], int, Tuple[str, ...]]]:
    return _MANUAL[-1] if _MANUAL else None


def manual_shard_mode(m: MoEConfig, params: Params) -> Optional[str]:
    """How the expert weights handed to this manual region are sliced:
    "expert" (E/msize local experts), "ffn" (full E, d_expert/msize hidden),
    or None (replicated — caller should use the plain local path). Inferred
    from the actual leaf shapes so it always agrees with what the spec
    builder (:func:`repro.train.parallel.mesh_param_specs`) produced."""
    st = manual_state()
    if st is None or st[0] is None:
        return None
    msize = st[1]
    E_loc, _, f_loc = params["w_gate"].shape
    if E_loc * msize == m.n_experts:
        return "expert"
    if E_loc == m.n_experts and f_loc * msize == m.d_expert:
        return "ffn"
    return None


# ---------------------------------------------------------------------------
# adjoint fence around the partial-sum region
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def region_in(x: jax.Array, axis) -> jax.Array:
    """Identity forward / psum(``axis``) backward. Wraps every replicated
    differentiable tensor entering the expert-partial computation: each
    shard's cotangent covers only its local experts, and the psum restores
    the full (replicated) gradient."""
    return x


def _region_in_fwd(x, axis):
    return x, None


def _region_in_bwd(axis, _, g):
    return (jax.lax.psum(g, axis),)


region_in.defvjp(_region_in_fwd, _region_in_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def region_out(y: jax.Array, axis) -> jax.Array:
    """psum(``axis``) forward / identity backward — the combine. The output
    cotangent is replicated (downstream math is replicated over the model
    axis), and each shard's partial wants exactly that cotangent."""
    return jax.lax.psum(y, axis)


def _region_out_fwd(y, axis):
    return jax.lax.psum(y, axis), None


def _region_out_bwd(axis, _, g):
    return (g,)


region_out.defvjp(_region_out_fwd, _region_out_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def mean_in_fwd(x: jax.Array, axes) -> jax.Array:
    """pmean(``axes``) forward / identity backward.

    For batch-statistics losses that are NON-linear in per-shard means (the
    router load-balance loss ``E * sum_e f_e * P_e``): the forward pmean
    makes the loss value the global one, and the identity backward leaves
    each shard's per-token cotangent UNSCALED — so after the step's final
    gradient pmean over the dp axes, each token's contribution lands exactly
    once. (A plain pmean here would transpose into a second 1/n.)"""
    return jax.lax.pmean(x, axes)


def _mean_in_fwd_fwd(x, axes):
    return jax.lax.pmean(x, axes), None


def _mean_in_fwd_bwd(axes, _, g):
    return (g,)


mean_in_fwd.defvjp(_mean_in_fwd_fwd, _mean_in_fwd_bwd)


# ---------------------------------------------------------------------------
# the shared per-shard dispatch -> expert FF -> combine
# ---------------------------------------------------------------------------


def _local_combine(xb: jax.Array, tib: jax.Array, twb: jax.Array,
                   slb: jax.Array, kpb: jax.Array, wg: jax.Array,
                   wu: jax.Array, wd: jax.Array, *, m: MoEConfig, C: int,
                   axis: str, mode: str) -> jax.Array:
    """One shard's scatter -> expert SwiGLU -> gather -> psum combine.

    xb: (Bl, S, d) tokens (replicated over ``axis``); tib/twb/slb/kpb:
    (Bl, S, k) routing bookkeeping (likewise replicated); wg/wu/wd: the
    LOCAL expert-weight slice — (E/msize, d, f) in "expert" mode, or
    (E, d, f/msize) / (E, f/msize, d) in "ffn" mode.
    """
    dt = xb.dtype
    Bl, S, k = tib.shape
    d = xb.shape[-1]
    xb = region_in(xb, axis)
    twb = region_in(twb, axis)
    if mode == "expert":
        E_loc = wg.shape[0]
        lo = jax.lax.axis_index(axis) * E_loc
        local = (tib >= lo) & (tib < lo + E_loc) & kpb     # (Bl, S, k)
        e_loc = jnp.where(local, tib - lo, 0)
    else:                                                  # "ffn"
        E_loc = wg.shape[0]
        local = kpb
        e_loc = tib
    s_idx = jnp.where(local, slb, 0)
    b_idx = jnp.broadcast_to(jnp.arange(Bl)[:, None], (Bl, S)).reshape(-1)
    # scatter one k-assignment at a time: peak extra memory is one
    # (Bl, S, d) masked copy, not the (Bl, S, k, d) broadcast.
    buf = jnp.zeros((Bl, E_loc, C, d), dtype=dt)
    for j in range(k):
        xj = xb * local[:, :, j, None].astype(dt)
        buf = buf.at[b_idx, e_loc[:, :, j].reshape(-1),
                     s_idx[:, :, j].reshape(-1)].add(
            xj.reshape(-1, d), mode="drop")
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg.astype(dt)))
    u = jnp.einsum("becd,edf->becf", buf, wu.astype(dt))
    y_buf = jnp.einsum("becf,efd->becd", g * u, wd.astype(dt))
    y = jnp.zeros((Bl, S, d), dtype=dt)
    for j in range(k):
        yj = y_buf[b_idx, e_loc[:, :, j].reshape(-1),
                   s_idx[:, :, j].reshape(-1)].reshape(Bl, S, d)
        y = y + yj * (twb[:, :, j].astype(dt)
                      * local[:, :, j].astype(dt))[..., None]
    return region_out(y, axis)


def ep_manual_combine(params: Params, m: MoEConfig, x: jax.Array,
                      topi: jax.Array, topw: jax.Array, slot: jax.Array,
                      keep: jax.Array, C: int, *, axis: str,
                      mode: str) -> jax.Array:
    """Dispatch+combine for callers ALREADY inside a shard_map region: the
    expert weights in ``params`` are the local slices (see
    :func:`manual_shard_mode`), all token tensors are model-replicated, and
    the single collective is the combine psum over ``axis``."""
    return _local_combine(x, topi, topw, slot, keep, params["w_gate"],
                          params["w_up"], params["w_down"], m=m, C=C,
                          axis=axis, mode=mode)


def ep_dispatch_combine(params: Params, m: MoEConfig, x: jax.Array,
                        topi: jax.Array, topw: jax.Array, slot: jax.Array,
                        keep: jax.Array, C: int, mesh, *,
                        batch_axis: int = 0) -> jax.Array:
    """x: (B, S, d); topi/topw/slot/keep: (B, S, k). ``batch_axis`` marks
    which of the two leading dims carries the data-sharded batch (0 normally;
    1 for decode, where the batch was folded into the token axis)."""
    dp = dp_spec_entry(mesh)
    nb = x.shape[batch_axis]
    dpsize = 1
    if dp is not None:
        for a in (dp if isinstance(dp, tuple) else (dp,)):
            dpsize *= mesh.shape[a]
    if nb % dpsize != 0:
        dp = None
    sp3 = [None, None, None]
    sp3[batch_axis] = dp
    tok_spec = P(*sp3)

    def local_fn(xb, tib, twb, slb, kpb, wg, wu, wd):
        return _local_combine(xb, tib, twb, slb, kpb, wg[0], wu[0], wd[0],
                              m=m, C=C, axis=MODEL_AXIS, mode="expert")

    # expert weights carry a leading dummy axis so the sharded E dim stays
    # explicit: (1, E, d, f) sharded on dim1.
    wg = params["w_gate"][None]
    wu = params["w_up"][None]
    wd = params["w_down"][None]
    w_spec = P(None, MODEL_AXIS, None, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, tok_spec, tok_spec,
                  w_spec, w_spec, w_spec),
        out_specs=tok_spec,
        check_vma=False)
    return fn(x, topi, topw, slot, keep, wg, wu, wd)
