"""Ghost Batch Normalization (Hoffer et al. 2017, Algorithm 1).

The large batch ``B_L`` is scattered into virtual ("ghost") batches of size
``|B_S|``; normalization statistics are computed **per ghost batch** during
training, while inference uses the running (full-batch) statistics, exactly
as the paper prescribes ("it is important to use the full batch statistic
... for the inference phase").

Running statistics follow the paper's cascaded EMA:

    mu_run <- (1-eta)^G mu_run + sum_{i=1..G} (1-eta)^{G-i} eta mu_B^i

i.e. the ghost batches are absorbed *sequentially* (equivalent closed form),
NOT by weighting each ghost batch equally — the paper reports that the
equal-weight variant used by the commercial frameworks "worsen[s] the
generalization performance".

Layout convention: x has shape (batch, ...features); statistics are computed
over the batch axis *and* all non-channel feature axes (NHWC convs reduce
over N,H,W per channel). The batch axis must be divisible by the ghost size
(use `num_ghosts` semantics below).

The compute-heavy normalization is also available as a Pallas TPU kernel
(`repro.kernels.gbn` / `ops.gbn_forward`), validated against this reference.
The kernel path is fully differentiable (dedicated Pallas backward via
``jax.custom_vjp``), so ``use_kernels=True`` is safe under ``jax.grad`` —
including the leftover-rows tail below, which back-propagates through the
kernel's mu/var outputs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def gbn_init(n_features: int) -> Tuple[Params, Params]:
    """Returns (learnable params, running state)."""
    params = {
        "gamma": jnp.ones((n_features,), jnp.float32),
        "beta": jnp.zeros((n_features,), jnp.float32),
    }
    state = {
        "mu_run": jnp.zeros((n_features,), jnp.float32),
        "var_run": jnp.ones((n_features,), jnp.float32),
        "initialized": jnp.zeros((), jnp.bool_),
    }
    return params, state


def _ghost_stats(xg: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """xg: (G, ghost_rows, C) -> per-ghost mean/var (G, C)."""
    mu = jnp.mean(xg, axis=1)
    var = jnp.mean(jnp.square(xg - mu[:, None, :]), axis=1)
    return mu, var


def _cascaded_ema(run: jax.Array, per_ghost: jax.Array, eta: float) -> jax.Array:
    """Closed form of sequentially folding G ghost statistics into the EMA:
    run <- (1-eta)^G run + eta * sum_i (1-eta)^(G-1-i) stats_i."""
    G = per_ghost.shape[0]
    decay = (1.0 - eta) ** jnp.arange(G - 1, -1, -1, dtype=jnp.float32)
    return (1.0 - eta) ** G * run + eta * jnp.einsum(
        "g,gc->c", decay, per_ghost)


def gbn_apply(params: Params, state: Params, x: jax.Array, *,
              ghost_batch_size: int, eps: float = 1e-5,
              momentum: float = 0.1, training: bool = True,
              use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    """Apply GBN over x: (B, ..., C). Returns (y, new_state).

    During training, batch rows are scattered into G = B // ghost_batch_size
    ghost batches (B < ghost_batch_size uses a single ghost batch = plain BN,
    the small-batch limit the paper matches).
    """
    orig_shape = x.shape
    Bsz, C = x.shape[0], x.shape[-1]
    dt = x.dtype
    gamma = params["gamma"].astype(jnp.float32)
    beta = params["beta"].astype(jnp.float32)

    if not training:
        mu, var = state["mu_run"], state["var_run"]
        y = (x.astype(jnp.float32) - mu) * jax.lax.rsqrt(var + eps)
        return (y * gamma + beta).astype(dt), state

    gbs = min(ghost_batch_size, Bsz)
    G = Bsz // gbs
    rows = G * gbs
    # fold all non-channel feature dims into the row axis per ghost
    xg = x[:rows].astype(jnp.float32).reshape(G, gbs, -1, C).reshape(G, -1, C)

    if use_kernels:
        from repro.kernels import ops as kops
        y, mu, var = kops.gbn_forward(xg, gamma, beta, eps=eps)
    else:
        mu, var = _ghost_stats(xg)
        y = (xg - mu[:, None, :]) * jax.lax.rsqrt(var[:, None, :] + eps)
        y = y * gamma + beta

    y = y.reshape((rows,) + orig_shape[1:])
    if rows < Bsz:  # leftover rows normalized with the last ghost's stats
        tail = (x[rows:].astype(jnp.float32) - mu[-1]) \
            * jax.lax.rsqrt(var[-1] + eps) * gamma + beta
        y = jnp.concatenate([y, tail], axis=0)

    # paper's cascaded EMA (unbiased var for the running estimate)
    n = xg.shape[1]
    var_unbiased = var * (n / max(n - 1, 1))
    first = ~state["initialized"]
    mu_run = jnp.where(first, mu.mean(0),
                       _cascaded_ema(state["mu_run"], mu, momentum))
    var_run = jnp.where(first, var_unbiased.mean(0),
                        _cascaded_ema(state["var_run"], var_unbiased, momentum))
    new_state = {"mu_run": mu_run, "var_run": var_run,
                 "initialized": jnp.ones((), jnp.bool_)}
    return y.astype(dt), new_state


def equal_weight_bn_apply(params: Params, state: Params, x: jax.Array, *,
                          eps: float = 1e-5, momentum: float = 0.1,
                          training: bool = True) -> Tuple[jax.Array, Params]:
    """Conventional BatchNorm over the *full* batch with the equal-weight
    running update — the baseline GBN is compared against (what the paper
    calls the commercial-framework behaviour)."""
    dt = x.dtype
    gamma = params["gamma"].astype(jnp.float32)
    beta = params["beta"].astype(jnp.float32)
    if not training:
        mu, var = state["mu_run"], state["var_run"]
        y = (x.astype(jnp.float32) - mu) * jax.lax.rsqrt(var + eps)
        return (y * gamma + beta).astype(dt), state
    C = x.shape[-1]
    xf = x.astype(jnp.float32).reshape(-1, C)
    mu = xf.mean(0)
    var = jnp.mean(jnp.square(xf - mu), axis=0)
    y = (x.astype(jnp.float32) - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    n = xf.shape[0]
    var_u = var * (n / max(n - 1, 1))
    first = ~state["initialized"]
    mu_run = jnp.where(first, mu,
                       (1 - momentum) * state["mu_run"] + momentum * mu)
    var_run = jnp.where(first, var_u,
                        (1 - momentum) * state["var_run"] + momentum * var_u)
    return y.astype(dt), {"mu_run": mu_run, "var_run": var_run,
                          "initialized": jnp.ones((), jnp.bool_)}
