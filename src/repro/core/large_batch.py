"""LargeBatchConfig: the paper's complete large-batch recipe as one object.

Combines (paper §7's "simple set of remedies"):
  1. momentum SGD + gradient clipping + decreasing LR regime,
  2. LR scaled with batch size (sqrt by default),
  3. ghost batch normalization (for batch-normalized models) /
     ghost gradient noise (norm-independent twin, for RMSNorm LLMs),
  4. regime adaptation: enough high-LR updates (schedule stretched by
     |B_L| / |B_S|).

``presets()`` returns the exact method column-set of Table 1:
SB, LB, LB+LR, LB+LR+GBN, LB+LR+GBN+RA.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.lr_scaling import noise_sigma, scale_lr
from repro.core.regime import Regime, adapt_regime


@dataclass(frozen=True)
class LargeBatchConfig:
    batch_size: int
    base_batch_size: int = 128        # the paper's |B_S|
    lr_rule: str = "sqrt"             # "sqrt" | "linear" | "none"
    ghost_batch_size: int = 128       # GBN virtual batch (|B_S| in Alg. 1)
    use_gbn: bool = True              # only effective for BN-carrying models
    regime_adaptation: bool = True
    grad_clip: float = 1.0            # global-norm clip (paper §4)
    ghost_noise: float = 0.0          # base sigma for multiplicative noise
    momentum: float = 0.9
    nesterov: bool = False

    @property
    def batch_ratio(self) -> float:
        return self.batch_size / self.base_batch_size

    def effective_lr(self, base_lr: float) -> float:
        return scale_lr(base_lr, self.batch_size, self.base_batch_size,
                        self.lr_rule)

    def effective_noise_sigma(self) -> float:
        if self.ghost_noise <= 0:
            return 0.0
        return noise_sigma(self.batch_size, self.base_batch_size,
                           self.ghost_noise)

    def build_regime(self, small_batch_regime: Regime) -> Regime:
        return adapt_regime(small_batch_regime,
                            batch_size=self.batch_size,
                            base_batch_size=self.base_batch_size,
                            lr_rule=self.lr_rule,
                            regime_adaptation=self.regime_adaptation)


def presets(large_batch: int, small_batch: int = 128,
            ghost: int = 128) -> Dict[str, LargeBatchConfig]:
    """The Table-1 method columns."""
    return {
        # small-batch reference: no scaling needed, plain BN == GBN at B_S
        "SB": LargeBatchConfig(
            batch_size=small_batch, base_batch_size=small_batch,
            lr_rule="none", use_gbn=False, regime_adaptation=False,
            ghost_batch_size=ghost, grad_clip=0.0),
        # naive large batch (the gap-exhibiting baseline)
        "LB": LargeBatchConfig(
            batch_size=large_batch, base_batch_size=small_batch,
            lr_rule="none", use_gbn=False, regime_adaptation=False,
            ghost_batch_size=ghost, grad_clip=0.0),
        "LB+LR": LargeBatchConfig(
            batch_size=large_batch, base_batch_size=small_batch,
            lr_rule="sqrt", use_gbn=False, regime_adaptation=False,
            ghost_batch_size=ghost),
        "LB+LR+GBN": LargeBatchConfig(
            batch_size=large_batch, base_batch_size=small_batch,
            lr_rule="sqrt", use_gbn=True, regime_adaptation=False,
            ghost_batch_size=ghost),
        "LB+LR+GBN+RA": LargeBatchConfig(
            batch_size=large_batch, base_batch_size=small_batch,
            lr_rule="sqrt", use_gbn=True, regime_adaptation=True,
            ghost_batch_size=ghost),
    }
