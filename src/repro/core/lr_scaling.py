"""Learning-rate scaling rules for large-batch training.

The paper's rule (eq. 7): keep the update covariance
``cov(dw, dw) ~ eta^2 / M * (1/N sum g g^T)`` constant across batch size by

    eta_L = sqrt(|B_L| / |B_S|) * eta_S        (sqrt scaling)

The linear rule (Krizhevsky 2014; Goyal et al. 2017) is implemented as the
comparison baseline — the paper reports it "works less well on CIFAR10".
"""
from __future__ import annotations

import math


def scale_lr(base_lr: float, batch_size: int, base_batch_size: int,
             rule: str = "sqrt") -> float:
    """Scale ``base_lr`` (tuned for ``base_batch_size``) to ``batch_size``."""
    if batch_size <= 0 or base_batch_size <= 0:
        raise ValueError("batch sizes must be positive")
    ratio = batch_size / base_batch_size
    if rule == "sqrt":
        return base_lr * math.sqrt(ratio)
    if rule == "linear":
        return base_lr * ratio
    if rule == "none":
        return base_lr
    raise ValueError(f"unknown LR scaling rule {rule!r}")


def noise_sigma(batch_size: int, base_batch_size: int,
                base_sigma: float = 1.0) -> float:
    """Std of the multiplicative gradient noise z_n ~ N(1, sigma^2) that
    matches the small-batch increment covariance: sigma^2 ∝ M (paper §4)."""
    return base_sigma * math.sqrt(max(batch_size / base_batch_size - 1.0, 0.0))
