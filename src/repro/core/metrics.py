"""Compat shim: ``MetricsLogger`` lives in :mod:`repro.obs.metrics` now.

The (step, name, value) series store used to be implemented here, with
:mod:`repro.experiments.metrics` re-exporting it — two import paths, one of
which was one refactor away from forking. The single implementation is the
observability layer's (:class:`repro.obs.metrics.MetricsLogger`, which can
mirror into a :class:`repro.obs.metrics.Registry`); this module keeps the
historical ``repro.core.metrics`` import path working for the trainers and
existing tests.
"""
from repro.obs.metrics import MetricsLogger

__all__ = ["MetricsLogger"]
