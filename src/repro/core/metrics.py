"""Structured per-run scalar series.

``MetricsLogger`` is the uniform (step, name, value) store both training
loops log into, replacing their ad-hoc ``history`` dicts. It lives in
``core`` (below the trainers) so that :mod:`repro.train.trainer` can depend
on it without reaching up into the experiments subsystem;
:mod:`repro.experiments.metrics` re-exports it next to the sweep-level
``ResultsStore``.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, List, Sequence, Tuple


class MetricsLogger:
    """Append-only (step, name, value) scalar series for one run."""

    def __init__(self) -> None:
        self._steps: Dict[str, List[int]] = defaultdict(list)
        self._values: Dict[str, List[float]] = defaultdict(list)

    def log(self, step: int, **scalars: float) -> None:
        for name, value in scalars.items():
            self._steps[name].append(int(step))
            self._values[name].append(float(value))

    def set_series(self, name: str, steps: Sequence[int],
                   values: Sequence[float]) -> None:
        """Replace one series wholesale (used for device-batched series like
        the diffusion distances, which are synced once at the end rather
        than logged float-by-float)."""
        self._steps[name] = [int(s) for s in steps]
        self._values[name] = [float(v) for v in values]

    def names(self) -> List[str]:
        return sorted(name for name in self._steps if self._steps[name])

    def series(self, name: str) -> Tuple[List[int], List[float]]:
        # .get, not [..]: reading a missing series must not create a
        # phantom empty one that would leak into to_json()/records
        return (list(self._steps.get(name, ())),
                list(self._values.get(name, ())))

    def last(self, name: str, default: float = float("nan")) -> float:
        vals = self._values.get(name)
        return vals[-1] if vals else default

    def max(self, name: str, default: float = 0.0) -> float:
        vals = self._values.get(name)
        return max(vals) if vals else default

    def to_json(self) -> Dict[str, Any]:
        return {name: [self._steps[name], self._values[name]]
                for name in self._steps if self._steps[name]}

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "MetricsLogger":
        lg = cls()
        for name, (steps, values) in obj.items():
            lg._steps[name] = [int(s) for s in steps]
            lg._values[name] = [float(v) for v in values]
        return lg

    def to_history(self) -> Dict[str, List[float]]:
        """The legacy ``train_vision`` history-dict view."""
        val_steps, val_acc = self.series("val_acc")
        _, train_loss = self.series("train_loss")
        dist_steps, distance = self.series("distance")
        return {"steps": val_steps, "val_acc": val_acc,
                "train_loss": train_loss,
                "dist_steps": dist_steps, "distance": distance}
