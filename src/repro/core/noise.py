"""Multiplicative gradient noise (paper §4).

The alternative to LR scaling that matches both the first *and* second
moments of the small-batch weight increments:

    g_hat = 1/M sum_n g_n z_n,   z_n ~ N(1, sigma^2),  sigma^2 ∝ M

Computing true per-sample noise requires per-sample gradients; the paper
notes both methods perform the same because the mean term is negligible, and
we expose two faithful implementations:

- ``ghost_noise_grads``: per-ghost-section noise — the mini-batch gradient is
  an average over G ghost sections, so multiplying each section's gradient by
  an independent z_g ~ N(1, G*sigma_n^2) reproduces the target covariance at
  ghost granularity. This is how we apply it at LLM scale (microbatch grads
  are available for free under gradient accumulation).
- ``multiplicative_noise_grads``: the whole-batch limit (single z per step),
  cheap and what we use when only the mean gradient exists.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def multiplicative_noise_grads(rng: jax.Array, grads: Any,
                               sigma: float) -> Any:
    """g <- g * z with z ~ N(1, sigma^2), independent per parameter tensor."""
    leaves, treedef = jax.tree.flatten(grads)
    rngs = jax.random.split(rng, len(leaves))
    noisy = [
        g * (1.0 + sigma * jax.random.normal(r, g.shape, jnp.float32)
             ).astype(g.dtype)
        for g, r in zip(leaves, rngs)
    ]
    return jax.tree.unflatten(treedef, noisy)


def ghost_noise_grads(rng: jax.Array, section_grads: Any, sigma: float) -> Any:
    """section_grads: pytree whose leaves have a leading ghost-section axis G.
    Multiplies section g's gradient by z_g ~ N(1, G * sigma^2) and averages,
    matching the per-sample-noise covariance at section granularity."""
    leaves, treedef = jax.tree.flatten(section_grads)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for g, r in zip(leaves, rngs):
        G = g.shape[0]
        z = 1.0 + sigma * jnp.sqrt(G * 1.0) * jax.random.normal(
            r, (G,) + (1,) * (g.ndim - 1), jnp.float32)
        out.append(jnp.mean(g * z.astype(g.dtype), axis=0))
    return jax.tree.unflatten(treedef, out)
