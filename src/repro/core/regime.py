"""Training regimes and Regime Adaptation (paper §5).

A regime is a piecewise-constant learning-rate schedule: an initial
high-learning-rate phase followed by exponential decreases every
``drop_every`` steps (the He et al. 2016 style regime the paper uses).

**Regime Adaptation (RA)** stretches the time-frame of the schedule by
``|B_L| / |B_S|`` so the *number of weight updates* matches the small-batch
run — the paper's key intervention: "the generalization gap stems from the
relatively small number of updates rather than the batch size".
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax.numpy as jnp

from repro.core.lr_scaling import scale_lr


@dataclass(frozen=True)
class Regime:
    """Piecewise exponential-decay LR regime, in units of optimizer steps."""

    base_lr: float
    total_steps: int
    drop_every: int                  # steps between LR drops
    drop_factor: float = 0.2         # gamma: lr *= gamma at each drop
    warmup_steps: int = 0            # optional linear warmup
    min_lr: float = 0.0

    def lr_at(self, step) -> jnp.ndarray:
        """LR at integer step (jax-traceable)."""
        step = jnp.asarray(step, jnp.float32)
        n_drops = jnp.floor(step / self.drop_every)
        lr = self.base_lr * self.drop_factor ** n_drops
        if self.warmup_steps > 0:
            warm = (step + 1.0) / self.warmup_steps
            lr = jnp.where(step < self.warmup_steps, self.base_lr * warm, lr)
        return jnp.maximum(lr, self.min_lr)

    def stretch(self, factor: float) -> "Regime":
        """Regime Adaptation: every phase of e steps becomes factor*e steps."""
        return dataclasses.replace(
            self,
            total_steps=int(round(self.total_steps * factor)),
            drop_every=max(1, int(round(self.drop_every * factor))),
            warmup_steps=int(round(self.warmup_steps * factor)),
        )


@dataclass(frozen=True)
class BatchSchedule:
    """"Don't decay the learning rate, increase the batch size" (Smith et
    al. 2018) — the comparison column from related work: keep the LR
    constant and grow the batch by ``1/drop_factor`` wherever the reference
    regime would have dropped the LR, so the gradient-noise scale follows
    the same trajectory.

    ``batch_at`` is host-side (plain int): the runner re-jits per distinct
    batch shape, which happens once per growth phase.
    """

    base_batch: int
    max_batch: int
    grow_every: int                  # steps between growths (= drop_every)
    grow_factor: float = 5.0         # = 1 / drop_factor of the LR regime
    round_to: int = 1                # keep ghost-batch divisibility

    def __post_init__(self):
        if self.round_to < 1:
            raise ValueError(f"round_to must be >= 1, got {self.round_to}")
        if self.max_batch < self.round_to:
            raise ValueError(
                f"max_batch={self.max_batch} < round_to={self.round_to}: "
                f"no batch size can satisfy both the cap and ghost-batch "
                f"divisibility")

    def batch_at(self, step: int) -> int:
        n = int(step) // self.grow_every
        b = self.base_batch * self.grow_factor ** n
        # round the cap DOWN to round_to first: clamping to a non-multiple
        # max_batch after rounding used to return an indivisible batch at
        # the cap, breaking ghost-batch divisibility
        cap = (self.max_batch // self.round_to) * self.round_to
        b = int(min(b, cap))
        return max(self.round_to, (b // self.round_to) * self.round_to)

    def phases(self, total_steps: int) -> Sequence[int]:
        """Distinct batch sizes reached within ``total_steps``."""
        seen, out = set(), []
        for s in range(0, total_steps, self.grow_every):
            b = self.batch_at(s)
            if b not in seen:
                seen.add(b)
                out.append(b)
        return out


def constant_lr(regime: Regime) -> Regime:
    """The regime with its LR decay removed (warmup kept) — the schedule a
    batch-growth run trains under. Both :func:`batch_size_increase` and
    ``RunSpec.regime()`` build it here so the mapping cannot drift."""
    return dataclasses.replace(regime, drop_factor=1.0)


def batch_size_increase(small_batch_regime: Regime, *, base_batch: int,
                        max_batch: int, round_to: int = 1
                        ) -> tuple[Regime, BatchSchedule]:
    """Map an LR-decay regime onto its Smith-et-al. equivalent: a constant-LR
    regime paired with a batch-growth schedule (grow where the LR dropped).
    """
    const = constant_lr(small_batch_regime)
    sched = BatchSchedule(
        base_batch=base_batch, max_batch=max_batch,
        grow_every=small_batch_regime.drop_every,
        grow_factor=1.0 / small_batch_regime.drop_factor,
        round_to=round_to)
    return const, sched


def adapt_regime(small_batch_regime: Regime, *, batch_size: int,
                 base_batch_size: int, lr_rule: str = "sqrt",
                 regime_adaptation: bool = True) -> Regime:
    """Build the large-batch regime from the small-batch reference.

    - ``lr_rule``: "sqrt" (paper), "linear" (Goyal baseline), or "none".
    - ``regime_adaptation=False`` keeps the *epoch budget* constant, meaning
      the large batch takes |B_S|/|B_L| as many steps (the conventional,
      gap-exhibiting setup). ``True`` keeps the *step budget* constant
      (paper's RA: epochs multiplied by |B_L|/|B_S|).
    """
    ratio = batch_size / base_batch_size
    lr = scale_lr(small_batch_regime.base_lr, batch_size, base_batch_size,
                  lr_rule)
    r = dataclasses.replace(small_batch_regime, base_lr=lr)
    if regime_adaptation:
        # same number of optimizer steps as the small-batch regime
        return r
    # same number of epochs: steps shrink by the batch ratio
    return r.stretch(1.0 / ratio)


def epochs_to_steps(n_epochs: int, dataset_size: int, batch_size: int) -> int:
    return max(1, (dataset_size // batch_size) * n_epochs)
