"""Training regimes and Regime Adaptation (paper §5).

A regime is a piecewise-constant learning-rate schedule: an initial
high-learning-rate phase followed by exponential decreases every
``drop_every`` steps (the He et al. 2016 style regime the paper uses).

**Regime Adaptation (RA)** stretches the time-frame of the schedule by
``|B_L| / |B_S|`` so the *number of weight updates* matches the small-batch
run — the paper's key intervention: "the generalization gap stems from the
relatively small number of updates rather than the batch size".
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax.numpy as jnp

from repro.core.lr_scaling import scale_lr


@dataclass(frozen=True)
class Regime:
    """Piecewise exponential-decay LR regime, in units of optimizer steps."""

    base_lr: float
    total_steps: int
    drop_every: int                  # steps between LR drops
    drop_factor: float = 0.2         # gamma: lr *= gamma at each drop
    warmup_steps: int = 0            # optional linear warmup
    min_lr: float = 0.0

    def lr_at(self, step) -> jnp.ndarray:
        """LR at integer step (jax-traceable)."""
        step = jnp.asarray(step, jnp.float32)
        n_drops = jnp.floor(step / self.drop_every)
        lr = self.base_lr * self.drop_factor ** n_drops
        if self.warmup_steps > 0:
            warm = (step + 1.0) / self.warmup_steps
            lr = jnp.where(step < self.warmup_steps, self.base_lr * warm, lr)
        return jnp.maximum(lr, self.min_lr)

    def stretch(self, factor: float) -> "Regime":
        """Regime Adaptation: every phase of e steps becomes factor*e steps."""
        return dataclasses.replace(
            self,
            total_steps=int(round(self.total_steps * factor)),
            drop_every=max(1, int(round(self.drop_every * factor))),
            warmup_steps=int(round(self.warmup_steps * factor)),
        )


def adapt_regime(small_batch_regime: Regime, *, batch_size: int,
                 base_batch_size: int, lr_rule: str = "sqrt",
                 regime_adaptation: bool = True) -> Regime:
    """Build the large-batch regime from the small-batch reference.

    - ``lr_rule``: "sqrt" (paper), "linear" (Goyal baseline), or "none".
    - ``regime_adaptation=False`` keeps the *epoch budget* constant, meaning
      the large batch takes |B_S|/|B_L| as many steps (the conventional,
      gap-exhibiting setup). ``True`` keeps the *step budget* constant
      (paper's RA: epochs multiplied by |B_L|/|B_S|).
    """
    ratio = batch_size / base_batch_size
    lr = scale_lr(small_batch_regime.base_lr, batch_size, base_batch_size,
                  lr_rule)
    r = dataclasses.replace(small_batch_regime, base_lr=lr)
    if regime_adaptation:
        # same number of optimizer steps as the small-batch regime
        return r
    # same number of epochs: steps shrink by the batch ratio
    return r.stretch(1.0 / ratio)


def epochs_to_steps(n_epochs: int, dataset_size: int, batch_size: int) -> int:
    return max(1, (dataset_size // batch_size) * n_epochs)
