from repro.data.pipeline import epoch_batches, minibatch_stream, shard_batch
from repro.data.synthetic import (ClassificationData, lm_sequences,
                                  teacher_classification, token_lm)

__all__ = [
    "epoch_batches", "minibatch_stream", "shard_batch",
    "ClassificationData", "lm_sequences", "teacher_classification",
    "token_lm",
]
