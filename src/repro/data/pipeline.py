"""Batching pipeline: epoch-shuffled minibatch iterators and device
placement helpers."""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def epoch_batches(rng: np.random.RandomState, n: int, batch_size: int,
                  drop_remainder: bool = True) -> Iterator[np.ndarray]:
    """Yield index arrays for one epoch."""
    perm = rng.permutation(n)
    end = n - n % batch_size if drop_remainder else n
    for i in range(0, end, batch_size):
        yield perm[i:i + batch_size]


def minibatch_stream(rng_seed: int, n: int, batch_size: int
                     ) -> Iterator[np.ndarray]:
    """Infinite stream of shuffled minibatch index arrays."""
    rng = np.random.RandomState(rng_seed)
    while True:
        yield from epoch_batches(rng, n, batch_size)


def shard_batch(batch: Dict[str, jax.Array], sharding) -> Dict[str, jax.Array]:
    """Place a host batch onto the mesh with the given NamedSharding."""
    return jax.tree.map(
        lambda a: jax.device_put(a, sharding), batch)
