"""Synthetic datasets.

The container is offline (no MNIST/CIFAR/ImageNet), so the paper's accuracy
experiments run on synthetic tasks engineered to exhibit a measurable
generalization gap at small scale:

- ``teacher_classification``: inputs are drawn from class-conditional
  Gaussian clusters warped by a random 2-layer teacher net; labels are the
  teacher's argmax. A limited train set + label noise makes generalization
  non-trivial, so optimizer/regime choices move validation accuracy —
  the property the Table-1 analogue needs.
- ``token_lm``: Zipf-marginal first-order Markov chains over a vocab, giving
  language-model training a learnable structure with a known entropy floor.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclass(frozen=True)
class ClassificationData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_train(self) -> int:
        return self.x_train.shape[0]


def teacher_classification(seed: int, *, n_train: int = 8192,
                           n_test: int = 2048,
                           input_shape: Tuple[int, int, int] = (16, 16, 3),
                           n_classes: int = 10,
                           label_noise: float = 0.05) -> ClassificationData:
    """Class clusters -> random teacher warp -> argmax labels (+ noise)."""
    rng = np.random.RandomState(seed)
    h, w, c = input_shape
    dim = h * w * c
    n = n_train + n_test
    protos = rng.randn(n_classes, dim).astype(np.float32)
    cls = rng.randint(0, n_classes, size=n)
    x = protos[cls] + 1.0 * rng.randn(n, dim).astype(np.float32)
    # random teacher relabels: makes the boundary non-linear in x
    w1 = rng.randn(dim, 128).astype(np.float32) / np.sqrt(dim)
    w2 = rng.randn(128, n_classes).astype(np.float32) / np.sqrt(128)
    logits = np.maximum(x @ w1, 0.0) @ w2 + 2.0 * np.eye(n_classes,
                                                         dtype=np.float32)[cls]
    y = logits.argmax(axis=1)
    flip = rng.rand(n) < label_noise
    y[flip] = rng.randint(0, n_classes, size=int(flip.sum()))
    x = x.reshape(n, h, w, c)
    # standardize like image preprocessing
    x = (x - x.mean()) / (x.std() + 1e-6)
    return ClassificationData(
        x_train=x[:n_train], y_train=y[:n_train].astype(np.int32),
        x_test=x[n_train:], y_test=y[n_train:].astype(np.int32))


def token_lm(seed: int, *, vocab_size: int, n_tokens: int,
             zipf_a: float = 1.2, branch: int = 32) -> np.ndarray:
    """First-order Markov chain with Zipf-ish marginals: every token has
    ``branch`` plausible successors. Returns a flat int32 token stream."""
    rng = np.random.RandomState(seed)
    V = vocab_size
    succ = rng.randint(0, V, size=(V, branch)).astype(np.int32)
    probs = 1.0 / np.arange(1, branch + 1) ** zipf_a
    probs /= probs.sum()
    out = np.empty(n_tokens, dtype=np.int32)
    tok = rng.randint(0, V)
    choices = rng.choice(branch, size=n_tokens, p=probs)
    jumps = rng.rand(n_tokens) < 0.02     # occasional resets
    rand_toks = rng.randint(0, V, size=n_tokens)
    for i in range(n_tokens):
        out[i] = tok
        tok = int(rand_toks[i]) if jumps[i] else int(succ[tok, choices[i]])
    return out


def lm_sequences(stream: np.ndarray, seq_len: int) -> np.ndarray:
    """Chop a token stream into (N, seq_len) rows."""
    n = stream.size // seq_len
    return stream[: n * seq_len].reshape(n, seq_len)
