"""Experiment subsystem: declarative sweeps, a resumable runner, and a
structured metrics store for the paper's Table-1 / Figure-2 studies.

- :mod:`repro.experiments.spec` — ``RunSpec`` / ``SweepSpec`` dataclasses
  with grid expansion and stable run IDs.
- :mod:`repro.experiments.metrics` — ``ResultsStore`` (append-only JSONL
  run records + Table-1 / diffusion aggregation) and the re-exported
  ``MetricsLogger`` (lives in :mod:`repro.core.metrics`, where the trainers
  log into it).
- :mod:`repro.experiments.runner` — resumable sweep runner over
  ``train_vision`` / ``train_lm`` with ``repro.checkpoint`` run state.
- :mod:`repro.experiments.registry` — the paper's sweeps (generalization-gap
  grid, diffusion study, batch-size-increase column).
- :mod:`repro.experiments.cli` — ``python -m repro.experiments.cli``.
"""
from repro.experiments.metrics import MetricsLogger, ResultsStore
from repro.experiments.registry import SWEEPS, get_sweep
from repro.experiments.runner import run_one, run_sweep
from repro.experiments.spec import DataSpec, RunSpec, SweepSpec

__all__ = [
    "DataSpec", "RunSpec", "SweepSpec", "MetricsLogger", "ResultsStore",
    "run_sweep", "run_one", "get_sweep", "SWEEPS",
]
