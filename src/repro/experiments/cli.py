"""Experiment CLI.

    PYTHONPATH=src python -m repro.experiments.cli list
    PYTHONPATH=src python -m repro.experiments.cli show <sweep>
    PYTHONPATH=src python -m repro.experiments.cli run <sweep> \
        [--out experiments/runs] [--steps N] [--seeds K] \
        [--checkpoint-every N] [--fresh] [--mesh [data|2d]]
    PYTHONPATH=src python -m repro.experiments.cli table <sweep> \
        [--out experiments/runs] [--burn-in N]

``run`` is resumable by default: re-invoking it after a kill skips recorded
runs and resumes the interrupted one from its checkpoint.
"""
from __future__ import annotations

import argparse

from repro.experiments import metrics as M
from repro.experiments.metrics import ResultsStore
from repro.experiments.registry import SWEEPS, get_sweep
from repro.experiments.runner import run_sweep


def _sweep_overrides(args) -> dict:
    kw = {}
    if args.steps:
        kw["steps"] = args.steps
    if args.seeds:
        kw["seeds"] = tuple(range(args.seeds))
    if args.mesh:
        kw["use_mesh"] = args.mesh   # "data" (1-D) or "2d" (data x model)
    return kw


def cmd_list(_args) -> None:
    for name, factory in sorted(SWEEPS.items()):
        doc = (factory.__doc__ or "").strip().splitlines()[0]
        print(f"{name:>22s}  {doc}")


def cmd_show(args) -> None:
    sweep = get_sweep(args.sweep, **_sweep_overrides(args))
    for spec in sweep.expand():
        print(f"{spec.run_id}  {spec.method:>14s}  b={spec.batch_size:<5d} "
              f"seed={spec.seed} steps={spec.regime().total_steps}")


def cmd_run(args) -> None:
    sweep = get_sweep(args.sweep, **_sweep_overrides(args))
    records = run_sweep(sweep, args.out, resume=not args.fresh,
                        checkpoint_every=args.checkpoint_every,
                        log_fn=print)
    print(f"\n{len(records)} records in {args.out}/{sweep.name}/"
          f"records.jsonl")
    _print_views(records, burn_in=2)


def cmd_table(args) -> None:
    sweep_name = args.sweep
    store = ResultsStore(f"{args.out}/{sweep_name}")
    records = store.records()
    if not records:
        print(f"no records under {store.path}")
        return
    _print_views(records, burn_in=args.burn_in)


def _print_views(records, *, burn_in: int) -> None:
    acc_rows = M.table1_view([r for r in records if "final_acc" in r])
    if acc_rows:
        print("\n== Table-1 view ==")
        print(M.format_table1(acc_rows))
    diff_rows = M.diffusion_view(records, burn_in=burn_in)
    if diff_rows:
        print("\n== diffusion fits ==")
        print(M.format_diffusion(diff_rows))
    lm = [r for r in records if "final_ce" in r]
    if lm:
        print("\n== LM runs ==")
        for r in lm:
            print(f"{r['method']:>14s} b={r['batch_size']:<5d} "
                  f"seed={r['seed']} ce={r['final_ce']:.4f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="repro.experiments.cli")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list")

    def _common(p):
        p.add_argument("sweep", choices=sorted(SWEEPS))
        p.add_argument("--steps", type=int, default=0)
        p.add_argument("--seeds", type=int, default=0,
                       help="number of seeds (0..K-1)")
        p.add_argument("--mesh", nargs="?", const="data", default="",
                       choices=["data", "2d"],
                       help="fan runs over a mesh when usable: 'data' "
                            "(1-D, the default when the flag is bare) or "
                            "'2d' (data x model)")

    p = sub.add_parser("show")
    _common(p)
    p = sub.add_parser("run")
    _common(p)
    p.add_argument("--out", default="experiments/runs")
    p.add_argument("--checkpoint-every", type=int, default=200)
    p.add_argument("--fresh", action="store_true",
                   help="discard existing records and rerun everything")
    p = sub.add_parser("table")
    p.add_argument("sweep")
    p.add_argument("--out", default="experiments/runs")
    p.add_argument("--burn-in", type=int, default=2)

    args = ap.parse_args(argv)
    {"list": cmd_list, "show": cmd_show, "run": cmd_run,
     "table": cmd_table}[args.cmd](args)


if __name__ == "__main__":
    main()
