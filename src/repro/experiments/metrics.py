"""Sweep-level metrics: the results store + the paper's aggregation views.

``MetricsLogger`` (ONE implementation, in :mod:`repro.obs.metrics`;
re-exported here and via the :mod:`repro.core.metrics` shim the trainers
import) replaces the trainers' ad-hoc ``history`` dicts with a uniform
(step, name, value) series store that serializes to/from JSON (so a
checkpointed run resumes with its already-logged metrics intact) and can
mirror into the observability :class:`~repro.obs.metrics.Registry`.

``ResultsStore`` is the sweep-level artifact: one JSONL line per finished
run (append-only — a killed sweep never corrupts earlier records), plus the
aggregations the paper reports: the Table-1 method x batch view and the
Figure-2 log/power diffusion fits (re-fit from the stored distance series
via :func:`repro.core.diffusion.fit_log_diffusion` so burn-in is an analysis
choice, not a training-time one).
"""
from __future__ import annotations

import json
import os
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.diffusion import fit_log_diffusion, fit_power_diffusion
from repro.core.metrics import MetricsLogger

__all__ = ["MetricsLogger", "ResultsStore", "table1_view", "diffusion_view",
           "format_table1", "format_diffusion"]


# ---------------------------------------------------------------------------
# results store
# ---------------------------------------------------------------------------


class ResultsStore:
    """Append-only JSONL store of run records under ``<root>/records.jsonl``.

    A record is one finished run: spec identity (run_id/method/seed/batch),
    the summary numbers, and the logged series. Appends are flushed line by
    line, so interrupting a sweep leaves every completed record readable —
    that is what makes run-granular resume safe.
    """

    def __init__(self, root: str):
        self.root = root
        self.path = os.path.join(root, "records.jsonl")

    def append(self, record: Dict[str, Any]) -> None:
        os.makedirs(self.root, exist_ok=True)
        line = json.dumps(record, sort_keys=True)
        with open(self.path, "a") as f:
            f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())

    def records(self) -> List[Dict[str, Any]]:
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out

    def completed_run_ids(self) -> set:
        return {r["run_id"] for r in self.records() if "run_id" in r}


# ---------------------------------------------------------------------------
# aggregation: the paper's views
# ---------------------------------------------------------------------------


def table1_view(records: Iterable[Dict[str, Any]]
                ) -> List[Dict[str, Any]]:
    """Aggregate run records into Table-1 rows: one row per
    (method, batch_size, step budget), validation accuracy mean/std over
    seeds. Grouping by the step budget keeps records from different-scale
    invocations of the same sweep (e.g. a --steps 120 debug run next to
    the full one) in separate rows instead of silently averaging them."""
    groups: Dict[Tuple[str, int, int],
                 List[Dict[str, Any]]] = defaultdict(list)
    for r in records:
        groups[(r["method"], int(r["batch_size"]),
                int(r.get("steps", 0)))].append(r)
    rows = []
    for (method, batch, _), rs in sorted(groups.items(),
                                         key=lambda kv: (kv[0][1], kv[0][0],
                                                         kv[0][2])):
        accs = np.asarray([r["final_acc"] for r in rs], dtype=np.float64)
        trains = np.asarray([r.get("train_acc", float("nan")) for r in rs],
                            dtype=np.float64)
        rows.append({
            "method": method,
            "batch_size": batch,
            "n_seeds": len(rs),
            "steps": int(rs[0]["steps"]),
            "val_acc_mean": float(accs.mean()),
            "val_acc_std": float(accs.std()),
            "train_acc_mean": float(np.nanmean(trains)),
        })
    return rows


def diffusion_view(records: Iterable[Dict[str, Any]], *, burn_in: int = 2
                   ) -> List[Dict[str, Any]]:
    """Figure-2 view: re-fit the log/power diffusion laws from each record's
    stored (dist_steps, distance) series at the requested burn-in."""
    rows = []
    for r in records:
        series = r.get("metrics", {}).get("distance")
        if not series or not series[0]:
            continue
        steps, dists = series
        rows.append({
            "method": r["method"],
            "batch_size": int(r["batch_size"]),
            "seed": r.get("seed", 0),
            "log_fit": fit_log_diffusion(steps, dists, burn_in=burn_in),
            "power_fit": fit_power_diffusion(steps, dists, burn_in=burn_in),
            "final_distance": float(dists[-1]) if dists else float("nan"),
        })
    rows.sort(key=lambda r: (r["batch_size"], r["method"], r["seed"]))
    return rows


def format_table1(rows: Sequence[Dict[str, Any]],
                  baseline: Optional[str] = "SB") -> str:
    """Render Table-1 rows as the examples' aligned text table."""
    lines = [f"{'method':>14s} {'batch':>6s} {'steps':>7s} {'val_acc':>8s} "
             f"{'+/-':>6s} {'train_acc':>9s}"]
    base = next((r["val_acc_mean"] for r in rows
                 if baseline and r["method"] == baseline), None)
    for r in rows:
        delta = ("" if base is None or r["method"] == baseline
                 else f"  ({r['val_acc_mean'] - base:+.4f} vs {baseline})")
        lines.append(
            f"{r['method']:>14s} {r['batch_size']:6d} {r['steps']:7d} "
            f"{r['val_acc_mean']:8.4f} {r['val_acc_std']:6.4f} "
            f"{r['train_acc_mean']:9.4f}{delta}")
    return "\n".join(lines)


def format_diffusion(rows: Sequence[Dict[str, Any]]) -> str:
    lines = [f"{'method':>14s} {'batch':>6s} {'slope':>7s} {'log R^2':>8s} "
             f"{'pow exp':>8s} {'pow R^2':>8s}"]
    for r in rows:
        lf, pf = r["log_fit"], r["power_fit"]
        lines.append(f"{r['method']:>14s} {r['batch_size']:6d} "
                     f"{lf['slope']:7.3f} {lf['r2']:8.4f} "
                     f"{pf['power']:8.3f} {pf['r2']:8.4f}")
    return "\n".join(lines)
