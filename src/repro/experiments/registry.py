"""The paper's sweeps, as named SweepSpec factories.

Each factory returns a reduced-scale (offline-container) configuration of a
study from the paper or its related work:

- ``generalization-gap`` — Table 1: the SB/LB/+LR/+GBN/+RA method columns.
- ``diffusion`` — Figure 2: constant-high-LR walks at several batch sizes,
  log-t vs power-law fits of ||w_t - w_0||.
- ``batch-size-increase`` — the Smith et al. 2018 comparison column
  ("don't decay the learning rate, increase the batch size") against SB and
  the paper's full recipe.
- ``lm-smoke`` — the recipe on a reduced assigned LM architecture (ghost
  gradient noise instead of GBN), exercising the LM runner path through the
  ``use_kernels=True`` hot path (Pallas flash-attention / Mamba chunk-scan
  forward+backward kernels).

Factories accept scale overrides so the examples, tests, and benchmarks can
shrink them (``steps=``, ``seeds=``, ...).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence

from repro.configs.paper_models import F1_MNIST
from repro.core.large_batch import LargeBatchConfig, presets
from repro.core.regime import batch_size_increase
from repro.experiments.spec import DataSpec, RunSpec, SweepSpec


def _f1_reduced(hidden=(192, 192, 192), ghost=16):
    return dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                               hidden_sizes=tuple(hidden),
                               ghost_batch_size=ghost)


def _gap_base(steps: int, *, track_diffusion: bool = True) -> RunSpec:
    return RunSpec(
        name="generalization-gap", method="SB", model=_f1_reduced(),
        data=DataSpec(seed=7, n_train=6144, n_test=1024,
                      input_shape=(8, 8, 1), n_classes=10,
                      label_noise=0.05),
        lb=LargeBatchConfig(batch_size=32, base_batch_size=32),
        base_lr=0.08, total_steps=steps, drop_every=max(1, steps // 3),
        drop_factor=0.2, seed=5, track_diffusion=track_diffusion)


def generalization_gap(*, steps: int = 2400, large_batch: int = 1024,
                       small_batch: int = 32, ghost: int = 16,
                       seeds: Sequence[int] = (0,),
                       use_mesh=False) -> SweepSpec:
    """Table 1: the five method columns on the reduced F1 task."""
    cols = presets(large_batch, small_batch, ghost=ghost)
    base = dataclasses.replace(_gap_base(steps), use_mesh=use_mesh)
    return SweepSpec(
        name="generalization-gap", base=base,
        methods={name: {"lb": lb} for name, lb in cols.items()},
        seeds=tuple(seeds))


def diffusion(*, steps: int = 400, batches: Sequence[int] = (32, 128, 512),
              seeds: Sequence[int] = (0,), use_mesh=False
              ) -> SweepSpec:
    """Figure 2: constant high-LR random walk, one run per batch size."""
    base = RunSpec(
        name="diffusion", method="high-lr-walk",
        model=_f1_reduced(hidden=(128, 128)),
        data=DataSpec(seed=3, n_train=4096, n_test=512,
                      input_shape=(8, 8, 1), n_classes=10, label_noise=0.0),
        lb=LargeBatchConfig(batch_size=32, base_batch_size=32,
                            grad_clip=0.0),
        base_lr=0.08, total_steps=steps, drop_every=10 ** 9, seed=11,
        use_mesh=use_mesh)
    return SweepSpec(
        name="diffusion", base=base,
        grid={"lb": [LargeBatchConfig(batch_size=b, base_batch_size=b,
                                      grad_clip=0.0) for b in batches]},
        seeds=tuple(seeds))


def batch_size_increase_sweep(*, steps: int = 2400, large_batch: int = 1024,
                              small_batch: int = 32, ghost: int = 16,
                              seeds: Sequence[int] = (0,),
                              use_mesh=False) -> SweepSpec:
    """Smith et al. 2018 as a Table-1 column: constant LR with the batch
    grown where the SB regime would drop the LR, next to SB and the paper's
    full recipe."""
    base = dataclasses.replace(_gap_base(steps), use_mesh=use_mesh)
    cols = presets(large_batch, small_batch, ghost=ghost)
    _, sched = batch_size_increase(base.small_regime(),
                                   base_batch=small_batch,
                                   max_batch=large_batch, round_to=ghost)
    bs_inc_lb = LargeBatchConfig(
        batch_size=large_batch, base_batch_size=small_batch,
        lr_rule="none", use_gbn=True, regime_adaptation=False,
        ghost_batch_size=ghost, grad_clip=0.0)
    return SweepSpec(
        name="batch-size-increase", base=base,
        methods={
            "SB": {"lb": cols["SB"]},
            "LB+LR+GBN+RA": {"lb": cols["LB+LR+GBN+RA"]},
            "LB+BS-INC": {"lb": bs_inc_lb, "batch_schedule": sched},
        },
        seeds=tuple(seeds))


def lm_smoke(*, steps: int = 30, arch: str = "qwen3-1.7b",
             seeds: Sequence[int] = (0,), use_mesh=False
             ) -> SweepSpec:
    """The recipe on a reduced assigned LM arch: SB vs LB with ghost
    gradient noise (the norm-free GBN twin) — a runner smoke, not a paper
    table. Runs ``use_kernels=True``: training differentiates through the
    Pallas flash-attention / Mamba chunk-scan custom-VJP pairs.
    ``use_mesh="2d"`` fans MoE-arch runs over the ``("data", "model")``
    mesh (expert weights sharded over ``"model"``) when the geometry
    allows; dense archs take the full-width data mesh instead."""
    base = RunSpec(
        name="lm-smoke", method="SB", model=_f1_reduced(),
        data=DataSpec(seed=1), lm_arch=arch, lm_seq_len=32,
        lm_n_tokens=16384, lm_vocab_size=128,
        lb=LargeBatchConfig(batch_size=8, base_batch_size=8,
                            lr_rule="none", use_gbn=False),
        base_lr=0.02, total_steps=steps, drop_every=max(1, steps // 2),
        track_diffusion=False, weight_decay=0.0, use_kernels=True,
        eval_every=max(1, steps // 2), use_mesh=use_mesh)
    lb_large = LargeBatchConfig(batch_size=32, base_batch_size=8,
                                lr_rule="sqrt", use_gbn=False,
                                ghost_noise=1.0)
    return SweepSpec(name="lm-smoke", base=base,
                     methods={"SB": {}, "LB+LR+NOISE": {"lb": lb_large}},
                     seeds=tuple(seeds))


SWEEPS: Dict[str, Callable[..., SweepSpec]] = {
    "generalization-gap": generalization_gap,
    "diffusion": diffusion,
    "batch-size-increase": batch_size_increase_sweep,
    "lm-smoke": lm_smoke,
}


def get_sweep(name: str, **overrides) -> SweepSpec:
    """Build a registered sweep. Unknown override names raise TypeError —
    silently dropping them would let a typo'd or unsupported flag change
    what the user thinks they ran."""
    if name not in SWEEPS:
        raise KeyError(f"unknown sweep {name!r}; have {sorted(SWEEPS)}")
    return SWEEPS[name](**overrides)
