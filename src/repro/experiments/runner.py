"""Resumable sweep runner.

``run_sweep`` expands a :class:`~repro.experiments.spec.SweepSpec` into its
runs (deterministic order), skips every run whose ``run_id`` is already in
the sweep's :class:`~repro.experiments.metrics.ResultsStore`, and executes
the rest. Each run trains with ``checkpoint_dir`` under the sweep directory,
so a sweep killed mid-run restarts at the first unfinished run AND that run
resumes from its last checkpointed (params, bn_state, opt_state, epoch,
cursor, metrics) — the restarted sweep produces the same JSONL records as an
uninterrupted one (shuffling is a pure function of (seed, epoch)).

Runs fan over a mesh when more than one device is available and the run's
geometry shards evenly (:func:`repro.train.parallel.mesh_compatible`):
``use_mesh`` selects the topology — ``True``/``"data"`` for the 1-D
``("data",)`` mesh, ``"2d"`` for the ``("data", "model")`` mesh (LM MoE
expert weights sharded over ``"model"``) — and ``_mesh_for`` walks down the
topology ladder to the widest compatible mesh, or single-device.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import shutil
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.experiments.metrics import MetricsLogger, ResultsStore
from repro.experiments.spec import RunSpec, SweepSpec


def _lm_config(spec: RunSpec):
    """The reduced LM ModelConfig an LM run trains (shared by the trainer
    dispatch and the mesh-geometry gate)."""
    from repro.configs.registry import get_config
    return dataclasses.replace(get_config(spec.lm_arch).reduced(),
                               dtype="float32",
                               vocab_size=spec.lm_vocab_size)


_DEGRADE_WARNED: set = set()


def _warn_degraded(requested: str, actual: str) -> None:
    """One warning per (requested, actual) pair per process: the ladder's
    silent fallbacks made "my 2d sweep ran single-device" invisible."""
    key = (requested, actual)
    if key in _DEGRADE_WARNED:
        return
    _DEGRADE_WARNED.add(key)
    warnings.warn(
        f"mesh topology {requested!r} unavailable for this run's geometry/"
        f"devices; degrading to {actual!r}", RuntimeWarning, stacklevel=3)


def _mesh_for(spec: RunSpec):
    """The widest mesh this run's topology request and geometry allow.

    ``use_mesh`` is a topology selector: falsy -> None; True/"data" -> the
    1-D ``("data",)`` mesh; "2d" -> the ``("data", "model")`` mesh. A "2d"
    request degrades to the data mesh (and then to None) when the geometry
    (batch % dp size, experts % model size — see
    :func:`repro.train.parallel.mesh_compatible`) doesn't fit, or when the
    run has nothing to shard over the model axis (vision or dense-LM runs
    — a model axis would only replicate work that the wider data mesh
    parallelizes). Degrading emits a one-time RuntimeWarning naming the
    requested and actual topology.
    """
    if not spec.use_mesh:
        return None
    topo = "data" if spec.use_mesh is True else str(spec.use_mesh)
    if topo not in ("data", "2d"):
        raise ValueError(f"unknown mesh topology {spec.use_mesh!r}; "
                         "expected False, True, 'data', or '2d'")
    import jax
    from repro.launch.mesh import MODEL_AXIS, make_2d_mesh, make_data_mesh
    from repro.train.parallel import mesh_compatible
    if len(jax.devices()) < 2:
        _warn_degraded(topo, "single-device")
        return None
    cfg = _lm_config(spec) if spec.lm_arch else None
    sizes = (spec.batch_schedule.phases(spec.regime().total_steps)
             if spec.batch_schedule is not None else [spec.lb.batch_size])
    ladder = [("data", make_data_mesh())]
    if topo == "2d" and cfg is not None and cfg.moe is not None:
        mesh2d = make_2d_mesh()
        if MODEL_AXIS in mesh2d.axis_names and mesh2d.shape[MODEL_AXIS] > 1:
            ladder.insert(0, ("2d", mesh2d))
    for name, mesh in ladder:
        if all(mesh_compatible(spec.lb, mesh, batch_size=b, cfg=cfg)
               for b in sizes):
            if name != topo:
                _warn_degraded(topo, name)
            return mesh
    _warn_degraded(topo, "single-device")
    return None


def run_one(spec: RunSpec, *, checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 0,
            log_fn: Optional[Callable[[str], None]] = None,
            obs=None) -> Dict[str, Any]:
    """Execute one run and return its JSONL record (not yet stored).

    ``obs`` (a :class:`repro.obs.Observability`) threads into the trainer:
    the run's ``MetricsLogger`` series mirror into the shared registry
    under ``train/`` and each step gets a ``train.step`` span — one
    observability sink across a whole sweep.
    """
    t0 = time.time()
    regime = spec.regime()
    if spec.lm_arch:
        out = _run_lm(spec, regime, checkpoint_dir=checkpoint_dir,
                      checkpoint_every=checkpoint_every, log_fn=log_fn,
                      obs=obs)
    else:
        out = _run_vision(spec, regime, checkpoint_dir=checkpoint_dir,
                          checkpoint_every=checkpoint_every, log_fn=log_fn,
                          obs=obs)
    logger: MetricsLogger = out["metrics"]
    record: Dict[str, Any] = {
        "run_id": spec.run_id,
        "sweep": spec.name,
        "method": spec.method,
        "seed": spec.seed,
        "batch_size": spec.batch_size,
        "steps": out["steps"],
        "wall_s": round(time.time() - t0, 3),
        "metrics": logger.to_json(),
        "spec": spec.to_json(),
    }
    for k in ("final_acc", "best_acc", "train_acc", "final_ce"):
        if k in out:
            record[k] = float(out[k])
    for k in ("log_fit", "power_fit"):
        if k in out:
            record[k] = out[k]
    return record


def _run_vision(spec: RunSpec, regime, *, checkpoint_dir, checkpoint_every,
                log_fn, obs=None):
    from repro.models.cnn import model_fns
    from repro.train.trainer import train_vision
    data = spec.data.build()
    return train_vision(
        model_fns(spec.model), spec.model, data, spec.lb, regime,
        seed=spec.seed, eval_every=spec.eval_every,
        track_diffusion=spec.track_diffusion,
        diffusion_every=spec.diffusion_every, log_fn=log_fn,
        use_kernels=spec.use_kernels, mesh=_mesh_for(spec),
        weight_decay=spec.weight_decay,
        batch_schedule=spec.batch_schedule,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        obs=obs)


def _run_lm(spec: RunSpec, regime, *, checkpoint_dir, checkpoint_every,
            log_fn, obs=None):
    from repro.data.synthetic import lm_sequences, token_lm
    from repro.train.trainer import train_lm
    cfg = _lm_config(spec)
    stream = token_lm(spec.data.seed, vocab_size=spec.lm_vocab_size,
                      n_tokens=spec.lm_n_tokens)
    rows = lm_sequences(stream, spec.lm_seq_len)
    holdout = max(spec.lb.batch_size, rows.shape[0] // 10)
    return train_lm(
        cfg, spec.lb, regime, rows, seed=spec.seed,
        eval_every=spec.eval_every, holdout=holdout,
        use_kernels=spec.use_kernels, weight_decay=spec.weight_decay,
        track_diffusion=spec.track_diffusion,
        diffusion_every=spec.diffusion_every, log_fn=log_fn,
        mesh=_mesh_for(spec),
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        obs=obs)


def _shard_owns(run_id: str, index: int, count: int) -> bool:
    """Stable run -> host assignment: hash the content-addressed run_id, not
    the expansion order, so adding/removing runs from a sweep never
    reshuffles the survivors across hosts."""
    h = int(hashlib.sha1(run_id.encode()).hexdigest()[:8], 16)
    return h % count == index


def run_sweep(sweep: SweepSpec, out_dir: str, *, resume: bool = True,
              checkpoint_every: int = 0,
              keep_checkpoints: bool = False,
              log_fn: Optional[Callable[[str], None]] = None,
              obs=None,
              shard: Optional[Tuple[int, int]] = None
              ) -> List[Dict[str, Any]]:
    """Run (or resume) every run of ``sweep``; returns all its records.

    ``out_dir/<sweep.name>/records.jsonl`` accumulates one record per
    finished run; ``out_dir/<sweep.name>/ckpt/<run_id>/`` holds the
    in-flight run state (deleted on run completion unless
    ``keep_checkpoints``). With ``resume=False`` the store is cleared and
    every run re-executes.

    ``shard=(index, count)`` runs only the runs whose ``run_id`` hashes to
    ``index`` — one runner per host under a multi-process launch, all
    appending to the same shared ``out_dir`` store. ``shard=None``
    auto-detects from the jax distributed runtime when it spans more than
    one process; the returned records cover THIS shard only (the JSONL
    store accumulates the union).
    """
    if shard is None:
        import jax
        if jax.process_count() > 1:
            shard = (jax.process_index(), jax.process_count())
    root = os.path.join(out_dir, sweep.name)
    store = ResultsStore(root)
    if not resume and os.path.exists(root):
        shutil.rmtree(root)
    specs = sweep.expand()
    if shard is not None:
        index, count = shard
        if not (0 <= index < count):
            raise ValueError(f"bad sweep shard {shard}")
        specs = [s for s in specs if _shard_owns(s.run_id, index, count)]
        if log_fn:
            log_fn(f"sweep shard {index}/{count}: {len(specs)} run(s)")
    done = store.completed_run_ids() if resume else set()
    for i, spec in enumerate(specs):
        tag = f"[{i + 1}/{len(specs)}] {spec.method} b={spec.batch_size} " \
              f"seed={spec.seed}"
        ckpt_dir = os.path.join(root, "ckpt", spec.run_id)
        if spec.run_id in done:
            if not keep_checkpoints and os.path.exists(ckpt_dir):
                # a kill between store.append and cleanup orphans the
                # checkpoint; reap it once the record exists
                shutil.rmtree(ckpt_dir)
            if log_fn:
                log_fn(f"{tag}: done ({spec.run_id}), skipping")
            continue
        if log_fn:
            log_fn(f"{tag}: running ({spec.run_id})")
        record = run_one(spec, checkpoint_dir=ckpt_dir if checkpoint_every
                         else None,
                         checkpoint_every=checkpoint_every, log_fn=log_fn,
                         obs=obs)
        store.append(record)
        if not keep_checkpoints and os.path.exists(ckpt_dir):
            shutil.rmtree(ckpt_dir)
    wanted = {s.run_id for s in specs}
    return [r for r in store.records() if r["run_id"] in wanted]
