"""Declarative experiment specs.

A ``RunSpec`` is everything one training run needs — model config, data
source, the ``LargeBatchConfig`` recipe, regime construction, seed, and
runner knobs — as a frozen dataclass that serializes to canonical JSON.
Its ``run_id`` is a content hash of that JSON, so identity is stable across
processes: the resumable runner uses it to skip already-recorded runs, and
two sweeps that share a run share its ID.

A ``SweepSpec`` is a base ``RunSpec`` crossed with method columns (named
field-override sets, e.g. Table 1's SB/LB/+LR/+GBN/+RA), a value grid over
dotted field paths (``"lb.batch_size"``, ``"model.ghost_batch_size"``), and
seeds. ``expand()`` materializes the grid in a deterministic order.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.configs.paper_models import PAPER_MODELS, VisionModelConfig
from repro.core.large_batch import LargeBatchConfig
from repro.core.regime import BatchSchedule, Regime, constant_lr


@dataclass(frozen=True)
class DataSpec:
    """Synthetic teacher-classification data source (the offline container's
    stand-in for MNIST/CIFAR — see :mod:`repro.data.synthetic`)."""

    seed: int = 7
    n_train: int = 6144
    n_test: int = 1024
    input_shape: Tuple[int, int, int] = (8, 8, 1)
    n_classes: int = 10
    label_noise: float = 0.05

    def build(self):
        from repro.data.synthetic import teacher_classification
        return teacher_classification(
            self.seed, n_train=self.n_train, n_test=self.n_test,
            input_shape=tuple(self.input_shape), n_classes=self.n_classes,
            label_noise=self.label_noise)


@dataclass(frozen=True)
class RunSpec:
    """One training run, fully specified."""

    name: str                         # sweep-local label, e.g. "gen-gap"
    method: str                       # Table-1 column label, e.g. "LB+LR"
    model: VisionModelConfig
    data: DataSpec
    lb: LargeBatchConfig
    # small-batch reference regime; the per-method regime comes from
    # lb.build_regime(small_regime()) unless a batch schedule overrides it
    base_lr: float = 0.08
    total_steps: int = 2400
    drop_every: int = 800
    drop_factor: float = 0.2
    warmup_steps: int = 0
    batch_schedule: Optional[BatchSchedule] = None
    # runner knobs
    seed: int = 0
    eval_every: int = 0
    track_diffusion: bool = True
    diffusion_every: int = 0          # 0 = auto cadence
    use_kernels: bool = False
    weight_decay: float = 5e-4
    # mesh-topology selector: False/"" = single device; True or "data" =
    # the 1-D ("data",) mesh; "2d" = the ("data", "model") mesh (expert
    # weights sharded over "model"). The runner falls back down the
    # topology ladder when a run's geometry doesn't fit (see
    # experiments.runner._mesh_for).
    use_mesh: Any = False
    # LM workload: set to a registry arch name to drive the LM trainer
    # instead of the vision one (model/data are then ignored)
    lm_arch: str = ""
    lm_seq_len: int = 64
    lm_n_tokens: int = 65536
    lm_vocab_size: int = 256

    # -- regime construction ------------------------------------------------

    def small_regime(self) -> Regime:
        return Regime(base_lr=self.base_lr, total_steps=self.total_steps,
                      drop_every=self.drop_every,
                      drop_factor=self.drop_factor,
                      warmup_steps=self.warmup_steps)

    def regime(self) -> Regime:
        if self.batch_schedule is not None:
            # Smith et al.: the LR stays constant; growth replaces decay
            return constant_lr(self.small_regime())
        return self.lb.build_regime(self.small_regime())

    # -- identity / serialization ------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        obj = _to_jsonable(dataclasses.asdict(self))
        # canonicalize the topology selector so equivalent requests hash to
        # the same run_id: "data" == True (preserving run_ids recorded when
        # the 1-D mesh was a boolean), any falsy == False.
        um = obj.get("use_mesh")
        obj["use_mesh"] = (True if um in (True, "data")
                           else str(um) if um else False)
        return obj

    @classmethod
    def from_json(cls, obj: Mapping[str, Any]) -> "RunSpec":
        obj = dict(obj)
        obj["model"] = VisionModelConfig(**_detuple(
            obj["model"], ("input_shape", "hidden_sizes", "channels")))
        obj["data"] = DataSpec(**_detuple(obj["data"], ("input_shape",)))
        obj["lb"] = LargeBatchConfig(**obj["lb"])
        if obj.get("batch_schedule") is not None:
            obj["batch_schedule"] = BatchSchedule(**obj["batch_schedule"])
        return cls(**obj)

    @property
    def run_id(self) -> str:
        canon = json.dumps(self.to_json(), sort_keys=True)
        return hashlib.sha256(canon.encode()).hexdigest()[:12]

    @property
    def batch_size(self) -> int:
        return (self.batch_schedule.base_batch
                if self.batch_schedule is not None else self.lb.batch_size)


@dataclass(frozen=True)
class SweepSpec:
    """A grid of runs: base spec x method columns x field grid x seeds."""

    name: str
    base: RunSpec
    # method label -> field overrides (dotted paths allowed); the Table-1
    # columns are {"SB": {"lb": <cfg>}, ...}. Empty = just the base spec.
    methods: Mapping[str, Mapping[str, Any]] = field(default_factory=dict)
    # dotted field path -> values, crossed in insertion order
    grid: Mapping[str, Sequence[Any]] = field(default_factory=dict)
    seeds: Sequence[int] = (0,)

    def expand(self) -> List[RunSpec]:
        methods = dict(self.methods) or {self.base.method: {}}
        specs: List[RunSpec] = []
        for method, overrides in methods.items():
            spec = dataclasses.replace(self.base, name=self.name,
                                       method=method)
            for path, value in overrides.items():
                spec = replace_path(spec, path, value)
            for assignment in _grid_points(self.grid):
                s = spec
                for path, value in assignment:
                    s = replace_path(s, path, value)
                for seed in self.seeds:
                    specs.append(dataclasses.replace(s, seed=int(seed)))
        return specs


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def replace_path(spec: Any, path: str, value: Any) -> Any:
    """``dataclasses.replace`` through a dotted field path, e.g.
    ``replace_path(run, "lb.batch_size", 512)``."""
    head, _, rest = path.partition(".")
    if not rest:
        return dataclasses.replace(spec, **{head: value})
    inner = replace_path(getattr(spec, head), rest, value)
    return dataclasses.replace(spec, **{head: inner})


def _grid_points(grid: Mapping[str, Sequence[Any]]
                 ) -> List[Tuple[Tuple[str, Any], ...]]:
    points: List[Tuple[Tuple[str, Any], ...]] = [()]
    for path, values in grid.items():
        points = [p + ((path, v),) for p in points for v in values]
    return points


def _to_jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {k: _to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_to_jsonable(v) for v in obj]
    return obj


def _detuple(obj: Dict[str, Any], keys: Sequence[str]) -> Dict[str, Any]:
    out = dict(obj)
    for k in keys:
        if k in out and out[k] is not None:
            out[k] = tuple(out[k])
    return out


def paper_model(name: str) -> VisionModelConfig:
    return PAPER_MODELS[name]
