"""Pallas TPU flash attention (streaming softmax), with causal masking,
sliding-window support and GQA.

TPU-native design: the grid is (B, H, n_q_blocks, n_kv_blocks) — TPU iterates
the last grid axis sequentially per core, so the running max / normalizer /
accumulator live in VMEM scratch across kv steps and the output block is
written once on the final kv step. KV blocks that are entirely masked
(beyond causal frontier or older than the window) are skipped with
``pl.when``. Block sizes are MXU-aligned (128 multiples); GQA indexes the
kv head as h // (H // KV) in the BlockSpec index maps, so K/V are never
materialised per-q-head.

Layout: q (B, H, T, hd); k, v (B, KV, S, hd) — head-major so the sequence
axis is the penultimate (sublane) dimension of each block.

Public entry: :func:`repro.kernels.ops.flash_attention`.
Oracle: :func:`repro.kernels.ref.attention_ref`.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # does this kv block intersect the visible band of this q block?
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        # newest visible key for the oldest query in the block:
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 > q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                       # (bq, bk)
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_idx < seq_k
        if causal:
            mask &= k_idx <= q_idx
        if window is not None:
            mask &= k_idx > q_idx - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, T, hd); k, v: (B, KV, S, hd) -> (B, H, T, hd)."""
    B, H, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    bq = min(block_q, max(T, 8))
    bk = min(block_k, max(S, 8))
    Tp, Sp = (T + bq - 1) // bq * bq, (S + bk - 1) // bk * bk
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    grid = (B, H, Tp // bq, Sp // bk)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
            window=window, block_q=bq, block_k=bk, seq_q=T, seq_k=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running normalizer
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :T]
