"""Pallas TPU flash attention (streaming softmax), with causal masking,
sliding-window support, GQA, and a dedicated Pallas backward.

TPU-native design: the forward grid is (B, H, n_q_blocks, n_kv_blocks) — TPU
iterates the last grid axis sequentially per core, so the running max /
normalizer / accumulator live in VMEM scratch across kv steps and the output
block is written once on the final kv step. KV blocks that are entirely
masked (beyond causal frontier or older than the window) are skipped with
``pl.when``. Block sizes are sublane-aligned (rounded up to the dtype's
sublane multiple — 8 for f32, 16 for bf16 — so ragged ``T``/``S`` produce
legal BlockSpecs outside interpret mode); GQA indexes the kv head as
h // (H // KV) in the BlockSpec index maps, so K/V are never materialised
per-q-head.

Backward: the standard recomputation trick. The forward additionally emits
the per-row logsumexp ``lse = m + log l`` (the only residual beyond the
inputs and output), and the backward recomputes the probabilities
``p = exp(q k^T * scale - lse)`` blockwise instead of storing the (T, S)
matrix:

- ``_flash_bwd_dq_kernel`` — grid (B, H, n_q, n_kv), kv innermost; dq is
  accumulated in VMEM scratch across kv steps and written once.
- ``_flash_bwd_dkv_kernel`` — the transposed grid (B, H, n_kv, n_q), q
  innermost; dk and dv accumulate in VMEM scratch across q steps. Gradients
  are produced per q-head; :func:`flash_attention_backward_pallas` sums the
  GQA cotangents over each q-head group outside the kernel.

Both backward kernels skip non-intersecting (q-block, kv-block) pairs with
the same visibility test as the forward.

Layout: q (B, H, T, hd); k, v (B, KV, S, hd) — head-major so the sequence
axis is the penultimate (sublane) dimension of each block.

Public entry: :func:`repro.kernels.ops.flash_attention` (differentiable via
``jax.custom_vjp``). Oracles: :func:`repro.kernels.ref.attention_ref` /
:func:`repro.kernels.ref.attention_vjp_ref`.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _sublane(dtype) -> int:
    """Minimum sublane multiple for a block's penultimate axis."""
    return 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8


def _round_up(n: int, mult: int) -> int:
    return (n + mult - 1) // mult * mult


def _block_sizes(T: int, S: int, block_q: int, block_k: int,
                 dtype) -> Tuple[int, int]:
    """Sublane-aligned (bq, bk): never larger than the padded sequence, and
    always a multiple of the dtype's sublane count, so the BlockSpecs are
    legal on hardware even for ragged ``T``/``S`` (e.g. T=100 -> bq=104,
    not 100)."""
    sub = _sublane(dtype)
    bq = _round_up(min(block_q, max(T, sub)), sub)
    bk = _round_up(min(block_k, max(S, sub)), sub)
    return bq, bk


def _band_intersects(q_start, k_start, *, causal: bool,
                     window: Optional[int], block_q: int, block_k: int):
    """Does this (q-block, kv-block) pair intersect the visible band?
    Shared by the forward and both backward kernels so they agree on which
    blocks are skipped."""
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        # newest visible key for the oldest query in the block:
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 > q_start - window)
    return needed


def _rope_rotate(x, pos, theta: float):
    """Half-rotation RoPE on one f32 (rows, hd) tile with per-row positions
    ``pos`` (rows, 1) f32 — the in-kernel form of ``layers.apply_rope``
    (llama convention, ``freqs_i = theta ** -(i / (hd/2))``). Shared by the
    fused-RoPE attention forward and both decode kernels so the rotation
    cannot drift between them."""
    hd = x.shape[-1]
    half = hd // 2
    j = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1)
    ang = pos * jnp.exp(-(j / half) * math.log(theta))    # (rows, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[:, :half], x[:, half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def _visibility_mask(s_shape, q_start, k_start, *, causal: bool,
                     window: Optional[int], seq_k: int, kv_offset=None):
    q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, s_shape, 0)
    k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, s_shape, 1)
    mask = k_idx < seq_k
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    if kv_offset is not None:
        # left-padded ragged prefill: keys before this sequence's first real
        # token are invisible (dynamic per-batch scalar)
        mask &= k_idx >= kv_offset
    return mask


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _flash_kernel(q_ref, k_ref, v_ref, *rest, scale: float, causal: bool,
                  window: Optional[int], block_q: int, block_k: int,
                  seq_k: int, has_offsets: bool = False,
                  rope_theta: Optional[float] = None):
    rest = list(rest)
    off_ref = rest.pop(0) if has_offsets else None
    pq_ref = pk_ref = None
    if rope_theta is not None:
        pq_ref = rest.pop(0)
        pk_ref = rest.pop(0)
    o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    needed = _band_intersects(q_start, k_start, causal=causal, window=window,
                              block_q=block_q, block_k=block_k)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        if rope_theta is not None:
            # rotation is linear, so rotating before the 1/sqrt(hd) scale
            # is exact; padded rows rotate garbage that the visibility mask
            # (k side) or the output slice (q side) discards
            q = _rope_rotate(q, pq_ref[0], rope_theta)
            k = _rope_rotate(k, pk_ref[0], rope_theta)
        q = q * scale
        s = q @ k.T                                       # (bq, bk)
        mask = _visibility_mask(
            s.shape, q_start, k_start, causal=causal, window=window,
            seq_k=seq_k,
            kv_offset=off_ref[0, 0] if has_offsets else None)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)           # (bq, 1)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           return_residuals: bool = False,
                           kv_offsets: Optional[jax.Array] = None,
                           interpret: bool = False
                           ) -> Union[jax.Array,
                                      Tuple[jax.Array, jax.Array]]:
    """q: (B, H, T, hd); k, v: (B, KV, S, hd) -> (B, H, T, hd).

    ``return_residuals=True`` additionally returns the per-row logsumexp
    ``lse`` (B, H, T) f32 — the residual the backward pass needs to
    recompute the probabilities blockwise.

    ``kv_offsets`` (B,) int32 hides keys before each sequence's first real
    token (left-padded ragged prefill). Forward-only: the serving fused
    prefill uses it; the differentiable training entry does not expose it.
    """
    B, H, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    bq, bk = _block_sizes(T, S, block_q, block_k, q.dtype)
    Tp, Sp = _round_up(T, bq), _round_up(S, bk)
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    grid = (B, H, Tp // bq, Sp // bk)

    has_offsets = kv_offsets is not None
    inputs = (q, k, v)
    off_specs = []
    if has_offsets:
        inputs = inputs + (jnp.asarray(kv_offsets, jnp.int32).reshape(B, 1),)
        off_specs = [pl.BlockSpec((1, 1), lambda b, h, qi, ki: (b, 0),
                                  memory_space=pltpu.SMEM)]

    out, lse = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
            window=window, block_q=bq, block_k=bk, seq_k=S,
            has_offsets=has_offsets),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ] + off_specs,
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            # trailing unit axis keeps bq on the SUBLANE axis — a (1,1,bq)
            # block would put the merely-sublane-aligned bq on the lane
            # axis, which is illegal off-interpret for ragged T
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, qi, ki: (b, h, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tp, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Tp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running normalizer
        ],
        interpret=interpret,
    )(*inputs)
    if return_residuals:
        return out[:, :, :T], lse[:, :, :T, 0]
    return out[:, :, :T]


def _rope_rotate_hm(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Head-major RoPE: x (B, Hx, T, hd), pos (B, T) -> x.dtype. Same llama
    half-split convention as :func:`_rope_rotate`; negate ``pos`` to rotate
    back (the rotation is orthogonal)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    freqs = jnp.exp(-(jnp.arange(half, dtype=jnp.float32) / half)
                    * math.log(theta))
    ang = pos.astype(jnp.float32)[:, None, :, None] * freqs   # (B, 1, T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(dt)


def flash_attention_rope_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                                pos: jax.Array, *, theta: float,
                                causal: bool = True,
                                window: Optional[int] = None,
                                block_q: int = DEFAULT_BLOCK_Q,
                                block_k: int = DEFAULT_BLOCK_K,
                                return_residuals: bool = False,
                                kv_offsets: Optional[jax.Array] = None,
                                interpret: bool = False
                                ) -> Union[jax.Array,
                                           Tuple[jax.Array, jax.Array]]:
    """Flash attention with the RoPE rotation fused into the q/k loads.

    Same contract as :func:`flash_attention_pallas` plus ``pos`` (B, T)
    positions shared by q and k (self-attention: S == T required) and the
    static rotation base ``theta``. Each q/k tile is rotated in f32 right
    after load, so the separate ``apply_rope`` pass over the full (B, H, T,
    hd) tensors — two extra HBM round-trips — disappears. Positions ride in
    as (B, Tp, 1) f32 blocks (trailing unit axis keeps the sublane-aligned
    tile legal, as for lse).
    """
    B, H, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    if S != T:
        raise ValueError("fused-RoPE attention is self-attention only")
    if hd % 2:
        raise ValueError("RoPE needs an even head dim")
    g = H // KV
    bq, bk = _block_sizes(T, S, block_q, block_k, q.dtype)
    Tp, Sp = _round_up(T, bq), _round_up(S, bk)
    pos_f = jnp.asarray(pos, jnp.float32)
    posq = jnp.pad(pos_f, ((0, 0), (0, Tp - T)))[..., None]
    posk = jnp.pad(pos_f, ((0, 0), (0, Sp - S)))[..., None]
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    grid = (B, H, Tp // bq, Sp // bk)

    has_offsets = kv_offsets is not None
    inputs = (q, k, v)
    extra_specs = []
    if has_offsets:
        inputs = inputs + (jnp.asarray(kv_offsets, jnp.int32).reshape(B, 1),)
        extra_specs = [pl.BlockSpec((1, 1), lambda b, h, qi, ki: (b, 0),
                                    memory_space=pltpu.SMEM)]
    inputs = inputs + (posq, posk)
    extra_specs = extra_specs + [
        pl.BlockSpec((1, bq, 1), lambda b, h, qi, ki: (b, qi, 0)),
        pl.BlockSpec((1, bk, 1), lambda b, h, qi, ki: (b, ki, 0)),
    ]

    out, lse = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
            window=window, block_q=bq, block_k=bk, seq_k=S,
            has_offsets=has_offsets, rope_theta=theta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ] + extra_specs,
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, qi, ki: (b, h, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tp, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Tp, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    if return_residuals:
        return out[:, :, :T], lse[:, :, :T, 0]
    return out[:, :, :T]


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _recompute_p_ds(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    q_start, k_start, *, scale: float, causal: bool,
                    window: Optional[int], seq_k: int):
    """Shared recomputation for both backward kernels: rebuild this block's
    probabilities from the lse residual and form ``ds = p * (dp - delta)``
    (the softmax-backward core). Keeping it in one place keeps the dq and
    dk/dv kernels' masking/scaling in lockstep. Returns (q, k, do, p, ds),
    all f32."""
    q = q_ref[0, 0].astype(jnp.float32)                   # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]                                   # (bq, 1)
    delta = delta_ref[0, 0]                               # (bq, 1)
    s = (q @ k.T) * scale                                 # (bq, bk)
    mask = _visibility_mask(s.shape, q_start, k_start, causal=causal,
                            window=window, seq_k=seq_k)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse)
    dp = do @ v.T                                         # (bq, bk)
    ds = p * (dp - delta)
    return q, k, do, p, ds


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, dq_acc, *, scale: float, causal: bool,
                         window: Optional[int], block_q: int, block_k: int,
                         seq_k: int):
    """dq for one q block, accumulated across kv blocks (innermost axis)."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = qi * block_q
    k_start = ki * block_k
    needed = _band_intersects(q_start, k_start, causal=causal, window=window,
                              block_q=block_q, block_k=block_k)

    @pl.when(needed)
    def _compute():
        _, k, _, _, ds = _recompute_p_ds(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, q_start,
            k_start, scale=scale, causal=causal, window=window, seq_k=seq_k)
        dq_acc[...] += (ds @ k) * scale

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                          causal: bool, window: Optional[int], block_q: int,
                          block_k: int, seq_k: int):
    """Per-q-head dk/dv for one kv block, accumulated across q blocks
    (innermost axis). GQA groups are summed outside the kernel."""
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = qi * block_q
    k_start = ki * block_k
    needed = _band_intersects(q_start, k_start, causal=causal, window=window,
                              block_q=block_q, block_k=block_k)

    @pl.when(needed)
    def _compute():
        q, _, do, p, ds = _recompute_p_ds(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, q_start,
            k_start, scale=scale, causal=causal, window=window, seq_k=seq_k)
        dv_acc[...] += p.T @ do                           # (bk, hd)
        dk_acc[...] += (ds.T @ q) * scale

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


# dq rides as a full (1, 1, Tp, hd) output block in the fused backward; cap
# its VMEM footprint (acc itemsize * Tp * hd) or fall back to the two-kernel
# path
_FUSED_BWD_DQ_VMEM_BYTES = 1 << 21


def _flash_bwd_fused_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                            dq_ref, dk_ref, dv_ref, dk_acc, dv_acc, *,
                            scale: float, causal: bool,
                            window: Optional[int], block_q: int,
                            block_k: int, seq_k: int):
    """One recomputation feeding BOTH accumulators. Grid (B, H, n_kv, n_q),
    q innermost: dk/dv accumulate in VMEM scratch exactly as in
    ``_flash_bwd_dkv_kernel``, while dq accumulates into a full-(Tp, hd)
    output block whose index map is constant over (ki, qi) — the block is
    resident in VMEM for the whole (b, h) sweep (consecutive revisits), so
    each (q, kv) pair's ``p``/``ds`` recompute — the expensive half of the
    backward — happens once instead of twice."""
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(jnp.logical_and(ki == 0, qi == 0))
    def _init_dq():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    @pl.when(qi == 0)
    def _init_kv():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = qi * block_q
    k_start = ki * block_k
    needed = _band_intersects(q_start, k_start, causal=causal, window=window,
                              block_q=block_q, block_k=block_k)

    @pl.when(needed)
    def _compute():
        q, k, do, p, ds = _recompute_p_ds(
            q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, q_start,
            k_start, scale=scale, causal=causal, window=window, seq_k=seq_k)
        dv_acc[...] += (p.T @ do).astype(dv_acc.dtype)
        dk_acc[...] += ((ds.T @ q) * scale).astype(dk_acc.dtype)
        dq_ref[0, 0, pl.ds(q_start, block_q), :] += (
            (ds @ k) * scale).astype(dq_ref.dtype)

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_backward_pallas(
        q: jax.Array, k: jax.Array, v: jax.Array, o: jax.Array,
        lse: jax.Array, do: jax.Array, *, causal: bool = True,
        window: Optional[int] = None, block_q: int = DEFAULT_BLOCK_Q,
        block_k: int = DEFAULT_BLOCK_K, fuse_dq: Optional[bool] = None,
        acc_dtype=jnp.float32, interpret: bool = False
        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """VJP of :func:`flash_attention_pallas` w.r.t. (q, k, v).

    q, o, do: (B, H, T, hd); k, v: (B, KV, S, hd); lse: (B, H, T) f32 (the
    forward's logsumexp residual). Returns (dq, dk, dv) in the input dtypes.

    Standard recomputation backward: ``delta = rowsum(do * o)`` is one cheap
    elementwise pass outside the kernels; the probability blocks are rebuilt
    from ``lse`` inside each kernel, so no (T, S)-sized tensor is ever
    materialised.

    ``fuse_dq=None`` (auto) picks the single-kernel fused path — one
    ``p``/``ds`` recompute feeding dq AND dk/dv — whenever the full dq block
    (``Tp * hd`` in ``acc_dtype``) fits the VMEM budget, else the original
    two-kernel split (which recomputes each block pair twice).
    ``acc_dtype`` sets the fused path's accumulator precision (the bf16
    accumulation study in docs/kernels.md uses ``jnp.bfloat16`` here).
    """
    B, H, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    bq, bk = _block_sizes(T, S, block_q, block_k, q.dtype)
    Tp, Sp = _round_up(T, bq), _round_up(S, bk)

    # per-row terms carry a trailing unit axis so bq stays on the sublane
    # axis of their blocks (see the forward's lse out_spec)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)[..., None]
    lse = lse[..., None]
    if Tp != T:
        pad_t = ((0, 0), (0, 0), (0, Tp - T), (0, 0))
        q = jnp.pad(q, pad_t)
        do = jnp.pad(do, pad_t)
        lse = jnp.pad(lse, pad_t)
        delta = jnp.pad(delta, pad_t)
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))

    # transposed grid: kv blocks outer, q blocks innermost so the dk/dv
    # accumulators persist in VMEM across q steps
    qT_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, ki, qi: (b, h, qi, 0))
    kvT_spec = pl.BlockSpec((1, 1, bk, hd),
                            lambda b, h, ki, qi: (b, h // g, ki, 0))
    rowT_spec = pl.BlockSpec((1, 1, bq, 1),
                             lambda b, h, ki, qi: (b, h, qi, 0))
    dkvT_spec = pl.BlockSpec((1, 1, bk, hd),
                             lambda b, h, ki, qi: (b, h, ki, 0))

    if fuse_dq is None:
        fuse_dq = (Tp * hd * jnp.dtype(acc_dtype).itemsize
                   <= _FUSED_BWD_DQ_VMEM_BYTES)

    if fuse_dq:
        dq_full_spec = pl.BlockSpec((1, 1, Tp, hd),
                                    lambda b, h, ki, qi: (b, h, 0, 0))
        dqh, dkh, dvh = pl.pallas_call(
            functools.partial(
                _flash_bwd_fused_kernel, scale=scale, causal=causal,
                window=window, block_q=bq, block_k=bk, seq_k=S),
            grid=(B, H, Sp // bk, Tp // bq),
            in_specs=[qT_spec, kvT_spec, kvT_spec, qT_spec, rowT_spec,
                      rowT_spec],
            out_specs=[dq_full_spec, dkvT_spec, dkvT_spec],
            out_shape=[jax.ShapeDtypeStruct((B, H, Tp, hd), acc_dtype),
                       jax.ShapeDtypeStruct((B, H, Sp, hd), acc_dtype),
                       jax.ShapeDtypeStruct((B, H, Sp, hd), acc_dtype)],
            scratch_shapes=[pltpu.VMEM((bk, hd), acc_dtype),
                            pltpu.VMEM((bk, hd), acc_dtype)],
            interpret=interpret,
        )(q, k, v, do, lse, delta)
        dq = dqh.astype(q.dtype)
    else:
        q_spec = pl.BlockSpec((1, 1, bq, hd),
                              lambda b, h, qi, ki: (b, h, qi, 0))
        kv_spec = pl.BlockSpec((1, 1, bk, hd),
                               lambda b, h, qi, ki: (b, h // g, ki, 0))
        row_spec = pl.BlockSpec((1, 1, bq, 1),
                                lambda b, h, qi, ki: (b, h, qi, 0))

        dq = pl.pallas_call(
            functools.partial(
                _flash_bwd_dq_kernel, scale=scale, causal=causal,
                window=window, block_q=bq, block_k=bk, seq_k=S),
            grid=(B, H, Tp // bq, Sp // bk),
            in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
            out_specs=q_spec,
            out_shape=jax.ShapeDtypeStruct((B, H, Tp, hd), q.dtype),
            scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
            interpret=interpret,
        )(q, k, v, do, lse, delta)

        dkh, dvh = pl.pallas_call(
            functools.partial(
                _flash_bwd_dkv_kernel, scale=scale, causal=causal,
                window=window, block_q=bq, block_k=bk, seq_k=S),
            grid=(B, H, Sp // bk, Tp // bq),
            in_specs=[qT_spec, kvT_spec, kvT_spec, qT_spec, rowT_spec,
                      rowT_spec],
            out_specs=[dkvT_spec, dkvT_spec],
            out_shape=[jax.ShapeDtypeStruct((B, H, Sp, hd), jnp.float32),
                       jax.ShapeDtypeStruct((B, H, Sp, hd), jnp.float32)],
            scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                            pltpu.VMEM((bk, hd), jnp.float32)],
            interpret=interpret,
        )(q, k, v, do, lse, delta)

    # GQA: sum the per-q-head cotangents over each q-head group
    dk = dkh.reshape(B, KV, g, Sp, hd).sum(axis=2)[:, :, :S].astype(k.dtype)
    dv = dvh.reshape(B, KV, g, Sp, hd).sum(axis=2)[:, :, :S].astype(v.dtype)
    return dq[:, :, :T], dk, dv


def flash_attention_rope_backward_pallas(
        q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array,
        o: jax.Array, lse: jax.Array, do: jax.Array, *, theta: float,
        causal: bool = True, window: Optional[int] = None,
        block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
        interpret: bool = False) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """VJP of :func:`flash_attention_rope_pallas` w.r.t. (q, k, v).

    The rotation is orthogonal and position-wise, so the chain rule factors
    cleanly around the shared backward kernels: rotate q/k by +theta once
    outside (a cheap elementwise recompute — the unrotated q/k are the saved
    residuals), run :func:`flash_attention_backward_pallas` on the rotated
    inputs, then rotate the resulting dq/dk back by -theta
    (``R(-theta) = R(theta)^T``). dv is untouched by RoPE.
    """
    qr = _rope_rotate_hm(q, pos, theta)
    kr = _rope_rotate_hm(k, pos, theta)
    dqr, dkr, dv = flash_attention_backward_pallas(
        qr, kr, v, o, lse, do, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    dq = _rope_rotate_hm(dqr, -jnp.asarray(pos, jnp.float32), theta)
    dk = _rope_rotate_hm(dkr, -jnp.asarray(pos, jnp.float32), theta)
    return dq, dk, dv
