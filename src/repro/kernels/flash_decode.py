"""Pallas TPU flash-decode: single-query-row attention against a
seq_len-deep KV cache — the serving hot path (decode_32k / long_500k).

One new token per sequence attends every cached key: there is no q-block
axis to tile, so the kernel streams KV blocks under an online-softmax
accumulator exactly like the training flash forward, but with a (g, hd)
query tile per kv head (g = H // KV, the GQA group — all q-heads that share
a kv head are processed together, so K/V blocks are read once per kv head).

Grid: (B, KV, n_kv_blocks) — the kv-block axis is innermost, so the running
max / normalizer / output accumulator live in VMEM scratch across kv steps
and the output tile is written once on the final step. The current position
``pos`` and the optional per-sequence left-pad ``offsets`` are dynamic
**per-row (B,) SMEM refs** (a scalar ``pos`` is broadcast): every sequence
in the batch may sit at a different depth — the continuous-batching engine's
rows do — and blocks entirely beyond that row's ``pos`` are skipped with
``pl.when``; at position p only ceil((p+1)/block_k) of the cache's
n_kv_blocks are touched, which is what makes the seq_len-deep cache
affordable early in the sequence.

Cache layouts:

- full attention: head-major ``(B, KV, S, hd)`` where slot ``s`` holds
  global position ``s`` (``ring=False``);
- sliding-window: the same shape but a ring buffer of ``S = min(max_len,
  window)`` slots where slot ``s`` holds global position
  ``pos - ((pos - s) mod S)`` (``ring=True``) — the slot->position map is
  evaluated inside the kernel so masking works pre- and post-wrap.

Visibility of a slot with global position g:  ``0 <= g <= pos``, and
``g > pos - window`` when a window is given, and ``g >= offsets[b]`` for
left-padded ragged prompts.

Serving is forward-only: there is no backward kernel (decode takes no
gradients). Public entry: :func:`repro.kernels.ops.flash_decode`; oracle:
:func:`repro.kernels.ref.flash_decode_ref`.

Off TPU, :func:`flash_decode_blockwise` is the serving lowering: the SAME
blockwise online-softmax program as a ``lax.scan`` over KV blocks.
Interpret-mode ``pallas_call`` pays a per-grid-step emulation cost
proportional to the full operand size — on a seq_len-deep cache that is
exactly the cost the kernel exists to avoid, so the hot serving path does
not run it (the kernel itself is validated against the oracle via
``interpret=True`` in tests/test_serving.py).

**Paged cache** (:func:`flash_decode_paged_pallas` /
:func:`flash_decode_paged_blockwise`): K/V live in a pool of fixed-size
pages ``(n_pages, KV, page_size, hd)`` and each row owns a block table
``pt (B, n_blocks)`` mapping its logical block i (slots
[i*page_size, (i+1)*page_size)) to a physical page. The kernel gathers by
block table via scalar-prefetch index maps (the page id picks the k/v
block to DMA); the blockwise lowering gathers one page per scan step —
neither ever materialises a row's cache contiguously. Visibility is the
same ``_slot_visibility`` predicate over logical slot indices, so a paged
row is bit-identical to the contiguous layout (fully-masked pages are
exact no-ops under the online softmax). Long-context rows then reserve
pages as they grow instead of worst-case contiguous memory.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import (NEG_INF, _rope_rotate,
                                           _rope_rotate_hm, _round_up,
                                           _sublane)

DEFAULT_BLOCK_K = 512


def _slot_visibility(slot, pos, *, seq_k: int, window: Optional[int],
                     ring: bool, offset=None):
    """Visibility of cache slots at query position ``pos`` — the ONE
    predicate shared by the Pallas kernel body, the blockwise CPU lowering,
    and (in spirit) the jnp oracle. ``slot`` is an int32 array of slot
    indices; ``offset`` an optional broadcastable left-pad bound."""
    if ring:
        gpos = pos - jnp.mod(pos - slot, seq_k)
    else:
        gpos = slot
    mask = (slot < seq_k) & (gpos >= 0) & (gpos <= pos)
    if window is not None:
        mask &= gpos > pos - window
    if offset is not None:
        mask = mask & (gpos >= offset)
    return mask


def _flash_decode_kernel(pos_ref, off_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float,
                         window: Optional[int], ring: bool, seq_k: int,
                         block_k: int, has_offsets: bool,
                         rope_theta: Optional[float] = None):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0, 0]
    k_start = ki * block_k
    # dynamic block skip: a full-layout block is dead if its first slot is
    # beyond pos (causal) or its last slot is older than the window. Ring
    # slots have no monotone slot->position map, so ring never skips (the
    # ring is at most window slots deep anyway).
    if ring:
        needed = jnp.bool_(True)
    else:
        needed = k_start <= pos
        if window is not None:
            needed = jnp.logical_and(needed,
                                     k_start + block_k - 1 > pos - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (g, hd)
        if rope_theta is not None:
            # cached keys are rotated at write time; only the fresh query
            # row still needs its rotation — fused here, by the row's
            # logical position (pos minus any left pad)
            qpos = pos - (off_ref[0, 0] if has_offsets else 0)
            q = _rope_rotate(
                q, jnp.zeros((q.shape[0], 1), jnp.float32) + qpos,
                rope_theta)
        q = q * scale
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                       # (g, bk)
        slot = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = _slot_visibility(
            slot, pos, seq_k=seq_k, window=window, ring=ring,
            offset=off_ref[0, 0] if has_offsets else None)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        pos: jax.Array, *, window: Optional[int] = None,
                        ring: bool = False,
                        offsets: Optional[jax.Array] = None,
                        rope_theta: Optional[float] = None,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k, v: (B, KV, S, hd) head-major cache -> (B, H, hd).

    ``pos`` is the (dynamic) global position of each row's query token —
    a scalar (every row at the same depth, the static-batch engine) or a
    ``(B,)`` vector (continuous batching: one depth per row). Slots whose
    global position falls outside [max(offset, pos_b-window+1), pos_b] are
    masked, where the slot->position map is the identity (``ring=False``) or
    the ring-buffer map (``ring=True``, S = ring depth). ``offsets`` (B,)
    masks the left padding of ragged prompts.

    ``rope_theta`` fuses the query's RoPE rotation (by ``pos - offset``)
    into the kernel — q arrives UNROTATED; cached keys are rotated at
    write time as before.
    """
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    sub = max(_sublane(q.dtype), _sublane(k.dtype))
    bk = _round_up(min(block_k, max(S, sub)), sub)
    Sp = _round_up(S, bk)
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    qg = q.reshape(B, KV, g, hd)
    # per-row (B, 1) SMEM refs; a scalar pos broadcasts to every row
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                               (B,)).reshape(B, 1)
    has_offsets = offsets is not None
    if has_offsets:
        off_arr = jnp.asarray(offsets, jnp.int32).reshape(B, 1)
    else:
        off_arr = jnp.zeros((1, 1), jnp.int32)
    off_spec = pl.BlockSpec(
        (1, 1), (lambda b, h, ki: (b, 0)) if has_offsets
        else (lambda b, h, ki: (0, 0)), memory_space=pltpu.SMEM)

    out = pl.pallas_call(
        functools.partial(
            _flash_decode_kernel, scale=1.0 / math.sqrt(hd), window=window,
            ring=ring, seq_k=S, block_k=bk, has_offsets=has_offsets,
            rope_theta=rope_theta),
        grid=(B, KV, Sp // bk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0),
                         memory_space=pltpu.SMEM),
            off_spec,
            pl.BlockSpec((1, 1, g, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((g, 1), jnp.float32),    # running max
            pltpu.VMEM((g, 1), jnp.float32),    # running normalizer
        ],
        interpret=interpret,
    )(pos_arr, off_arr, qg, k, v)
    return out.reshape(B, H, hd)


def flash_decode_blockwise(q: jax.Array, k: jax.Array, v: jax.Array,
                           pos: jax.Array, *, window: Optional[int] = None,
                           ring: bool = False,
                           offsets: Optional[jax.Array] = None,
                           rope_theta: Optional[float] = None,
                           block_k: int = 2048) -> jax.Array:
    """Pure-jnp lowering of the same blockwise online-softmax program the
    Pallas kernel runs: a ``lax.scan`` over KV blocks carrying (m, l, acc),
    with the identical :func:`_slot_visibility` predicate. The off-TPU
    serving path (see module docstring)."""
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    if rope_theta is not None:
        qpos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
        if offsets is not None:
            qpos = qpos - jnp.asarray(offsets, jnp.int32).reshape(-1)
        q = _rope_rotate_hm(q[:, :, None, :],
                            jnp.broadcast_to(qpos[:, None], (B, 1)),
                            rope_theta)[:, :, 0, :]
    bk = min(block_k, S)
    Sp = _round_up(S, bk)
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    nk = Sp // bk
    qg = (q.astype(jnp.float32).reshape(B, KV, g, hd)
          * (1.0 / math.sqrt(hd)))
    kb = k.reshape(B, KV, nk, bk, hd).swapaxes(0, 2).swapaxes(1, 2)
    vb = v.reshape(B, KV, nk, bk, hd).swapaxes(0, 2).swapaxes(1, 2)
    off = None if offsets is None else offsets[:, None, None, None]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim:                      # per-row (B,) -> broadcast over heads
        pos = pos.reshape(B, 1, 1, 1)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, ki = inp                              # (B, KV, bk, hd)
        s = jnp.einsum("bkgd,bksd->bkgs", qg, kblk.astype(jnp.float32))
        slot = ki * bk + jnp.arange(bk)
        mask = _slot_visibility(slot[None, None, None, :], pos, seq_k=S,
                                window=window, ring=ring, offset=off)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(-1)
        acc = (alpha[..., None] * acc
               + jnp.einsum("bkgs,bksd->bkgd", p, vblk.astype(jnp.float32)))
        return (m_new, l, acc), None

    init = (jnp.full((B, KV, g), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, g), jnp.float32),
            jnp.zeros((B, KV, g, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init,
                                  (kb, vb, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# paged cache (block-table gather)
# ---------------------------------------------------------------------------


def _flash_decode_paged_kernel(pt_ref, pos_ref, off_ref, q_ref, k_ref, v_ref,
                               *rest, scale: float,
                               window: Optional[int], page_size: int,
                               n_blocks: int, has_offsets: bool,
                               quantized: bool = False,
                               rope_theta: Optional[float] = None):
    rest = list(rest)
    ks_ref = vs_ref = None
    if quantized:
        ks_ref = rest.pop(0)
        vs_ref = rest.pop(0)
    o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    i = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]
    k_start = i * page_size
    # logical pages are monotone in position (no ring), so a page whose
    # first slot is beyond pos, or whose last slot predates the window, is
    # skipped. The DMA itself still lands on a valid physical page — an
    # unallocated logical block's table entry is the reserved trash page 0.
    needed = k_start <= pos
    if window is not None:
        needed = jnp.logical_and(needed,
                                 k_start + page_size - 1 > pos - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (g, hd)
        if rope_theta is not None:
            qpos = pos - (off_ref[b] if has_offsets else 0)
            q = _rope_rotate(
                q, jnp.zeros((q.shape[0], 1), jnp.float32) + qpos,
                rope_theta)
        q = q * scale
        k = k_ref[0, 0].astype(jnp.float32)               # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            # per-slot scales (ps, 1) broadcast over hd: int8 pages
            # dequantize in VMEM, right at the load
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = q @ k.T                                       # (g, ps)
        slot = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = _slot_visibility(
            slot, pos, seq_k=n_blocks * page_size, window=window,
            ring=False, offset=off_ref[b] if has_offsets else None)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(i == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode_paged_pallas(q: jax.Array, kp: jax.Array, vp: jax.Array,
                              pt: jax.Array, pos: jax.Array, *,
                              window: Optional[int] = None,
                              offsets: Optional[jax.Array] = None,
                              k_scale: Optional[jax.Array] = None,
                              v_scale: Optional[jax.Array] = None,
                              rope_theta: Optional[float] = None,
                              interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); kp, vp: (n_pages, KV, page_size, hd) physical page
    pool; pt: (B, n_blocks) int32 block table -> (B, H, hd).

    Row b's logical slots [i*page_size, (i+1)*page_size) live in physical
    page ``pt[b, i]``. The block table, per-row ``pos`` and per-row
    ``offsets`` ride in as scalar-prefetch refs so the k/v BlockSpec index
    maps can pick the physical page to DMA per grid step — the gather IS
    the index map; no contiguous copy of the row's cache ever exists.
    Grid: (B, KV, n_blocks) with the page axis innermost (online softmax
    over logical pages in order). Ring buffers are not paged (SWA caches
    are window-bounded); ``ring`` is intentionally absent.

    ``k_scale``/``v_scale`` (n_pages, KV, page_size) f32 mark an int8 pool:
    kp/vp hold int8 codes and each slot's row dequantizes in VMEM right at
    the load (``k = kp * k_scale``), so the HBM traffic per page is half
    (plus the scale sidecar). ``rope_theta`` fuses the query rotation as in
    :func:`flash_decode_pallas`.
    """
    B, H, hd = q.shape
    n_pages, KV, ps = kp.shape[0], kp.shape[1], kp.shape[2]
    NB = pt.shape[1]
    g = H // KV
    qg = q.reshape(B, KV, g, hd)
    pt_arr = jnp.asarray(pt, jnp.int32)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    has_offsets = offsets is not None
    off_arr = (jnp.asarray(offsets, jnp.int32).reshape(B) if has_offsets
               else jnp.zeros((B,), jnp.int32))
    quantized = k_scale is not None

    page_spec = pl.BlockSpec((1, 1, ps, hd),
                             lambda b, h, i, pt, pos, off: (pt[b, i], h, 0, 0))
    in_specs = [
        pl.BlockSpec((1, 1, g, hd),
                     lambda b, h, i, pt, pos, off: (b, h, 0, 0)),
        page_spec,
        page_spec,
    ]
    inputs = [qg, kp, vp]
    if quantized:
        # scales follow the same page gather; trailing unit axis keeps the
        # sublane-aligned page_size off the lane axis (see lse in the
        # training forward)
        scale_spec = pl.BlockSpec(
            (1, 1, ps, 1), lambda b, h, i, pt, pos, off: (pt[b, i], h, 0, 0))
        in_specs += [scale_spec, scale_spec]
        inputs += [k_scale.reshape(n_pages, KV, ps, 1),
                   v_scale.reshape(n_pages, KV, ps, 1)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KV, NB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b, h, i, pt, pos, off: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((g, 1), jnp.float32),    # running max
            pltpu.VMEM((g, 1), jnp.float32),    # running normalizer
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _flash_decode_paged_kernel, scale=1.0 / math.sqrt(hd),
            window=window, page_size=ps, n_blocks=NB,
            has_offsets=has_offsets, quantized=quantized,
            rope_theta=rope_theta),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(pt_arr, pos_arr, off_arr, *inputs)
    return out.reshape(B, H, hd)


def flash_decode_paged_blockwise(q: jax.Array, kp: jax.Array, vp: jax.Array,
                                 pt: jax.Array, pos: jax.Array, *,
                                 window: Optional[int] = None,
                                 offsets: Optional[jax.Array] = None,
                                 k_scale: Optional[jax.Array] = None,
                                 v_scale: Optional[jax.Array] = None,
                                 rope_theta: Optional[float] = None
                                 ) -> jax.Array:
    """Pure-jnp lowering of the paged kernel: a ``lax.scan`` over logical
    blocks, gathering ONE page per row per step (``kp[pt[:, i]]``) under the
    same online-softmax carry and :func:`_slot_visibility` predicate. The
    off-TPU serving path for paged caches — peak memory per step is one
    page per row, never the full gathered cache. ``k_scale``/``v_scale``
    mark an int8 pool (dequantized per gathered page); ``rope_theta`` fuses
    the query rotation."""
    B, H, hd = q.shape
    KV, ps = kp.shape[1], kp.shape[2]
    NB = pt.shape[1]
    g = H // KV
    if rope_theta is not None:
        qpos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
        if offsets is not None:
            qpos = qpos - jnp.asarray(offsets, jnp.int32).reshape(-1)
        q = _rope_rotate_hm(q[:, :, None, :],
                            jnp.broadcast_to(qpos[:, None], (B, 1)),
                            rope_theta)[:, :, 0, :]
    qg = (q.astype(jnp.float32).reshape(B, KV, g, hd)
          * (1.0 / math.sqrt(hd)))
    off = None if offsets is None else offsets[:, None, None, None]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                           (B,)).reshape(B, 1, 1, 1)

    def body(carry, inp):
        m, l, acc = carry
        page_ids, i = inp                              # (B,), ()
        kblk = kp[page_ids].astype(jnp.float32)        # (B, KV, ps, hd)
        vblk = vp[page_ids].astype(jnp.float32)
        if k_scale is not None:
            kblk = kblk * k_scale[page_ids][..., None]
            vblk = vblk * v_scale[page_ids][..., None]
        s = jnp.einsum("bkgd,bksd->bkgs", qg, kblk)
        slot = i * ps + jnp.arange(ps)
        mask = _slot_visibility(slot[None, None, None, :], pos,
                                seq_k=NB * ps, window=window, ring=False,
                                offset=off)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(-1)
        acc = (alpha[..., None] * acc
               + jnp.einsum("bkgs,bksd->bkgd", p, vblk))
        return (m_new, l, acc), None

    init = (jnp.full((B, KV, g), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, g), jnp.float32),
            jnp.zeros((B, KV, g, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        body, init, (jnp.asarray(pt, jnp.int32).T, jnp.arange(NB)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, hd).astype(q.dtype)
