"""Pallas TPU flash-decode: single-query-row attention against a
seq_len-deep KV cache — the serving hot path (decode_32k / long_500k).

One new token per sequence attends every cached key: there is no q-block
axis to tile, so the kernel streams KV blocks under an online-softmax
accumulator exactly like the training flash forward, but with a (g, hd)
query tile per kv head (g = H // KV, the GQA group — all q-heads that share
a kv head are processed together, so K/V blocks are read once per kv head).

Grid: (B, KV, n_kv_blocks) — the kv-block axis is innermost, so the running
max / normalizer / output accumulator live in VMEM scratch across kv steps
and the output tile is written once on the final step. The current position
``pos`` and the optional per-sequence left-pad ``offsets`` are dynamic
scalars (SMEM): blocks entirely beyond ``pos`` are skipped with ``pl.when``
— at position p only ceil((p+1)/block_k) of the cache's n_kv_blocks are
touched, which is what makes the seq_len-deep cache affordable early in the
sequence.

Cache layouts:

- full attention: head-major ``(B, KV, S, hd)`` where slot ``s`` holds
  global position ``s`` (``ring=False``);
- sliding-window: the same shape but a ring buffer of ``S = min(max_len,
  window)`` slots where slot ``s`` holds global position
  ``pos - ((pos - s) mod S)`` (``ring=True``) — the slot->position map is
  evaluated inside the kernel so masking works pre- and post-wrap.

Visibility of a slot with global position g:  ``0 <= g <= pos``, and
``g > pos - window`` when a window is given, and ``g >= offsets[b]`` for
left-padded ragged prompts.

Serving is forward-only: there is no backward kernel (decode takes no
gradients). Public entry: :func:`repro.kernels.ops.flash_decode`; oracle:
:func:`repro.kernels.ref.flash_decode_ref`.

Off TPU, :func:`flash_decode_blockwise` is the serving lowering: the SAME
blockwise online-softmax program as a ``lax.scan`` over KV blocks.
Interpret-mode ``pallas_call`` pays a per-grid-step emulation cost
proportional to the full operand size — on a seq_len-deep cache that is
exactly the cost the kernel exists to avoid, so the hot serving path does
not run it (the kernel itself is validated against the oracle via
``interpret=True`` in tests/test_serving.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import (NEG_INF, _round_up, _sublane)

DEFAULT_BLOCK_K = 512


def _slot_visibility(slot, pos, *, seq_k: int, window: Optional[int],
                     ring: bool, offset=None):
    """Visibility of cache slots at query position ``pos`` — the ONE
    predicate shared by the Pallas kernel body, the blockwise CPU lowering,
    and (in spirit) the jnp oracle. ``slot`` is an int32 array of slot
    indices; ``offset`` an optional broadcastable left-pad bound."""
    if ring:
        gpos = pos - jnp.mod(pos - slot, seq_k)
    else:
        gpos = slot
    mask = (slot < seq_k) & (gpos >= 0) & (gpos <= pos)
    if window is not None:
        mask &= gpos > pos - window
    if offset is not None:
        mask = mask & (gpos >= offset)
    return mask


def _flash_decode_kernel(pos_ref, off_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float,
                         window: Optional[int], ring: bool, seq_k: int,
                         block_k: int, has_offsets: bool):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0, 0]
    k_start = ki * block_k
    # dynamic block skip: a full-layout block is dead if its first slot is
    # beyond pos (causal) or its last slot is older than the window. Ring
    # slots have no monotone slot->position map, so ring never skips (the
    # ring is at most window slots deep anyway).
    if ring:
        needed = jnp.bool_(True)
    else:
        needed = k_start <= pos
        if window is not None:
            needed = jnp.logical_and(needed,
                                     k_start + block_k - 1 > pos - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (g, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                       # (g, bk)
        slot = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = _slot_visibility(
            slot, pos, seq_k=seq_k, window=window, ring=ring,
            offset=off_ref[0, 0] if has_offsets else None)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                               # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        pos: jax.Array, *, window: Optional[int] = None,
                        ring: bool = False,
                        offsets: Optional[jax.Array] = None,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k, v: (B, KV, S, hd) head-major cache -> (B, H, hd).

    ``pos`` is the (dynamic) global position of the query token; slots whose
    global position falls outside [max(offset, pos-window+1), pos] are
    masked, where the slot->position map is the identity (``ring=False``) or
    the ring-buffer map (``ring=True``, S = ring depth). ``offsets`` (B,)
    masks the left padding of ragged prompts.
    """
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    sub = max(_sublane(q.dtype), _sublane(k.dtype))
    bk = _round_up(min(block_k, max(S, sub)), sub)
    Sp = _round_up(S, bk)
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    qg = q.reshape(B, KV, g, hd)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1, 1)
    has_offsets = offsets is not None
    if has_offsets:
        off_arr = jnp.asarray(offsets, jnp.int32).reshape(B, 1)
    else:
        off_arr = jnp.zeros((1, 1), jnp.int32)
    off_spec = pl.BlockSpec(
        (1, 1), (lambda b, h, ki: (b, 0)) if has_offsets
        else (lambda b, h, ki: (0, 0)), memory_space=pltpu.SMEM)

    out = pl.pallas_call(
        functools.partial(
            _flash_decode_kernel, scale=1.0 / math.sqrt(hd), window=window,
            ring=ring, seq_k=S, block_k=bk, has_offsets=has_offsets),
        grid=(B, KV, Sp // bk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, ki: (0, 0),
                         memory_space=pltpu.SMEM),
            off_spec,
            pl.BlockSpec((1, 1, g, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((g, 1), jnp.float32),    # running max
            pltpu.VMEM((g, 1), jnp.float32),    # running normalizer
        ],
        interpret=interpret,
    )(pos_arr, off_arr, qg, k, v)
    return out.reshape(B, H, hd)


def flash_decode_blockwise(q: jax.Array, k: jax.Array, v: jax.Array,
                           pos: jax.Array, *, window: Optional[int] = None,
                           ring: bool = False,
                           offsets: Optional[jax.Array] = None,
                           block_k: int = 2048) -> jax.Array:
    """Pure-jnp lowering of the same blockwise online-softmax program the
    Pallas kernel runs: a ``lax.scan`` over KV blocks carrying (m, l, acc),
    with the identical :func:`_slot_visibility` predicate. The off-TPU
    serving path (see module docstring)."""
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    bk = min(block_k, S)
    Sp = _round_up(S, bk)
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    nk = Sp // bk
    qg = (q.astype(jnp.float32).reshape(B, KV, g, hd)
          * (1.0 / math.sqrt(hd)))
    kb = k.reshape(B, KV, nk, bk, hd).swapaxes(0, 2).swapaxes(1, 2)
    vb = v.reshape(B, KV, nk, bk, hd).swapaxes(0, 2).swapaxes(1, 2)
    off = None if offsets is None else offsets[:, None, None, None]

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, ki = inp                              # (B, KV, bk, hd)
        s = jnp.einsum("bkgd,bksd->bkgs", qg, kblk.astype(jnp.float32))
        slot = ki * bk + jnp.arange(bk)
        mask = _slot_visibility(slot[None, None, None, :], pos, seq_k=S,
                                window=window, ring=ring, offset=off)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(-1)
        acc = (alpha[..., None] * acc
               + jnp.einsum("bkgs,bksd->bkgd", p, vblk.astype(jnp.float32)))
        return (m_new, l, acc), None

    init = (jnp.full((B, KV, g), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, g), jnp.float32),
            jnp.zeros((B, KV, g, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init,
                                  (kb, vb, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, hd).astype(q.dtype)
