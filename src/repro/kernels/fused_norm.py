"""Pallas TPU kernel for fused residual-add + RMSNorm.

The transformer block's second sublayer boundary does two passes over the
(B*T, d) activations: ``s = x + y_mixer`` (residual add) then
``h = rmsnorm(s) * scale``. This kernel folds both into ONE pass: each
row tile is read once, the residual sum ``s`` (the new residual stream —
a live output, it feeds the next sublayer's add) and the normalized ``h``
are written together, halving the HBM round-trips at the sublayer seam.

Layout: rows = flattened (B*T) on the sublane axis (tiled), the full
``d_model`` axis on the lane axis — ``d`` must be a 128-multiple
(``ops._fused_tile`` gates this; non-aligned widths fall back to the jnp
oracle with a one-time warning). Rows are zero-padded to the row tile.

Backward (`rmsnorm_residual_backward_pallas`): one pass over the same
grid. Both forward outputs carry live cotangents (``dy`` on the normed
activations, ``ds`` on the emitted residual stream). With
``rv = rsqrt(mean(s^2) + eps)``, ``s_hat = s * rv`` and ``w = dy * scale``:

    dx = dr = rv * w - rv * s_hat * mean(w * s_hat) + ds

and ``dscale = sum_rows(dy * s_hat)`` accumulates across row tiles
directly in a ``(1, d)`` output block whose index map is constant over
the row-tile grid axis (the consecutive-revisit pattern of the GBN
reduction). Residuals saved: ``(s, scale)`` — nothing beyond the live
residual stream.

Public entry: :func:`repro.kernels.ops.rmsnorm_residual` (custom_vjp).
Oracle: :func:`repro.kernels.ref.rmsnorm_residual_ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 128


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _fwd_kernel(x_ref, r_ref, scale_ref, y_ref, s_ref, *, eps: float):
    s = x_ref[...] + r_ref[...]                 # residual add, input dtype
    s_ref[...] = s
    sf = s.astype(jnp.float32)
    var = jnp.mean(sf * sf, axis=-1, keepdims=True)
    y = sf * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)


def _bwd_kernel(s_ref, scale_ref, dy_ref, ds_ref, dx_ref, dscale_ref, *,
                eps: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dscale_ref[...] = jnp.zeros_like(dscale_ref)

    sf = s_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    rv = jax.lax.rsqrt(jnp.mean(sf * sf, axis=-1, keepdims=True) + eps)
    s_hat = sf * rv
    w = dy * scale_ref[...].astype(jnp.float32)
    ds_norm = rv * (w - s_hat * jnp.mean(w * s_hat, axis=-1, keepdims=True))
    dx = ds_norm + ds_ref[...].astype(jnp.float32)
    dx_ref[...] = dx.astype(dx_ref.dtype)
    # padded rows have dy == 0, so they add nothing here
    dscale_ref[...] += jnp.sum(dy * s_hat, axis=0, keepdims=True)


def rmsnorm_residual_pallas(x: jax.Array, r: jax.Array, scale: jax.Array, *,
                            eps: float = 1e-6,
                            row_tile: int = DEFAULT_ROW_TILE,
                            interpret: bool = False
                            ) -> Tuple[jax.Array, jax.Array]:
    """x, r: (N, d) with d a 128-multiple; scale: (d,).

    Returns (y = rmsnorm(x + r) * scale, s = x + r), both (N, d) in
    x.dtype.
    """
    N, d = x.shape
    xp = _pad_rows(x, row_tile)
    rp = _pad_rows(r, row_tile)
    nr = xp.shape[0] // row_tile
    row_spec = pl.BlockSpec((row_tile, d), lambda i: (i, 0))
    y, s = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(nr,),
        in_specs=[row_spec, row_spec,
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct(xp.shape, x.dtype),
                   jax.ShapeDtypeStruct(xp.shape, x.dtype)],
        interpret=interpret,
    )(xp, rp, scale.reshape(1, d))
    return y[:N], s[:N]


def rmsnorm_residual_backward_pallas(s: jax.Array, scale: jax.Array,
                                     dy: jax.Array, ds: jax.Array, *,
                                     eps: float = 1e-6,
                                     row_tile: int = DEFAULT_ROW_TILE,
                                     interpret: bool = False
                                     ) -> Tuple[jax.Array, jax.Array]:
    """VJP of :func:`rmsnorm_residual_pallas` from the saved ``(s, scale)``.

    s, dy, ds: (N, d); returns (dx (N, d) in s.dtype — ``dr`` is the same
    array, the residual add fans the cotangent out equally — and
    dscale (d,) f32).
    """
    N, d = s.shape
    sp = _pad_rows(s, row_tile)
    dyp = _pad_rows(dy, row_tile)
    dsp = _pad_rows(ds, row_tile)
    nr = sp.shape[0] // row_tile
    row_spec = pl.BlockSpec((row_tile, d), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((1, d), lambda i: (0, 0))
    dx, dscale = pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps),
        grid=(nr,),
        in_specs=[row_spec, vec_spec, row_spec, row_spec],
        out_specs=[row_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct(sp.shape, s.dtype),
                   jax.ShapeDtypeStruct((1, d), jnp.float32)],
        interpret=interpret,
    )(sp, scale.reshape(1, d), dyp, dsp)
    return dx[:N], dscale.reshape(d)
