"""Pallas TPU kernel for Ghost Batch Normalization (the paper's Algorithm 1
hot loop).

TPU-native design (not a CUDA port): two single-purpose kernels —
a tiled reduction producing per-(ghost, channel-tile) sums, and an
elementwise normalize — each gridded over (ghost, channel-tile, row-tile)
with VMEM-resident blocks. Channel tiles are multiples of 128 (VPU lane
width); row tiles bound the VMEM working set regardless of how many
rows (ghost_batch * H * W for convs) one ghost batch folds in.

Public entry point: :func:`repro.kernels.ops.gbn_forward` (jit'd, falls back
to interpret mode off-TPU). Oracle: :func:`repro.kernels.ref.gbn_ref`.

Kernel gradients
----------------
``gbn_forward`` is fully differentiable: :mod:`repro.kernels.ops` wires
:func:`gbn_backward_pallas` up as the ``jax.custom_vjp`` rule, so
``jax.grad`` through the ``use_kernels=True`` training path never falls back
to autodiff-through-interpret. The backward mirrors the forward's structure:

1. a tiled reduction over the same (ghost, col-tile, row-tile) grid
   accumulating the two per-(ghost, channel) sums the BN backward needs,
   ``sum_r dy`` and ``sum_r dy * xhat`` (``xhat`` recomputed in-kernel from
   the saved mu/var — nothing bigger than the activations is stashed);
2. tiny (G, C)-shaped host math folding those sums (plus any upstream
   cotangents on the mu/var outputs — the leftover-rows path in
   :mod:`repro.core.gbn` genuinely propagates these) into three
   per-(ghost, channel) coefficients;
3. an elementwise pass over the same grid computing
   ``dx = dy*c1 + (x - mu)*c2 + c3``.

``dgamma``/``dbeta`` are the per-ghost sums reduced over ghosts. Oracle:
:func:`repro.kernels.ref.gbn_vjp_ref` (hand-derived pure jnp), cross-checked
against ``jax.vjp`` of :func:`repro.kernels.ref.gbn_ref` in the tests.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 512
DEFAULT_COL_TILE = 128


def _stats_kernel(x_ref, sum_ref, sq_ref, *, n_rows: int):
    """Accumulate per-(ghost, col-tile) sum and sum-of-squares over row tiles.

    grid = (G, n_col_tiles, n_row_tiles); the row-tile axis is innermost so
    the (1, col_tile) accumulators persist in VMEM across row steps.
    """
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    x = x_ref[0].astype(jnp.float32)                  # (row_tile, col_tile)
    # mask padded rows in the last row tile
    row0 = r * x.shape[0]
    valid = (row0 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)) < n_rows
    x = jnp.where(valid, x, 0.0)
    sum_ref[...] += jnp.sum(x, axis=0, keepdims=True)
    sq_ref[...] += jnp.sum(x * x, axis=0, keepdims=True)


def _normalize_kernel(x_ref, mu_ref, var_ref, gamma_ref, beta_ref, y_ref, *,
                      eps: float):
    x = x_ref[0].astype(jnp.float32)                  # (row_tile, col_tile)
    mu = mu_ref[...].astype(jnp.float32)              # (1, col_tile)
    var = var_ref[...].astype(jnp.float32)
    g = gamma_ref[...].astype(jnp.float32)
    b = beta_ref[...].astype(jnp.float32)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * g + b
    y_ref[0] = y.astype(y_ref.dtype)


def _bwd_stats_kernel(x_ref, dy_ref, mu_ref, rstd_ref, sdy_ref, sdyxh_ref):
    """Accumulate sum_r dy and sum_r dy*xhat per (ghost, col-tile).

    Same grid as the forward reduction; row-padding needs no mask because the
    padded dy rows are zero and multiply every term.
    """
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        sdy_ref[...] = jnp.zeros_like(sdy_ref)
        sdyxh_ref[...] = jnp.zeros_like(sdyxh_ref)

    x = x_ref[0].astype(jnp.float32)                  # (row_tile, col_tile)
    dy = dy_ref[0].astype(jnp.float32)
    xhat = (x - mu_ref[...]) * rstd_ref[...]
    sdy_ref[...] += jnp.sum(dy, axis=0, keepdims=True)
    sdyxh_ref[...] += jnp.sum(dy * xhat, axis=0, keepdims=True)


def _bwd_dx_kernel(x_ref, dy_ref, mu_ref, c1_ref, c2_ref, c3_ref, dx_ref):
    """Elementwise dx = dy*c1 + (x - mu)*c2 + c3 with per-(ghost, channel)
    coefficients (c1 = gamma*rstd, c2 = 2*gvar/R, c3 = gmu/R)."""
    x = x_ref[0].astype(jnp.float32)
    dy = dy_ref[0].astype(jnp.float32)
    dx = dy * c1_ref[...] + (x - mu_ref[...]) * c2_ref[...] + c3_ref[...]
    dx_ref[0] = dx.astype(dx_ref.dtype)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def gbn_forward_pallas(xg: jax.Array, gamma: jax.Array, beta: jax.Array, *,
                       eps: float = 1e-5,
                       row_tile: int = DEFAULT_ROW_TILE,
                       col_tile: int = DEFAULT_COL_TILE,
                       interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xg: (G, R, C) -> (y (G,R,C), mu (G,C), var (G,C))."""
    G, R, C = xg.shape
    xp = _pad_to(_pad_to(xg, 2, col_tile), 1, row_tile)
    Rp, Cp = xp.shape[1], xp.shape[2]
    nr, nc = Rp // row_tile, Cp // col_tile

    sums, sqs = pl.pallas_call(
        functools.partial(_stats_kernel, n_rows=R),
        grid=(G, nc, nr),
        in_specs=[pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c))],
        out_specs=[pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c)),
                   pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c))],
        out_shape=[jax.ShapeDtypeStruct((G, Cp), jnp.float32),
                   jax.ShapeDtypeStruct((G, Cp), jnp.float32)],
        interpret=interpret,
    )(xp)
    mu = sums / R
    var = sqs / R - mu * mu

    gp = _pad_to(gamma.reshape(1, -1), 1, col_tile)
    bp = _pad_to(beta.reshape(1, -1), 1, col_tile)
    y = pl.pallas_call(
        functools.partial(_normalize_kernel, eps=eps),
        grid=(G, nc, nr),
        in_specs=[
            pl.BlockSpec((1, row_tile, col_tile), lambda g, c, r: (g, r, c)),
            pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c)),
            pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c)),
            pl.BlockSpec((1, col_tile), lambda g, c, r: (0, c)),
            pl.BlockSpec((1, col_tile), lambda g, c, r: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c)),
        out_shape=jax.ShapeDtypeStruct((G, Rp, Cp), xg.dtype),
        interpret=interpret,
    )(xp, mu, var, gp, bp)
    return y[:, :R, :C], mu[:, :C], var[:, :C]


def gbn_backward_pallas(xg: jax.Array, gamma: jax.Array, mu: jax.Array,
                        var: jax.Array, dy: jax.Array, dmu: jax.Array,
                        dvar: jax.Array, *, eps: float = 1e-5,
                        row_tile: int = DEFAULT_ROW_TILE,
                        col_tile: int = DEFAULT_COL_TILE,
                        interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """VJP of :func:`gbn_forward_pallas` w.r.t. (xg, gamma, beta).

    xg, dy: (G, R, C); mu, var, dmu, dvar: (G, C) — the saved forward
    statistics and the cotangents of all three forward outputs.
    Returns (dx (G, R, C) in xg.dtype, dgamma (C,), dbeta (C,)) — the
    parameter grads in float32.
    """
    G, R, C = xg.shape
    xp = _pad_to(_pad_to(xg, 2, col_tile), 1, row_tile)
    dyp = _pad_to(_pad_to(dy, 2, col_tile), 1, row_tile)
    Rp, Cp = xp.shape[1], xp.shape[2]
    nr, nc = Rp // row_tile, Cp // col_tile

    mup = _pad_to(mu.astype(jnp.float32), 1, col_tile)          # (G, Cp)
    rstd = _pad_to(jax.lax.rsqrt(var.astype(jnp.float32) + eps), 1, col_tile)
    stat_spec = pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c))

    sdy, sdyxh = pl.pallas_call(
        _bwd_stats_kernel,
        grid=(G, nc, nr),
        in_specs=[pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c)),
                  pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c)),
                  stat_spec, stat_spec],
        out_specs=[stat_spec, stat_spec],
        out_shape=[jax.ShapeDtypeStruct((G, Cp), jnp.float32),
                   jax.ShapeDtypeStruct((G, Cp), jnp.float32)],
        interpret=interpret,
    )(xp, dyp, mup, rstd)

    # (G, C)-sized glue: fold the tile sums and the upstream mu/var
    # cotangents into per-(ghost, channel) dx coefficients. With
    # mu = mean(x) the explicit dvar/dmu cross term vanishes identically.
    g32 = _pad_to(gamma.astype(jnp.float32).reshape(1, -1), 1, col_tile)
    gvar = _pad_to(dvar.astype(jnp.float32), 1, col_tile) \
        - 0.5 * g32 * rstd * rstd * sdyxh
    gmu = _pad_to(dmu.astype(jnp.float32), 1, col_tile) - g32 * rstd * sdy
    c1 = g32 * rstd
    c2 = 2.0 * gvar / R
    c3 = gmu / R

    dx = pl.pallas_call(
        _bwd_dx_kernel,
        grid=(G, nc, nr),
        in_specs=[pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c)),
                  pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c)),
                  stat_spec, stat_spec, stat_spec, stat_spec],
        out_specs=pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c)),
        out_shape=jax.ShapeDtypeStruct((G, Rp, Cp), xg.dtype),
        interpret=interpret,
    )(xp, dyp, mup, c1, c2, c3)

    dgamma = jnp.sum(sdyxh, axis=0)[:C]
    dbeta = jnp.sum(sdy, axis=0)[:C]
    return dx[:, :R, :C], dgamma, dbeta
