"""Pallas TPU kernel for Ghost Batch Normalization (the paper's Algorithm 1
hot loop).

TPU-native design (not a CUDA port): two single-purpose kernels —
a tiled reduction producing per-(ghost, channel-tile) sums, and an
elementwise normalize — each gridded over (ghost, channel-tile, row-tile)
with VMEM-resident blocks. Channel tiles are multiples of 128 (VPU lane
width); row tiles bound the VMEM working set regardless of how many
rows (ghost_batch * H * W for convs) one ghost batch folds in.

Public entry point: :func:`repro.kernels.ops.gbn_forward` (jit'd, falls back
to interpret mode off-TPU). Oracle: :func:`repro.kernels.ref.gbn_ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 512
DEFAULT_COL_TILE = 128


def _stats_kernel(x_ref, sum_ref, sq_ref, *, n_rows: int):
    """Accumulate per-(ghost, col-tile) sum and sum-of-squares over row tiles.

    grid = (G, n_col_tiles, n_row_tiles); the row-tile axis is innermost so
    the (1, col_tile) accumulators persist in VMEM across row steps.
    """
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    x = x_ref[0].astype(jnp.float32)                  # (row_tile, col_tile)
    # mask padded rows in the last row tile
    row0 = r * x.shape[0]
    valid = (row0 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)) < n_rows
    x = jnp.where(valid, x, 0.0)
    sum_ref[...] += jnp.sum(x, axis=0, keepdims=True)
    sq_ref[...] += jnp.sum(x * x, axis=0, keepdims=True)


def _normalize_kernel(x_ref, mu_ref, var_ref, gamma_ref, beta_ref, y_ref, *,
                      eps: float):
    x = x_ref[0].astype(jnp.float32)                  # (row_tile, col_tile)
    mu = mu_ref[...].astype(jnp.float32)              # (1, col_tile)
    var = var_ref[...].astype(jnp.float32)
    g = gamma_ref[...].astype(jnp.float32)
    b = beta_ref[...].astype(jnp.float32)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * g + b
    y_ref[0] = y.astype(y_ref.dtype)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def gbn_forward_pallas(xg: jax.Array, gamma: jax.Array, beta: jax.Array, *,
                       eps: float = 1e-5,
                       row_tile: int = DEFAULT_ROW_TILE,
                       col_tile: int = DEFAULT_COL_TILE,
                       interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xg: (G, R, C) -> (y (G,R,C), mu (G,C), var (G,C))."""
    G, R, C = xg.shape
    xp = _pad_to(_pad_to(xg, 2, col_tile), 1, row_tile)
    Rp, Cp = xp.shape[1], xp.shape[2]
    nr, nc = Rp // row_tile, Cp // col_tile

    sums, sqs = pl.pallas_call(
        functools.partial(_stats_kernel, n_rows=R),
        grid=(G, nc, nr),
        in_specs=[pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c))],
        out_specs=[pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c)),
                   pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c))],
        out_shape=[jax.ShapeDtypeStruct((G, Cp), jnp.float32),
                   jax.ShapeDtypeStruct((G, Cp), jnp.float32)],
        interpret=interpret,
    )(xp)
    mu = sums / R
    var = sqs / R - mu * mu

    gp = _pad_to(gamma.reshape(1, -1), 1, col_tile)
    bp = _pad_to(beta.reshape(1, -1), 1, col_tile)
    y = pl.pallas_call(
        functools.partial(_normalize_kernel, eps=eps),
        grid=(G, nc, nr),
        in_specs=[
            pl.BlockSpec((1, row_tile, col_tile), lambda g, c, r: (g, r, c)),
            pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c)),
            pl.BlockSpec((1, col_tile), lambda g, c, r: (g, c)),
            pl.BlockSpec((1, col_tile), lambda g, c, r: (0, c)),
            pl.BlockSpec((1, col_tile), lambda g, c, r: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, row_tile, col_tile),
                               lambda g, c, r: (g, r, c)),
        out_shape=jax.ShapeDtypeStruct((G, Rp, Cp), xg.dtype),
        interpret=interpret,
    )(xp, mu, var, gp, bp)
    return y[:, :R, :C], mu[:, :C], var[:, :C]
