"""Pallas TPU kernel for one chunk of the Mamba selective scan.

TPU adaptation of the CUDA selective-scan: instead of a warp-parallel scan
over the sequence, the kernel keeps the (d_inner-tile, d_state) hidden state
resident in VMEM and walks the chunk sequentially with a ``fori_loop`` —
sequential-over-time, parallel-over-channels, which matches the VPU's
(8, 128) lanes (channels on the lane axis). The outer grid parallelises over
(batch, d_inner tiles); chunk boundaries are handled by the carried h.

Public entry: :func:`repro.kernels.ops.mamba_chunk`.
Oracle: :func:`repro.kernels.ref.mamba_chunk_ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_DI_TILE = 512


def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
                  y_ref, hout_ref, *, chunk: int):
    """Blocks: x/dt (1, chunk, dit); b/c (1, chunk, ds); a (dit, ds);
    h0/hout (1, dit, ds); y (1, chunk, dit)."""
    a = a_ref[...].astype(jnp.float32)                  # (dit, ds)
    h = h0_ref[0].astype(jnp.float32)                   # (dit, ds)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)           # (dit,)
        dt_t = dt_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)           # (ds,)
        c_t = c_ref[0, t].astype(jnp.float32)
        decay = jnp.exp(dt_t[:, None] * a)              # (dit, ds)
        h = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t] = jnp.sum(h * c_t[None, :], axis=-1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h)
    hout_ref[0] = h.astype(hout_ref.dtype)


def mamba_chunk_pallas(xc: jax.Array, dt: jax.Array, Bm: jax.Array,
                       Cm: jax.Array, A: jax.Array, h0: jax.Array, *,
                       di_tile: int = DEFAULT_DI_TILE,
                       interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """xc, dt: (B, c, di); Bm, Cm: (B, c, ds); A: (di, ds); h0: (B, di, ds).

    Returns (y (B, c, di) f32, h_last (B, di, ds) f32).
    """
    B, c, di = xc.shape
    ds = A.shape[1]
    dit = min(di_tile, di)
    assert di % dit == 0, (di, dit)
    grid = (B, di // dit)

    y, hout = pl.pallas_call(
        functools.partial(_mamba_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, c, ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, c, ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((dit, ds), lambda b, d: (d, 0)),
            pl.BlockSpec((1, dit, ds), lambda b, d: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, dit, ds), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, c, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dt, Bm, Cm, A, h0)
    return y, hout
