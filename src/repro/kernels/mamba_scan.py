"""Pallas TPU kernels for one chunk of the Mamba selective scan
(forward + dedicated backward).

TPU adaptation of the CUDA selective-scan: instead of a warp-parallel scan
over the sequence, the kernel keeps the (d_inner-tile, d_state) hidden state
resident in VMEM and walks the chunk sequentially with a ``fori_loop`` —
sequential-over-time, parallel-over-channels, which matches the VPU's
(8, 128) lanes (channels on the lane axis). The outer grid parallelises over
(batch, d_inner tiles); chunk boundaries are handled by the carried h.

Backward (:func:`mamba_chunk_backward_pallas`): same (batch, d_inner-tile)
grid. Phase 1 re-runs the forward recurrence inside the kernel, stashing the
per-step states h_t in a (chunk, dit, ds) VMEM scratch (recompute-in-VMEM:
the (B, c, di, ds) state trajectory never exists in HBM). Phase 2 walks the
chunk in REVERSE with a ``fori_loop`` carrying the state cotangent dh,
emitting dx/ddt per (time, d-tile), accumulating dB/dC across d-tiles in the
output block (d-tile is the innermost grid axis), and dA in the loop carry.
The VMEM working set is ``chunk * dit * ds`` floats — callers bound it by
choosing ``di_tile`` (and the model's chunk size) accordingly.

Public entry: :func:`repro.kernels.ops.mamba_chunk` (differentiable —
``jax.custom_vjp`` pairs the two kernels, with no oracle forward replay).
Oracle: :func:`repro.kernels.ref.mamba_chunk_ref` /
:func:`repro.kernels.ref.mamba_chunk_vjp_ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_DI_TILE = 512


def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
                  y_ref, hout_ref, *, chunk: int):
    """Blocks: x/dt (1, chunk, dit); b/c (1, chunk, ds); a (dit, ds);
    h0/hout (1, dit, ds); y (1, chunk, dit)."""
    a = a_ref[...].astype(jnp.float32)                  # (dit, ds)
    h = h0_ref[0].astype(jnp.float32)                   # (dit, ds)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)           # (dit,)
        dt_t = dt_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)           # (ds,)
        c_t = c_ref[0, t].astype(jnp.float32)
        decay = jnp.exp(dt_t[:, None] * a)              # (dit, ds)
        h = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t] = jnp.sum(h * c_t[None, :], axis=-1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h)
    hout_ref[0] = h.astype(hout_ref.dtype)


def mamba_chunk_pallas(xc: jax.Array, dt: jax.Array, Bm: jax.Array,
                       Cm: jax.Array, A: jax.Array, h0: jax.Array, *,
                       di_tile: int = DEFAULT_DI_TILE,
                       interpret: bool = False
                       ) -> Tuple[jax.Array, jax.Array]:
    """xc, dt: (B, c, di); Bm, Cm: (B, c, ds); A: (di, ds); h0: (B, di, ds).

    Returns (y (B, c, di) f32, h_last (B, di, ds) f32).
    """
    B, c, di = xc.shape
    ds = A.shape[1]
    dit = min(di_tile, di)
    assert di % dit == 0, (di, dit)
    grid = (B, di // dit)

    y, hout = pl.pallas_call(
        functools.partial(_mamba_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, c, ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, c, ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((dit, ds), lambda b, d: (d, 0)),
            pl.BlockSpec((1, dit, ds), lambda b, d: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, dit, ds), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, c, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dt, Bm, Cm, A, h0)
    return y, hout


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _mamba_bwd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, dy_ref,
                      dhl_ref, dx_ref, ddt_ref, db_ref, dc_ref, da_ref,
                      dh0_ref, hs_ref, *, chunk: int):
    """Blocks: x/dt/dy/dx/ddt (1, chunk, dit); b/c/db/dc (1, chunk, ds);
    a (dit, ds); h0/dh0/dhl/da (1, dit, ds); hs scratch (chunk, dit, ds).

    db/dc accumulate across the (innermost) d-tile grid axis; da is summed
    over the batch axis by the caller.
    """
    d = pl.program_id(1)
    a = a_ref[...].astype(jnp.float32)                  # (dit, ds)
    h0 = h0_ref[0].astype(jnp.float32)

    # phase 1: recompute the forward states of this chunk into VMEM scratch
    def fwd_step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)           # (dit,)
        dt_t = dt_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)           # (ds,)
        decay = jnp.exp(dt_t[:, None] * a)              # (dit, ds)
        h = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        hs_ref[t] = h
        return h

    jax.lax.fori_loop(0, chunk, fwd_step, h0)

    @pl.when(d == 0)
    def _init():
        db_ref[...] = jnp.zeros_like(db_ref)
        dc_ref[...] = jnp.zeros_like(dc_ref)

    # phase 2: reverse-time sweep carrying (dh, dA accumulator)
    def bwd_step(i, carry):
        t = chunk - 1 - i
        dh, da = carry
        x_t = x_ref[0, t].astype(jnp.float32)
        dt_t = dt_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)
        c_t = c_ref[0, t].astype(jnp.float32)
        dy_t = dy_ref[0, t].astype(jnp.float32)         # (dit,)
        h_t = hs_ref[t]                                 # (dit, ds)
        h_prev = jnp.where(t == 0, h0, hs_ref[jnp.maximum(t - 1, 0)])
        # total cotangent of h_t: carried from t+1 plus y_t's contribution
        g = dh + dy_t[:, None] * c_t[None, :]
        dc_ref[0, t] += jnp.sum(h_t * dy_t[:, None], axis=0)       # (ds,)
        decay = jnp.exp(dt_t[:, None] * a)
        # cotangent of the exponent u = dt_t * A (d exp(u)/du = exp(u))
        du = g * h_prev * decay
        da = da + du * dt_t[:, None]
        gb = jnp.sum(g * b_t[None, :], axis=1)          # (dit,) = d(dt*x)
        db_ref[0, t] += jnp.sum(g * (dt_t * x_t)[:, None], axis=0)
        dx_ref[0, t] = (dt_t * gb).astype(dx_ref.dtype)
        ddt_ref[0, t] = (jnp.sum(du * a, axis=1)
                         + x_t * gb).astype(ddt_ref.dtype)
        return g * decay, da

    dh, da = jax.lax.fori_loop(
        0, chunk, bwd_step,
        (dhl_ref[0].astype(jnp.float32), jnp.zeros_like(a)))
    dh0_ref[0] = dh.astype(dh0_ref.dtype)
    da_ref[0] = da.astype(da_ref.dtype)


def mamba_chunk_backward_pallas(xc: jax.Array, dt: jax.Array, Bm: jax.Array,
                                Cm: jax.Array, A: jax.Array, h0: jax.Array,
                                dy: jax.Array, dh_last: jax.Array, *,
                                di_tile: int = DEFAULT_DI_TILE,
                                interpret: bool = False
                                ) -> Tuple[jax.Array, ...]:
    """VJP of :func:`mamba_chunk_pallas` w.r.t. all six inputs.

    Shapes as the forward, plus the output cotangents dy (B, c, di) and
    dh_last (B, di, ds). Returns (dxc, ddt, dB, dC, dA, dh0) — dxc/ddt/dB/dC
    in the corresponding input dtypes, dA/dh0 in f32.
    """
    B, c, di = xc.shape
    ds = A.shape[1]
    dit = min(di_tile, di)
    assert di % dit == 0, (di, dit)
    grid = (B, di // dit)

    dxc, ddt, dB, dC, dA_b, dh0 = pl.pallas_call(
        functools.partial(_mamba_bwd_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, c, ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, c, ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((dit, ds), lambda b, d: (d, 0)),
            pl.BlockSpec((1, dit, ds), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, dit, ds), lambda b, d: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, c, dit), lambda b, d: (b, 0, d)),
            pl.BlockSpec((1, c, ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, c, ds), lambda b, d: (b, 0, 0)),
            pl.BlockSpec((1, dit, ds), lambda b, d: (b, d, 0)),
            pl.BlockSpec((1, dit, ds), lambda b, d: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, c, di), xc.dtype),
            jax.ShapeDtypeStruct((B, c, di), dt.dtype),
            jax.ShapeDtypeStruct((B, c, ds), jnp.float32),
            jax.ShapeDtypeStruct((B, c, ds), jnp.float32),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((c, dit, ds), jnp.float32)],
        interpret=interpret,
    )(xc, dt, Bm, Cm, A, h0, dy, dh_last)
    # dA sums the per-batch blocks (each (b, d) grid cell owns one slice)
    return (dxc, ddt, dB.astype(Bm.dtype), dC.astype(Cm.dtype),
            dA_b.sum(axis=0).astype(A.dtype), dh0.astype(h0.dtype))
