"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container) they
run in ``interpret=True`` mode, which traces the kernel body to regular XLA
ops — bit-for-bit the same program structure, validated against the
pure-jnp oracles in :mod:`repro.kernels.ref`.

Every op here is differentiable through a dedicated Pallas backward kernel
wired up with ``jax.custom_vjp`` (see docs/kernels.md for each op's
forward/backward contract and residual layout) — ``jax.grad`` through the
``use_kernels=True`` training paths never falls back to
autodiff-through-interpret or to an oracle forward replay.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional, Set, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import (DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q,
                                           flash_attention_backward_pallas,
                                           flash_attention_pallas,
                                           flash_attention_rope_backward_pallas,
                                           flash_attention_rope_pallas)
from repro.kernels.flash_decode import (flash_decode_blockwise,
                                        flash_decode_paged_blockwise,
                                        flash_decode_paged_pallas,
                                        flash_decode_pallas)
from repro.kernels.fused_norm import (rmsnorm_residual_backward_pallas,
                                      rmsnorm_residual_pallas)
from repro.kernels.gbn import gbn_backward_pallas, gbn_forward_pallas
from repro.kernels.mamba_scan import (mamba_chunk_backward_pallas,
                                      mamba_chunk_pallas)
from repro.kernels.swiglu import swiglu_backward_pallas, swiglu_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     causal: bool, window: Optional[int],
                     block_q: int, block_k: int) -> jax.Array:
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())


def _flash_fwd(q, k, v, causal, window, block_q, block_k):
    out, lse = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, return_residuals=True, interpret=_interpret())
    # residuals: the inputs, the output, and the per-row logsumexp — the
    # backward rebuilds the probability blocks from lse instead of saving
    # anything (T, S)-sized
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, block_q, block_k, res, do):
    q, k, v, out, lse = res
    return flash_attention_backward_pallas(
        q, k, v, out, lse, do, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    kv_offsets: Optional[jax.Array] = None) -> jax.Array:
    """Layout adapter for the model code: q (B, T, H, hd); k, v
    (B, S, KV, hd) -> (B, T, H, hd). Internally head-major.

    Differentiable: the backward is the dedicated Pallas kernel pair
    (:func:`repro.kernels.flash_attention.flash_attention_backward_pallas`)
    via ``jax.custom_vjp``, validated against
    :func:`repro.kernels.ref.attention_vjp_ref`.

    ``kv_offsets`` (B,) masks keys before each sequence's first real token
    (the serving fused prefill's left-padded ragged prompts). That path is
    FORWARD-ONLY — it bypasses the custom_vjp pair.
    """
    qm = q.swapaxes(1, 2)
    km = k.swapaxes(1, 2)
    vm = v.swapaxes(1, 2)
    if kv_offsets is not None:
        out = flash_attention_pallas(qm, km, vm, causal=causal,
                                     window=window, kv_offsets=kv_offsets,
                                     interpret=_interpret())
    else:
        out = _flash_attention(qm, km, vm, causal, window,
                               DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K)
    return out.swapaxes(1, 2)


# ---------------------------------------------------------------------------
# flash decode (serving)
# ---------------------------------------------------------------------------


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array, *,
                 window: Optional[int] = None, ring: bool = False,
                 offsets: Optional[jax.Array] = None,
                 rope_theta: Optional[float] = None) -> jax.Array:
    """Single-row decode attention against a head-major cache.

    Layout adapter for the model code: q (B, 1, H, hd); k, v (B, KV, S, hd)
    -> (B, 1, H, hd). ``pos`` is a scalar or a per-row ``(B,)`` vector —
    both it and ``offsets`` are dynamic (per-row SMEM refs in the kernel);
    ``ring=True`` reads a sliding-window ring buffer of S slots.
    Forward-only (serving takes no gradients); oracle:
    :func:`repro.kernels.ref.flash_decode_ref`.

    On TPU the Pallas kernel runs compiled; elsewhere the SAME blockwise
    online-softmax program runs as a ``lax.scan``
    (:func:`repro.kernels.flash_decode.flash_decode_blockwise`) — unlike
    the training kernels, the decode hot loop cannot afford interpret-mode
    pallas emulation, whose per-grid-step cost scales with the full cache
    (the kernel body itself is oracle-validated under ``interpret=True`` in
    tests/test_serving.py).

    ``rope_theta`` fuses the query-row RoPE rotation (by ``pos - offset``)
    into the kernel — pass q UNROTATED; cached keys stay write-time rotated.
    """
    B, T, H, hd = q.shape
    assert T == 1, q.shape
    if _interpret():
        out = flash_decode_blockwise(q.reshape(B, H, hd), k, v, pos,
                                     window=window, ring=ring,
                                     offsets=offsets, rope_theta=rope_theta)
    else:
        out = flash_decode_pallas(q.reshape(B, H, hd), k, v, pos,
                                  window=window, ring=ring, offsets=offsets,
                                  rope_theta=rope_theta)
    return out.reshape(B, 1, H, hd)


def flash_decode_paged(q: jax.Array, kp: jax.Array, vp: jax.Array,
                       pt: jax.Array, pos: jax.Array, *,
                       window: Optional[int] = None,
                       offsets: Optional[jax.Array] = None,
                       k_scale: Optional[jax.Array] = None,
                       v_scale: Optional[jax.Array] = None,
                       rope_theta: Optional[float] = None) -> jax.Array:
    """Paged-cache decode attention: q (B, 1, H, hd); kp, vp
    (n_pages, KV, page_size, hd) physical page pool; pt (B, n_blocks)
    int32 block tables -> (B, 1, H, hd).

    On TPU the Pallas kernel gathers pages via scalar-prefetch index maps;
    elsewhere the blockwise ``lax.scan`` gathers one page per row per step
    (:func:`repro.kernels.flash_decode.flash_decode_paged_blockwise`).
    Neither materialises a row's cache contiguously. Forward-only; oracle:
    :func:`repro.kernels.ref.flash_decode_paged_ref`.

    ``k_scale``/``v_scale`` (n_pages, KV, page_size) f32 mark an int8 pool
    (``cache_dtype="int8"``): pages dequantize at the load, inside the
    kernel. ``rope_theta`` fuses the query rotation as in
    :func:`flash_decode`.
    """
    B, T, H, hd = q.shape
    assert T == 1, q.shape
    if _interpret():
        out = flash_decode_paged_blockwise(q.reshape(B, H, hd), kp, vp, pt,
                                           pos, window=window,
                                           offsets=offsets, k_scale=k_scale,
                                           v_scale=v_scale,
                                           rope_theta=rope_theta)
    else:
        out = flash_decode_paged_pallas(q.reshape(B, H, hd), kp, vp, pt,
                                        pos, window=window, offsets=offsets,
                                        k_scale=k_scale, v_scale=v_scale,
                                        rope_theta=rope_theta)
    return out.reshape(B, 1, H, hd)


def flash_attention_hm(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: Optional[int] = None,
                       block_q: int = DEFAULT_BLOCK_Q,
                       block_k: int = DEFAULT_BLOCK_K) -> jax.Array:
    """Head-major entry (B, H, T, hd) matching the oracle layout."""
    return _flash_attention(q, k, v, causal, window, block_q, block_k)


# ---------------------------------------------------------------------------
# ghost batch norm
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _gbn_forward(xg: jax.Array, gamma: jax.Array, beta: jax.Array,
                 eps: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    return gbn_forward_pallas(xg, gamma, beta, eps=eps,
                              interpret=_interpret())


def _gbn_fwd(xg, gamma, beta, eps):
    y, mu, var = _gbn_forward(xg, gamma, beta, eps)
    # residuals are the input + the already-reduced stats — nothing
    # activation-sized is saved beyond x itself
    return (y, mu, var), (xg, gamma, beta, mu, var)


def _gbn_bwd(eps, res, cts):
    xg, gamma, beta, mu, var = res
    dy, dmu, dvar = cts
    dx, dgamma, dbeta = gbn_backward_pallas(
        xg, gamma, mu, var, dy, dmu, dvar, eps=eps, interpret=_interpret())
    return dx, dgamma.astype(gamma.dtype), dbeta.astype(beta.dtype)


_gbn_forward.defvjp(_gbn_fwd, _gbn_bwd)


def gbn_forward(xg: jax.Array, gamma: jax.Array, beta: jax.Array, *,
                eps: float = 1e-5) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xg: (G, R, C) -> (y, mu (G,C), var (G,C)).

    Differentiable: the backward is the dedicated Pallas kernel
    (:func:`repro.kernels.gbn.gbn_backward_pallas`) via ``jax.custom_vjp``,
    validated against :func:`repro.kernels.ref.gbn_vjp_ref`.
    """
    return _gbn_forward(xg, gamma, beta, eps)


# ---------------------------------------------------------------------------
# mamba chunk scan
# ---------------------------------------------------------------------------

# d_inner values we already warned about (one warning per distinct shape,
# not per trace): sub-lane-aligned fallback tiles and oracle fallbacks
_TILE_WARNED: Set[Tuple[int, str]] = set()


def _warn_once(di: int, kind: str, msg: str) -> None:
    if (di, kind) not in _TILE_WARNED:
        _TILE_WARNED.add((di, kind))
        warnings.warn(msg, stacklevel=3)


# largest whole-axis (untiled) d_inner the kernel will take when no
# lane-aligned strict tile exists — bounds the VMEM block size
_MAX_UNTILED_DI = 1024


def _mamba_tile(di: int) -> Optional[int]:
    """Largest 128-multiple tile (<= 512) that divides d_inner, else the
    whole axis untiled.

    d_inner sits on the LANE axis of the x/dt blocks (and the sublane axis
    of the state blocks), so a strict sub-tile must be a 128-multiple to be
    legal off-interpret — when ``di % 128 != 0`` the only aligned option is
    the whole-axis block (Mosaic pads partial lanes of an untiled axis),
    which we take up to a VMEM bound. Returns None past that bound — the
    caller falls back to the jnp oracle. Both degraded paths warn once per
    shape so kernel-coverage regressions are visible instead of silent.
    """
    for cand in (512, 384, 256, 128):
        if di % cand == 0:
            return cand
    if di <= _MAX_UNTILED_DI:
        _warn_once(
            di, "untiled",
            f"mamba_chunk: d_inner={di} has no 128-multiple divisor; "
            f"running the whole axis as one untiled block (padded lanes, "
            f"larger VMEM working set)")
        return di
    _warn_once(
        di, "oracle",
        f"mamba_chunk: d_inner={di} has no 128-multiple divisor and is "
        f"too large for an untiled block; falling back to the un-tiled "
        f"jnp oracle (no kernel coverage)")
    return None


@jax.custom_vjp
def mamba_chunk(xc: jax.Array, dt: jax.Array, Bm: jax.Array, Cm: jax.Array,
                A: jax.Array, h0: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Pallas chunk scan with a custom VJP: the forward runs the
    VMEM-resident kernel and the backward runs the dedicated reverse-time
    kernel (:func:`repro.kernels.mamba_scan.mamba_chunk_backward_pallas`) —
    no oracle forward replay; the chunk states are recomputed in VMEM
    scratch inside the backward kernel. Validated against
    :func:`repro.kernels.ref.mamba_chunk_vjp_ref`.
    """
    dit = _mamba_tile(xc.shape[-1])
    if dit is None:
        return ref.mamba_chunk_ref(xc, dt, Bm, Cm, A, h0)
    return mamba_chunk_pallas(xc, dt, Bm, Cm, A, h0, di_tile=dit,
                              interpret=_interpret())


def _mamba_chunk_fwd(xc, dt, Bm, Cm, A, h0):
    out = mamba_chunk(xc, dt, Bm, Cm, A, h0)
    # residuals: the inputs only — the backward kernel recomputes the state
    # trajectory per chunk in VMEM, so nothing (B, c, di, ds)-sized is saved
    return out, (xc, dt, Bm, Cm, A, h0)


def _mamba_chunk_bwd(res, cts):
    xc, dt, Bm, Cm, A, h0 = res
    dit = _mamba_tile(xc.shape[-1])
    if dit is None:
        # the forward used the oracle; mirror it (shape-static decision)
        return ref.mamba_chunk_vjp_ref(xc, dt, Bm, Cm, A, h0, cts)
    dy, dh_last = cts
    return mamba_chunk_backward_pallas(xc, dt, Bm, Cm, A, h0, dy, dh_last,
                                       di_tile=dit, interpret=_interpret())


mamba_chunk.defvjp(_mamba_chunk_fwd, _mamba_chunk_bwd)


# ---------------------------------------------------------------------------
# fused RoPE attention
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash_attention_rope(q: jax.Array, k: jax.Array, v: jax.Array,
                          pos: jax.Array, theta: float, causal: bool,
                          window: Optional[int], block_q: int,
                          block_k: int) -> jax.Array:
    return flash_attention_rope_pallas(
        q, k, v, pos, theta=theta, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())


def _flash_rope_fwd(q, k, v, pos, theta, causal, window, block_q, block_k):
    out, lse = flash_attention_rope_pallas(
        q, k, v, pos, theta=theta, causal=causal, window=window,
        block_q=block_q, block_k=block_k, return_residuals=True,
        interpret=_interpret())
    # residuals: the UNROTATED inputs (the backward re-rotates them — one
    # cheap elementwise pass), positions, output, and the logsumexp
    return out, (q, k, v, pos, out, lse)


def _flash_rope_bwd(theta, causal, window, block_q, block_k, res, do):
    q, k, v, pos, out, lse = res
    dq, dk, dv = flash_attention_rope_backward_pallas(
        q, k, v, pos, out, lse, do, theta=theta, causal=causal,
        window=window, block_q=block_q, block_k=block_k,
        interpret=_interpret())
    # positions are integral sampling points, not a continuous parameter
    return dq, dk, dv, jnp.zeros_like(pos)


_flash_attention_rope.defvjp(_flash_rope_fwd, _flash_rope_bwd)


def flash_attention_rope(q: jax.Array, k: jax.Array, v: jax.Array,
                         positions: jax.Array, *, theta: float,
                         causal: bool = True,
                         window: Optional[int] = None) -> jax.Array:
    """Flash attention with RoPE fused into the q/k loads — the model-layout
    adapter: q (B, T, H, hd); k, v (B, T, KV, hd) UNROTATED; ``positions``
    broadcastable to (B, T) -> (B, T, H, hd). Replaces the separate
    ``apply_rope`` passes over q and k in the attention hot path.

    Differentiable via ``jax.custom_vjp``
    (:func:`repro.kernels.flash_attention.flash_attention_rope_backward_pallas`),
    validated against :func:`repro.kernels.ref.attention_rope_vjp_ref`.
    """
    B, T = q.shape[0], q.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(positions, jnp.float32), (B, T))
    out = _flash_attention_rope(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                v.swapaxes(1, 2), pos, theta, causal,
                                window, DEFAULT_BLOCK_Q, DEFAULT_BLOCK_K)
    return out.swapaxes(1, 2)


# ---------------------------------------------------------------------------
# fused row kernels (rmsnorm_residual, swiglu)
# ---------------------------------------------------------------------------

# widest whole-axis lane block the fused row kernels will take — their row
# blocks keep the full feature axis on the lane dimension
_MAX_FUSED_LANE = 8192


def _fused_tile(dim: int, kind: str) -> Optional[int]:
    """Feature-axis gate for the fused row kernels: the axis rides whole on
    the LANE dimension of each block, so it must be a 128-multiple and
    within a VMEM bound — otherwise the op falls back to the jnp oracle
    with a one-time warning (never a silent mis-tile)."""
    if dim % 128 == 0 and dim <= _MAX_FUSED_LANE:
        return dim
    if dim % 128:
        _warn_once(dim, kind,
                   f"{kind}: feature dim {dim} is not a 128-multiple; "
                   f"falling back to the jnp oracle (no kernel coverage)")
    else:
        _warn_once(dim, kind,
                   f"{kind}: feature dim {dim} exceeds the "
                   f"{_MAX_FUSED_LANE}-lane VMEM bound; falling back to the "
                   f"jnp oracle (no kernel coverage)")
    return None


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _rmsnorm_residual(x: jax.Array, r: jax.Array, scale: jax.Array,
                      eps: float) -> Tuple[jax.Array, jax.Array]:
    # off-TPU the fused jnp composition (XLA fuses the single pass) IS the
    # fast lowering — interpret-mode Pallas only re-runs it per grid step.
    # The kernel pair is the TPU path; tests drive it via interpret=True.
    d = x.shape[-1]
    if _fused_tile(d, "rmsnorm_residual") is None or _interpret():
        return ref.rmsnorm_residual_ref(x, r, scale, eps)
    shp = x.shape
    y, s = rmsnorm_residual_pallas(x.reshape(-1, d), r.reshape(-1, d),
                                   scale, eps=eps)
    return y.reshape(shp), s.reshape(shp)


def _rmsnorm_residual_fwd(x, r, scale, eps):
    y, s = _rmsnorm_residual(x, r, scale, eps)
    # residuals: the summed stream s (live anyway — it IS the second
    # output) and scale; x and r are never needed again
    return (y, s), (s, scale)


def _rmsnorm_residual_bwd(eps, res, cts):
    s, scale = res
    dy, ds = cts
    d = s.shape[-1]
    if _fused_tile(d, "rmsnorm_residual") is None or _interpret():
        # the forward used the oracle; its output depends on (x, r) only
        # through s = x + r, so re-linearize at (x=s, r=0)
        dx, _, dscale = ref.rmsnorm_residual_vjp_ref(
            s, jnp.zeros_like(s), scale, (dy, ds), eps)
        return dx, dx, dscale.astype(scale.dtype)
    dx, dscale = rmsnorm_residual_backward_pallas(
        s.reshape(-1, d), scale, dy.reshape(-1, d), ds.reshape(-1, d),
        eps=eps)
    dx = dx.reshape(s.shape)
    # the residual add fans the cotangent out equally: dr == dx
    return dx, dx, dscale.astype(scale.dtype)


_rmsnorm_residual.defvjp(_rmsnorm_residual_fwd, _rmsnorm_residual_bwd)


def rmsnorm_residual(x: jax.Array, r: jax.Array, scale: jax.Array, *,
                     eps: float = 1e-6) -> Tuple[jax.Array, jax.Array]:
    """Fused residual-add + RMSNorm: returns ``(rmsnorm(x + r) * scale,
    x + r)`` — the normed activations and the new residual stream — in one
    pass over (..., d). Differentiable via ``jax.custom_vjp``
    (:func:`repro.kernels.fused_norm.rmsnorm_residual_backward_pallas`),
    validated against :func:`repro.kernels.ref.rmsnorm_residual_vjp_ref`.
    Non-128-multiple ``d`` falls back to the oracle (one-time warning).
    """
    return _rmsnorm_residual(x, r, scale, eps)


@jax.custom_vjp
def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array) -> jax.Array:
    """Fused SwiGLU front half: ``silu(x @ wg) * (x @ wu)`` over (..., d)
    with one pass over x and a single saved hidden activation (the gate
    pre-activation; the up projection is recomputed by the backward).
    Differentiable via ``jax.custom_vjp``
    (:func:`repro.kernels.swiglu.swiglu_backward_pallas`), validated
    against :func:`repro.kernels.ref.swiglu_vjp_ref`. Non-128-multiple
    ``d``/hidden dims fall back to the oracle (one-time warning).
    """
    h, _ = _swiglu_impl(x, wg, wu)
    return h


def _swiglu_impl(x, wg, wu):
    # same off-TPU discipline as _rmsnorm_residual: jnp lowering off-TPU
    # (the tile gate still runs first so misaligned dims warn everywhere),
    # Pallas pair on TPU.
    d, F = wg.shape
    aligned = (_fused_tile(d, "swiglu") is not None
               and _fused_tile(F, "swiglu") is not None)
    if not aligned or _interpret():
        # single concatenated GEMM (one pass over x, gate in the epilogue);
        # XLA CPU lowers the naive two-GEMM composition measurably slower.
        dt = x.dtype
        gu = x @ jnp.concatenate([wg, wu], axis=1).astype(dt)
        g, u = jnp.split(gu, 2, axis=-1)
        return (jax.nn.silu(g) * u).astype(dt), None  # no gate residual
    shp = x.shape
    h, g = swiglu_pallas(x.reshape(-1, d), wg, wu)
    return h.reshape(shp[:-1] + (F,)), g


def _swiglu_fwd(x, wg, wu):
    h, g = _swiglu_impl(x, wg, wu)
    # residuals: inputs + the (N, F) gate pre-activation (None on the
    # oracle path — shape-static decision mirrored in the backward)
    return h, (x, wg, wu, g)


def _swiglu_bwd(res, dh):
    x, wg, wu, g = res
    if g is None:
        return ref.swiglu_vjp_ref(x, wg, wu, dh)
    d, F = wg.shape
    x2 = x.reshape(-1, d)
    dx, dg, du = swiglu_backward_pallas(x2, wg, wu, g, dh.reshape(-1, F))
    # weight grads are plain GEMMs over the full dg/du — nothing to fuse
    dwg = jnp.dot(x2.T.astype(jnp.float32),
                  dg.astype(jnp.float32)).astype(wg.dtype)
    dwu = jnp.dot(x2.T.astype(jnp.float32),
                  du.astype(jnp.float32)).astype(wu.dtype)
    return dx.astype(x.dtype).reshape(x.shape), dwg, dwu


swiglu.defvjp(_swiglu_fwd, _swiglu_bwd)
