"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container) they
run in ``interpret=True`` mode, which traces the kernel body to regular XLA
ops — bit-for-bit the same program structure, validated against the
pure-jnp oracles in :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gbn import gbn_backward_pallas, gbn_forward_pallas
from repro.kernels.mamba_scan import mamba_chunk_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None) -> jax.Array:
    """Layout adapter for the model code: q (B, T, H, hd); k, v
    (B, S, KV, hd) -> (B, T, H, hd). Internally head-major."""
    qm = q.swapaxes(1, 2)
    km = k.swapaxes(1, 2)
    vm = v.swapaxes(1, 2)
    out = flash_attention_pallas(qm, km, vm, causal=causal, window=window,
                                 interpret=_interpret())
    return out.swapaxes(1, 2)


def flash_attention_hm(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: Optional[int] = None,
                       block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Head-major entry (B, H, T, hd) matching the oracle layout."""
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())


# ---------------------------------------------------------------------------
# ghost batch norm
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _gbn_forward(xg: jax.Array, gamma: jax.Array, beta: jax.Array,
                 eps: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    return gbn_forward_pallas(xg, gamma, beta, eps=eps,
                              interpret=_interpret())


def _gbn_fwd(xg, gamma, beta, eps):
    y, mu, var = _gbn_forward(xg, gamma, beta, eps)
    # residuals are the input + the already-reduced stats — nothing
    # activation-sized is saved beyond x itself
    return (y, mu, var), (xg, gamma, beta, mu, var)


def _gbn_bwd(eps, res, cts):
    xg, gamma, beta, mu, var = res
    dy, dmu, dvar = cts
    dx, dgamma, dbeta = gbn_backward_pallas(
        xg, gamma, mu, var, dy, dmu, dvar, eps=eps, interpret=_interpret())
    return dx, dgamma.astype(gamma.dtype), dbeta.astype(beta.dtype)


_gbn_forward.defvjp(_gbn_fwd, _gbn_bwd)


def gbn_forward(xg: jax.Array, gamma: jax.Array, beta: jax.Array, *,
                eps: float = 1e-5) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xg: (G, R, C) -> (y, mu (G,C), var (G,C)).

    Differentiable: the backward is the dedicated Pallas kernel
    (:func:`repro.kernels.gbn.gbn_backward_pallas`) via ``jax.custom_vjp``,
    validated against :func:`repro.kernels.ref.gbn_vjp_ref`.
    """
    return _gbn_forward(xg, gamma, beta, eps)


# ---------------------------------------------------------------------------
# mamba chunk scan
# ---------------------------------------------------------------------------


@jax.custom_vjp
def mamba_chunk(xc: jax.Array, dt: jax.Array, Bm: jax.Array, Cm: jax.Array,
                A: jax.Array, h0: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Pallas chunk scan with a custom VJP: the forward runs the
    VMEM-resident kernel; the backward differentiates the pure-jnp oracle
    (a dedicated backward kernel is future work — the forward already
    removes the (B, c, d_inner, d_state) HBM round-trips that dominate,
    see EXPERIMENTS.md §Perf P2)."""
    di = xc.shape[-1]
    # pick the largest 128-multiple tile that divides d_inner (<= 512)
    for cand in (512, 256, 128):
        if di % cand == 0:
            return mamba_chunk_pallas(xc, dt, Bm, Cm, A, h0, di_tile=cand,
                                      interpret=_interpret())
    return ref.mamba_chunk_ref(xc, dt, Bm, Cm, A, h0)


def _mamba_chunk_fwd(xc, dt, Bm, Cm, A, h0):
    out = mamba_chunk(xc, dt, Bm, Cm, A, h0)
    return out, (xc, dt, Bm, Cm, A, h0)


def _mamba_chunk_bwd(res, cts):
    _, vjp = jax.vjp(ref.mamba_chunk_ref, *res)
    return vjp(cts)


mamba_chunk.defvjp(_mamba_chunk_fwd, _mamba_chunk_bwd)
