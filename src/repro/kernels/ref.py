"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# flash attention oracle
# ---------------------------------------------------------------------------


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: (B, H, T, hd); k, v: (B, KV, S, hd). Returns (B, H, T, hd).

    GQA: head h uses kv head h // (H // KV).
    """
    B, H, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, KV, g, T, hd)
    logits = jnp.einsum("bkgtd,bksd->bkgts", qg,
                        k).astype(jnp.float32) / math.sqrt(hd)
    qi = jnp.arange(T)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,bksd->bkgtd", p, v)
    return out.reshape(B, H, T, hd)


def attention_vjp_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      do: jax.Array, *, causal: bool = True,
                      window: Optional[int] = None
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Hand-derived pure-jnp VJP of :func:`attention_ref` w.r.t. (q, k, v).

    q, do: (B, H, T, hd); k, v: (B, KV, S, hd). Returns (dq, dk, dv) in the
    input dtypes (dk/dv summed over each GQA q-head group).

    Standard softmax-attention backward (f32 throughout): with
    ``p = softmax(q k^T / sqrt(hd))`` and ``delta = rowsum(do * o)``,

        dv = p^T do
        ds = p * (do v^T - delta) / sqrt(hd)
        dq = ds k,   dk = ds^T q
    """
    B, H, T, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.astype(jnp.float32).reshape(B, KV, g, T, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dog = do.astype(jnp.float32).reshape(B, KV, g, T, hd)

    logits = jnp.einsum("bkgtd,bksd->bkgts", qg, kf) * scale
    qi = jnp.arange(T)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)                  # (B, KV, g, T, S)

    dv = jnp.einsum("bkgts,bkgtd->bksd", p, dog)
    dp = jnp.einsum("bkgtd,bksd->bkgts", dog, vf)
    delta = jnp.sum(p * dp, axis=-1, keepdims=True)      # rowsum(do * o)
    ds = p * (dp - delta) * scale
    dq = jnp.einsum("bkgts,bksd->bkgtd", ds, kf).reshape(B, H, T, hd)
    dk = jnp.einsum("bkgts,bkgtd->bksd", ds, qg)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# ---------------------------------------------------------------------------
# flash decode oracle
# ---------------------------------------------------------------------------


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None,
                     ring: bool = False,
                     offsets: Optional[jax.Array] = None) -> jax.Array:
    """Single-row decode attention vs a cache. q: (B, H, hd); k, v:
    (B, KV, S, hd). Returns (B, H, hd).

    ``pos`` is a scalar or a per-row ``(B,)`` vector of query positions.
    Slot ``s`` holds global position ``s`` (``ring=False``) or
    ``pos - ((pos - s) mod S)`` (ring buffer of S slots). A slot with global
    position g is visible iff ``0 <= g <= pos``, ``g > pos - window`` (when
    windowed) and ``g >= offsets[b]`` (left-padded ragged prompts).
    """
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.astype(jnp.float32).reshape(B, KV, g, hd)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg,
                        k.astype(jnp.float32)) / math.sqrt(hd)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                            (B,))[:, None]                     # (B, 1)
    slot = jnp.arange(S)[None, :]                              # (1, S)
    gpos = posb - jnp.mod(posb - slot, S) if ring \
        else jnp.broadcast_to(slot, (B, S))
    valid = (gpos >= 0) & (gpos <= posb)                       # (B, S)
    if window is not None:
        valid &= gpos > posb - window
    if offsets is not None:
        valid &= gpos >= offsets[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def flash_decode_paged_ref(q: jax.Array, kp: jax.Array, vp: jax.Array,
                           pt: jax.Array, pos: jax.Array, *,
                           window: Optional[int] = None,
                           offsets: Optional[jax.Array] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Paged-cache decode oracle: gather each row's pages into a contiguous
    (B, KV, n_blocks*page_size, hd) cache and defer to
    :func:`flash_decode_ref` — the thing the paged kernel exists to avoid
    doing, which is exactly what makes it the oracle. kp, vp:
    (n_pages, KV, page_size, hd); pt: (B, n_blocks).

    ``k_scale``/``v_scale`` (n_pages, KV, page_size) dequantize an int8
    pool: the stored value is ``round(k / scale)`` and the oracle
    materialises ``kp * scale`` up front — the full-precision gather the
    in-kernel dequant exists to avoid."""
    B = q.shape[0]
    KV, ps, hd = kp.shape[1], kp.shape[2], kp.shape[3]
    NB = pt.shape[1]
    if k_scale is not None:
        kp = kp.astype(jnp.float32) * k_scale[..., None]
        vp = vp.astype(jnp.float32) * v_scale[..., None]
        kp = kp.astype(q.dtype)
        vp = vp.astype(q.dtype)
    k = kp[pt].transpose(0, 2, 1, 3, 4).reshape(B, KV, NB * ps, hd)
    v = vp[pt].transpose(0, 2, 1, 3, 4).reshape(B, KV, NB * ps, hd)
    return flash_decode_ref(q, k, v, pos, window=window, ring=False,
                            offsets=offsets)


# ---------------------------------------------------------------------------
# rotary embedding / fused-RoPE attention oracle
# ---------------------------------------------------------------------------


def rope_ref(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Head-major half-rotation RoPE: x (B, H, T, hd), pos (B, T).

    Mirrors ``models.layers.apply_rope`` (llama convention:
    ``freqs_i = theta ** -(i / (hd/2))``) on the kernel layout; the fused
    attention/decode kernels rotate q/k on load against this."""
    dt = x.dtype
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[:, None, :, None] * freqs  # (B,1,T,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(dt)


def attention_rope_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                       pos: jax.Array, *, theta: float,
                       causal: bool = True,
                       window: Optional[int] = None) -> jax.Array:
    """Oracle for the RoPE-fused flash attention: the unfused composition
    ``attention_ref(rope(q), rope(k), v)`` the kernel folds into one pass.
    q: (B, H, T, hd); k, v: (B, KV, T, hd); pos: (B, T) shared q/k
    positions (self-attention)."""
    return attention_ref(rope_ref(q, pos, theta), rope_ref(k, pos, theta),
                         v, causal=causal, window=window)


def attention_rope_vjp_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                           pos: jax.Array, do: jax.Array, *, theta: float,
                           causal: bool = True,
                           window: Optional[int] = None
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle VJP of :func:`attention_rope_ref` w.r.t. (q, k, v):
    autodiff of the unfused jnp composition."""
    def f(q_, k_, v_):
        return attention_rope_ref(q_, k_, v_, pos, theta=theta,
                                  causal=causal, window=window)
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)


# ---------------------------------------------------------------------------
# fused rmsnorm + residual oracle
# ---------------------------------------------------------------------------


def rmsnorm_residual_ref(x: jax.Array, r: jax.Array, scale: jax.Array,
                         eps: float = 1e-6
                         ) -> Tuple[jax.Array, jax.Array]:
    """Fused residual-add + RMSNorm oracle: ``s = x + r`` (the new residual
    stream) and ``y = rmsnorm(s) * scale``, both in one pass.

    x, r: (..., d); scale: (d,). Mirrors ``models.layers.rmsnorm_apply``
    (f32 compute, cast back to the input dtype). Returns (y, s)."""
    dt = x.dtype
    s = x + r
    sf = s.astype(jnp.float32)
    var = jnp.mean(jnp.square(sf), axis=-1, keepdims=True)
    y = sf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(dt), s


def rmsnorm_residual_vjp_ref(x: jax.Array, r: jax.Array, scale: jax.Array,
                             cts: Tuple[jax.Array, jax.Array],
                             eps: float = 1e-6) -> Tuple[jax.Array, ...]:
    """Oracle VJP of :func:`rmsnorm_residual_ref` w.r.t. (x, r, scale):
    autodiff of the jnp oracle. ``cts = (dy, ds)`` — both forward outputs
    are live (``s`` feeds the next residual add)."""
    _, vjp = jax.vjp(lambda a, b, c: rmsnorm_residual_ref(a, b, c, eps),
                     x, r, scale)
    return vjp(cts)


# ---------------------------------------------------------------------------
# fused SwiGLU oracle
# ---------------------------------------------------------------------------


def swiglu_ref(x: jax.Array, wg: jax.Array, wu: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Fused SwiGLU oracle: ``h = silu(x @ wg) * (x @ wu)`` plus the single
    hidden-activation residual ``g = x @ wg`` the backward keeps (``u`` is
    recomputed). x: (..., d); wg, wu: (d, f). Returns (h, g)."""
    dt = x.dtype
    g = x @ wg.astype(dt)
    u = x @ wu.astype(dt)
    return jax.nn.silu(g) * u, g


def swiglu_vjp_ref(x: jax.Array, wg: jax.Array, wu: jax.Array,
                   dh: jax.Array) -> Tuple[jax.Array, ...]:
    """Oracle VJP of the SwiGLU output ``h`` w.r.t. (x, wg, wu): autodiff
    of the jnp composition (``g`` is an internal residual, not a
    user-visible output — its cotangent is zero)."""
    _, vjp = jax.vjp(lambda a, b, c: swiglu_ref(a, b, c)[0], x, wg, wu)
    return vjp(dh)


# ---------------------------------------------------------------------------
# ghost batch norm oracle
# ---------------------------------------------------------------------------


def gbn_ref(xg: jax.Array, gamma: jax.Array, beta: jax.Array, *,
            eps: float = 1e-5) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xg: (G, R, C) -> (y (G,R,C), mu (G,C), var (G,C)); biased variance."""
    xf = xg.astype(jnp.float32)
    mu = xf.mean(axis=1)
    var = jnp.mean(jnp.square(xf - mu[:, None, :]), axis=1)
    y = (xf - mu[:, None, :]) * jax.lax.rsqrt(var[:, None, :] + eps)
    y = y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return y.astype(xg.dtype), mu, var


def gbn_vjp_ref(xg: jax.Array, gamma: jax.Array, beta: jax.Array,
                cts: Tuple[jax.Array, jax.Array, jax.Array], *,
                eps: float = 1e-5
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Hand-derived pure-jnp VJP of :func:`gbn_ref`.

    ``cts = (dy, dmu, dvar)`` are the cotangents of the three forward
    outputs (the mu/var cotangents are live: the leftover-rows path in
    ``core.gbn`` normalizes its tail with the last ghost's statistics, so
    the loss really does depend on them). Returns (dx, dgamma, dbeta).

    Standard BN backward, per ghost, with the upstream stat cotangents
    folded in (``gvar``/``gmu`` are the TOTAL adjoints of var/mu):

        gvar = dvar - 1/2 gamma rstd^2 sum_r dy xhat
        gmu  = dmu  - gamma rstd sum_r dy
        dx_r = gamma rstd dy_r + 2 gvar (x_r - mu)/R + gmu/R
    """
    dy, dmu, dvar = cts
    xf = xg.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    g = gamma.astype(jnp.float32)
    R = xg.shape[1]

    mu = xf.mean(axis=1)                                         # (G, C)
    var = jnp.mean(jnp.square(xf - mu[:, None, :]), axis=1)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (xf - mu[:, None, :]) * rstd[:, None, :]

    sdy = dyf.sum(axis=1)                                        # (G, C)
    sdyxh = jnp.sum(dyf * xhat, axis=1)
    gvar = dvar.astype(jnp.float32) - 0.5 * g * rstd * rstd * sdyxh
    gmu = dmu.astype(jnp.float32) - g * rstd * sdy

    dx = dyf * (g * rstd)[:, None, :] \
        + (xf - mu[:, None, :]) * (2.0 * gvar / R)[:, None, :] \
        + (gmu / R)[:, None, :]
    dgamma = sdyxh.sum(axis=0)
    dbeta = sdy.sum(axis=0)
    return (dx.astype(xg.dtype), dgamma.astype(gamma.dtype),
            dbeta.astype(beta.dtype))


# ---------------------------------------------------------------------------
# mamba chunk-scan oracle
# ---------------------------------------------------------------------------


def mamba_chunk_ref(xc: jax.Array, dt: jax.Array, Bm: jax.Array,
                    Cm: jax.Array, A: jax.Array, h0: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Sequential reference for one chunk of the selective scan.

    xc, dt: (B, c, di); Bm, Cm: (B, c, ds); A: (di, ds); h0: (B, di, ds).
    Returns (y (B, c, di) f32, h_last (B, di, ds) f32).
    """
    xc = xc.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        a = jnp.exp(dt_t[:, :, None] * A)            # (B, di, ds)
        h = a * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    inps = (xc.swapaxes(0, 1), dt.swapaxes(0, 1),
            Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), inps)
    return ys.swapaxes(0, 1), h_last


def mamba_chunk_vjp_ref(xc: jax.Array, dt: jax.Array, Bm: jax.Array,
                        Cm: jax.Array, A: jax.Array, h0: jax.Array,
                        cts: Tuple[jax.Array, jax.Array]
                        ) -> Tuple[jax.Array, ...]:
    """Oracle VJP of :func:`mamba_chunk_ref` w.r.t. all six inputs.

    ``cts = (dy, dh_last)`` are the cotangents of the two forward outputs.
    Returns (dxc, ddt, dB, dC, dA, dh0). Autodiff of the jnp oracle — the
    dedicated backward kernel is validated against this.
    """
    _, vjp = jax.vjp(mamba_chunk_ref, xc, dt, Bm, Cm, A, h0)
    return vjp(cts)
