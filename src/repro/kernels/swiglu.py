"""Pallas TPU kernel for fused SwiGLU: ``h = silu(x @ wg) * (x @ wu)``.

The unfused MLP front half runs three passes (gate GEMM, up GEMM,
elementwise gate) and materialises both (N, F) hidden activations in HBM.
This kernel fuses all three: each ``(row_tile, d)`` x block is read once
per hidden tile, both GEMM partials and the silu-gate product happen in
VMEM, and only ``h`` plus ONE hidden residual — the pre-activation gate
``g = x @ wg`` — are written out (``u = x @ wu`` is recomputed by the
backward, never stored).

Layout: rows (B*T) tiled on the sublane axis, ``d_model`` whole on the
lane/contraction axis, the hidden axis F tiled in 128-multiples
(``ops._fused_tile`` gates both widths; non-aligned dims fall back to the
jnp oracle with a one-time warning).

Backward (`swiglu_backward_pallas`), grid (rows, hidden-tiles) with the
hidden axis innermost: recompute ``u`` in-kernel, form the elementwise
chain (``sig = sigmoid(g)``)

    du = dh * g * sig
    dg = dh * u * sig * (1 + g * (1 - sig))

emit ``dg``/``du`` tiles, and accumulate ``dx = dg @ wg^T + du @ wu^T``
across hidden tiles directly in an f32 ``(row_tile, d)`` output block
whose index map is constant over the inner grid axis (the GBN
consecutive-revisit pattern). The weight grads are two plain GEMMs
outside the kernel (``dwg = x^T @ dg``, ``dwu = x^T @ du``) — they need
the full dg/du tiles anyway, so there is nothing to fuse.

Public entry: :func:`repro.kernels.ops.swiglu` (custom_vjp). Oracle:
:func:`repro.kernels.ref.swiglu_ref`.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 128


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, ((0, pad), (0, 0))) if pad else x


def _f_tile(F: int) -> int:
    """Largest standard hidden tile dividing F (F is 128-aligned here)."""
    for t in (512, 384, 256, 128):
        if F % t == 0:
            return t
    raise ValueError(f"hidden dim {F} is not 128-aligned")


def _fwd_kernel(x_ref, wg_ref, wu_ref, h_ref, g_ref):
    xf = x_ref[...].astype(jnp.float32)
    g = jnp.dot(xf, wg_ref[...].astype(jnp.float32))
    u = jnp.dot(xf, wu_ref[...].astype(jnp.float32))
    g_ref[...] = g.astype(g_ref.dtype)
    h_ref[...] = (g * jax.nn.sigmoid(g) * u).astype(h_ref.dtype)


def _bwd_kernel(x_ref, wg_ref, wu_ref, g_ref, dh_ref, dg_ref, du_ref,
                dx_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    xf = x_ref[...].astype(jnp.float32)
    wg = wg_ref[...].astype(jnp.float32)
    wu = wu_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    dh = dh_ref[...].astype(jnp.float32)
    u = jnp.dot(xf, wu)                         # recompute — u is not saved
    sig = jax.nn.sigmoid(g)
    du = dh * g * sig
    dg = dh * u * sig * (1.0 + g * (1.0 - sig))
    dg_ref[...] = dg.astype(dg_ref.dtype)
    du_ref[...] = du.astype(du_ref.dtype)
    dx_ref[...] += jnp.dot(dg, wg.T) + jnp.dot(du, wu.T)


def swiglu_pallas(x: jax.Array, wg: jax.Array, wu: jax.Array, *,
                  row_tile: int = DEFAULT_ROW_TILE,
                  interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (N, d); wg, wu: (d, F); d and F 128-multiples.

    Returns (h = silu(x @ wg) * (x @ wu), g = x @ wg), both (N, F) in
    x.dtype.
    """
    N, d = x.shape
    F = wg.shape[1]
    bf = _f_tile(F)
    xp = _pad_rows(x, row_tile)
    nr, nf = xp.shape[0] // row_tile, F // bf
    out_spec = pl.BlockSpec((row_tile, bf), lambda i, j: (i, j))
    h, g = pl.pallas_call(
        _fwd_kernel,
        grid=(nr, nf),
        in_specs=[pl.BlockSpec((row_tile, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((d, bf), lambda i, j: (0, j)),
                  pl.BlockSpec((d, bf), lambda i, j: (0, j))],
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((xp.shape[0], F), x.dtype),
                   jax.ShapeDtypeStruct((xp.shape[0], F), x.dtype)],
        interpret=interpret,
    )(xp, wg, wu)
    return h[:N], g[:N]


def swiglu_backward_pallas(x: jax.Array, wg: jax.Array, wu: jax.Array,
                           g: jax.Array, dh: jax.Array, *,
                           row_tile: int = DEFAULT_ROW_TILE,
                           interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Activation-side VJP of :func:`swiglu_pallas` from the saved gate
    ``g``. Returns (dx (N, d) f32, dg (N, F), du (N, F)); the caller forms
    ``dwg = x^T @ dg`` / ``dwu = x^T @ du`` outside (plain GEMMs).
    """
    N, d = x.shape
    F = wg.shape[1]
    bf = _f_tile(F)
    xp = _pad_rows(x, row_tile)
    gp = _pad_rows(g, row_tile)
    dhp = _pad_rows(dh, row_tile)
    nr, nf = xp.shape[0] // row_tile, F // bf
    hid_spec = pl.BlockSpec((row_tile, bf), lambda i, j: (i, j))
    dg, du, dx = pl.pallas_call(
        _bwd_kernel,
        grid=(nr, nf),
        in_specs=[pl.BlockSpec((row_tile, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((d, bf), lambda i, j: (0, j)),
                  pl.BlockSpec((d, bf), lambda i, j: (0, j)),
                  hid_spec, hid_spec],
        out_specs=[hid_spec, hid_spec,
                   pl.BlockSpec((row_tile, d), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((xp.shape[0], F), x.dtype),
                   jax.ShapeDtypeStruct((xp.shape[0], F), x.dtype),
                   jax.ShapeDtypeStruct((xp.shape[0], d), jnp.float32)],
        interpret=interpret,
    )(xp, wg, wu, gp, dhp)
    return dx[:N], dg[:N], du[:N]
