import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, record memory_analysis / cost_analysis / collective
schedule, and derive the roofline terms.

The two lines above MUST stay the very first statements in this module —
jax locks the device count on first init, and the dry-run (and ONLY the
dry-run) needs 512 placeholder host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import shape_applicable
from repro.configs.registry import get_config, get_shape, list_archs, list_shapes
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import setup_for


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            momentum_dtype: str = "bfloat16", use_kernels: bool = False,
            seq_parallel: bool = True, ce_chunk: int = 0,
            verbose: bool = True, setup=None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "applicable": ok,
    }
    if not ok:
        rec["skip_reason"] = reason
        return rec

    n_chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if setup is None:
        step_fn, args, in_shardings = setup_for(
            cfg, shape, mesh, momentum_dtype=momentum_dtype,
            use_kernels=use_kernels, seq_parallel=seq_parallel,
            ce_chunk=ce_chunk)
    else:
        # custom setup (perf experiments pass their own variant)
        step_fn, args, in_shardings = setup(cfg, shape, mesh)
    # realistic buffer aliasing: train updates params/opt in place, decode
    # updates the cache in place
    donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
    with mesh:
        lowered = jax.jit(step_fn, in_shardings=in_shardings,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    # --- memory ---------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes),
        }
        if verbose:
            print(f"  memory_analysis: args={rec['memory']['argument_bytes']/2**30:.2f}GiB "
                  f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
                  f"(per device)")
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)[:200]}

    # --- loop-aware HLO analysis (FLOPs, HBM bytes, collectives) ---------
    # raw cost_analysis is recorded too, but it counts while bodies once —
    # the loop-aware parse is authoritative (see hlo_analysis.py).
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    rec["xla_cost_raw"] = {"flops": float(cost.get("flops", 0.0)),
                           "bytes": float(cost.get("bytes accessed", 0.0))}
    hlo = compiled.as_text()
    stats = H.analyze(hlo)
    dev_flops = stats.flops
    dev_bytes = stats.bytes_hbm
    rec["cost"] = {"device_flops": dev_flops, "device_bytes": dev_bytes}
    # TPU-aliased (in-place DUS) memory model: tighter estimate for decode
    rec["memory_s_dus_aliased"] = (
        H.analyze(hlo, dus_aliased=True).bytes_hbm / H.HBM_BW)
    rec["collectives"] = stats.coll_dict()
    rec["collective_bytes"] = float(stats.collective_bytes)
    rec["n_whiles"] = stats.n_whiles
    rec["trip_counts"] = stats.trip_counts
    rec["hlo_lines"] = hlo.count("\n")

    # --- roofline ---------------------------------------------------------
    terms = H.roofline_terms(dev_flops, dev_bytes, stats.collective_bytes)
    rec["roofline"] = terms
    rec["bottleneck"] = H.dominant_term(terms)
    n_tokens = (shape.global_batch * shape.seq_len
                if shape.kind != "decode" else shape.global_batch)
    mf = H.model_flops(cfg.active_param_count(), n_tokens,
                       train=(shape.kind == "train"))
    rec["model_flops_total"] = mf
    rec["useful_flops_ratio"] = (mf / (dev_flops * n_chips)
                                 if dev_flops else 0.0)
    if verbose:
        print(f"  cost: {dev_flops/1e12:.2f} TFLOP/dev, "
              f"{dev_bytes/2**30:.2f} GiB/dev accessed; "
              f"collectives {stats.collective_bytes/2**30:.3f} GiB/dev")
        print(f"  roofline: compute {terms['compute_s']*1e3:.2f}ms "
              f"memory {terms['memory_s']*1e3:.2f}ms "
              f"collective {terms['collective_s']*1e3:.2f}ms "
              f"-> {rec['bottleneck']}  "
              f"useful/HLO flops {rec['useful_flops_ratio']:.2f}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list_shapes())
    ap.add_argument("--all", action="store_true",
                    help="all applicable (arch x shape) combinations")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512-chip) mesh instead of 16x16")
    ap.add_argument("--momentum-dtype", default="bfloat16",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true",
                    help="ablation: disable sequence parallelism")
    ap.add_argument("--ce-chunk", type=int, default=0,
                    help="vocab-chunked CE chunk size (0 = dense)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output record name")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        combos = [(a, s) for a in list_archs() for s in list_shapes()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in combos:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
        if args.tag:
            tag += "_" + args.tag
        print(f"[dryrun] {tag}")
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod,
                          momentum_dtype=args.momentum_dtype,
                          use_kernels=args.use_kernels,
                          seq_parallel=not args.no_seq_parallel,
                          ce_chunk=args.ce_chunk)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "error": str(e)[:2000]}
            failures.append(tag)
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2)
    if failures:
        print(f"FAILURES ({len(failures)}): {failures}")
        raise SystemExit(1)
    print("dry-run OK")


if __name__ == "__main__":
    main()
