"""Post-SPMD HLO analysis: loop-aware collective / FLOP / byte accounting
plus roofline terms.

Why not just ``compiled.cost_analysis()``: XLA's cost analysis counts a
``while`` body **once**, so anything under a ``lax.scan`` (our layer stacks)
is undercounted by its trip count. We therefore parse the optimized,
partitioned HLO text ourselves:

- computations are parsed into instruction lists;
- ``while`` trip counts are recovered from their condition computations
  (scan-canonical ``counter < constant(N)`` patterns);
- an execution multiplicity is propagated through nested while bodies;
- FLOPs are counted from ``dot`` / ``convolution`` shapes (2*M*N*K),
  weighted by multiplicity — fusion-internal dots included, because fusion
  computations inherit their caller's multiplicity;
- HBM bytes are modeled at fusion boundaries: for every top-level executed
  instruction, operand bytes (reads) + result bytes (writes);
- collective bytes sum operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (and -start forms),
  weighted by multiplicity.

Hardware model (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI, 4 links/chip.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
N_LINKS = 4

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|\S+?))\s+"
    r"([\w\-]+)\((.*)$")
# computation headers end with '{' and contain '->' (parameter lists may hold
# nested parens — tuple-typed args — so only anchor on the leading name)
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[List[int]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append(dims)
    return out


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str            # everything after the opening paren of operands

    def operand_names(self) -> List[str]:
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    inner = self.rest[:i]
                    break
        else:
            inner = self.rest
        return re.findall(r"%([\w\.\-]+)", inner)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_list(self, key: str) -> List[int]:
        m = re.search(key + r"=\{([\d,\s]*)\}", self.rest)
        if not m or not m.group(1).strip():
            return []
        return [int(x) for x in m.group(1).split(",")]


@dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr] = field(default_factory=list)
    sizes: Dict[str, int] = field(default_factory=dict)   # result bytes

    def instr_by_name(self, name: str) -> Optional[Instr]:
        for i in self.instrs:
            if i.name == name:
                return i
        return None


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    """Parse HLO text into computations; returns (comps, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            stripped = line.rstrip()
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HEADER_RE.match(stripped)
                if m:
                    cur = Computation(m.group(2), bool(m.group(1)))
                    if m.group(1):
                        entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.sizes[ins.name] = _shape_bytes(ins.type_str)
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Recover a while trip count from scan-canonical conditions."""
    const = None
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.match(r"(\-?\d+)", ins.rest)
            if m:
                const = int(m.group(1))
    for ins in cond.instrs:
        if "compare" in ins.opcode or "compare" in ins.rest:
            if const is not None and const > 0:
                return const
    # fused compare: constant appears at caller level; fall back to any
    # positive constant found
    return const if (const and const > 0) else 1


def _multiplicities(comps: Dict[str, Computation], entry: str
                    ) -> Dict[str, float]:
    """Execution multiplicity per computation (1 for entry; x trip count
    inside while bodies; fusions/calls inherit the caller's)."""
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry not in comps:
        return {c: 1.0 for c in comps}
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(20):
        changed = False
        for cname, comp in comps.items():
            m = mult.get(cname, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instrs:
                if ins.opcode == "while":
                    body, cond = ins.attr("body"), ins.attr("condition")
                    trips = 1
                    if cond in comps:
                        # constant may live in caller: check cond first
                        trips = _trip_count(comps[cond])
                        if trips == 1:
                            # look for "constant(N)" referenced via operands
                            trips = _caller_trip_hint(comp, ins) or 1
                    for target, k in ((body, trips), (cond, trips + 1)):
                        if target in comps:
                            new = m * k
                            if new > mult.get(target, 0.0):
                                mult[target] = new
                                changed = True
                else:
                    for key in ("calls", "to_apply", "body", "condition"):
                        t = ins.attr(key)
                        if t in comps and m > mult.get(t, 0.0):
                            mult[t] = m
                            changed = True
                    m2 = re.search(r"branch_computations=\{([^\}]*)\}",
                                   ins.rest)
                    if m2:
                        for t in re.findall(r"%?([\w\.\-]+)", m2.group(1)):
                            if t in comps and m > mult.get(t, 0.0):
                                mult[t] = m
                                changed = True
        if not changed:
            break
    return mult


def _caller_trip_hint(comp: Computation, while_ins: Instr) -> Optional[int]:
    return None


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, Tuple[int, float]] = field(default_factory=dict)
    n_whiles: int = 0
    trip_counts: List[int] = field(default_factory=list)

    def coll_dict(self) -> Dict:
        return {k: {"count": int(c), "bytes": float(b)}
                for k, (c, b) in sorted(self.collectives.items())}


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims)."""
    res_dims = _shape_dims(ins.type_str)
    if not res_dims:
        return 0.0
    out_elems = math.prod(res_dims[0]) if res_dims[0] else 1
    ops = ins.operand_names()
    contr = ins.attr_list("lhs_contracting_dims")
    k = 1
    if ops:
        lhs = comp.instr_by_name(ops[0])
        lhs_dims = _shape_dims(lhs.type_str)[0] if lhs else []
        for c in contr:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
    return 2.0 * out_elems * max(k, 1)


def _conv_flops(ins: Instr, comp: Computation) -> float:
    res_dims = _shape_dims(ins.type_str)
    ops = ins.operand_names()
    if not res_dims or len(ops) < 2:
        return 0.0
    out_elems = math.prod(res_dims[0]) if res_dims[0] else 1
    rhs = comp.instr_by_name(ops[1])
    if rhs is None:
        return 0.0
    kd = _shape_dims(rhs.type_str)
    if not kd or not kd[0]:
        return 0.0
    # kernel spatial+input-feature size = prod(kernel dims)/output features
    kernel = math.prod(kd[0])
    out_feat = max(kd[0][-1], 1)
    return 2.0 * out_elems * kernel / out_feat


_EXECUTED_OPCODES_SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "fusion", "call", "conditional", "custom-call",
}


def analyze(text: str, dus_aliased: bool = False) -> HloStats:
    """``dus_aliased=True`` models dynamic-(update-)slice as in-place (TPU
    aliasing): traffic = 2x the slice, not read+write of the whole buffer.
    The conservative default keeps the whole-buffer cost (upper bound) and is
    what the baseline roofline table uses; the aliased number is reported for
    the decode §Perf iterations, where scan-carried KV caches dominate."""
    comps, entry = parse_hlo(text)
    mult = _multiplicities(comps, entry)
    stats = HloStats()

    # collect names of computations used as fusion bodies (their instrs count
    # for FLOPs but not for HBM bytes)
    fusion_bodies = set()
    executed = set()   # top-level executed computations (entry + while parts)
    for cname, comp in comps.items():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                t = ins.attr("calls")
                if t:
                    fusion_bodies.add(t)
            if ins.opcode == "while":
                stats.n_whiles += 1
                for key in ("body", "condition"):
                    t = ins.attr(key)
                    if t:
                        executed.add(t)
                cond = ins.attr("condition")
                if cond in comps:
                    stats.trip_counts.append(_trip_count(comps[cond]))
    if entry:
        executed.add(entry)
    # transitively: while bodies nested in while bodies are already added
    # via the loop above (all comps scanned).

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        for ins in comp.instrs:
            # ---- FLOPs: dots and convs anywhere (incl. fusion bodies)
            if ins.opcode == "dot":
                stats.flops += m * _dot_flops(ins, comp)
            elif ins.opcode == "convolution":
                stats.flops += m * _conv_flops(ins, comp)
            # ---- collectives
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") \
                else ins.opcode
            if base in _COLLECTIVES:
                ob = 0
                for nm in ins.operand_names():
                    ob += comp.sizes.get(nm, 0)
                if ob == 0:
                    ob = comp.sizes.get(ins.name, 0)
                c, b = stats.collectives.get(base, (0, 0.0))
                stats.collectives[base] = (c + int(m), b + m * ob)
                stats.collective_bytes += m * ob
            # ---- HBM bytes: fusion-boundary model, only in top-level
            # executed computations (not inside fusion bodies)
            if cname in executed and cname not in fusion_bodies:
                if ins.opcode in ("parameter", "constant", "tuple",
                                  "get-tuple-element", "bitcast", "while",
                                  "conditional"):
                    continue
                rb = comp.sizes.get(ins.name, 0)
                op_bytes = [comp.sizes.get(nm, 0)
                            for nm in ins.operand_names()]
                ob = sum(op_bytes)
                if dus_aliased and _is_dus_like(ins, comps):
                    # in-place slice update: read update + write region
                    update = ob - (max(op_bytes) if op_bytes else 0)
                    stats.bytes_hbm += m * 2 * max(update, 0)
                elif dus_aliased and ins.opcode == "dynamic-slice":
                    stats.bytes_hbm += m * 2 * rb
                else:
                    stats.bytes_hbm += m * (rb + ob)
    return stats


def _is_dus_like(ins: Instr, comps: Dict[str, "Computation"]) -> bool:
    if ins.opcode == "dynamic-update-slice":
        return True
    if ins.opcode != "fusion":
        return False
    if "dynamic_update_slice" in ins.rest:
        return True
    body = ins.attr("calls")
    if body in comps:
        return any(i.opcode == "dynamic-update-slice"
                   for i in comps[body].instrs)
    return False


# ---------------------------------------------------------------------------
# donation aliasing
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class IoAlias:
    """One entry of the module's ``input_output_alias`` header: output
    tuple index <- (parameter number, kind)."""
    output_index: Tuple[int, ...]
    param_number: int
    kind: str            # "may-alias" | "must-alias"


_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{[^}]*\},\s*(may-alias|must-alias)\)")


def parse_input_output_aliases(text: str) -> List[IoAlias]:
    """Parse the module-level ``input_output_alias={ ... }`` header from
    compiled HLO text (``compiled.as_text()``).

    This is how donation (``donate_argnums``) shows up after buffer
    assignment: one entry per donated flat input leaf that XLA actually
    reused for an output. A donated argument that was *not* aliased (e.g.
    dtype/layout mismatch) is simply absent — which is exactly the hazard
    the trace auditor checks for. Returns [] when the module has no alias
    header at all.
    """
    start = text.find("input_output_alias={")
    if start < 0:
        return []
    # the header nests braces ({output index} and the per-entry {} attr
    # dict), so find the matching close by depth, not by regex
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, len(text)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                break
    else:
        return []
    body = text[i + 1:j]
    out: List[IoAlias] = []
    for e in _ALIAS_ENTRY_RE.finditer(body):
        idx = tuple(int(x) for x in e.group(1).split(",") if x.strip())
        out.append(IoAlias(idx, int(e.group(2)), e.group(3)))
    return out


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------


def roofline_terms(device_flops: float, device_bytes: float,
                   collective_bytes: float) -> Dict[str, float]:
    """Three roofline times in seconds (per chip; the per-device SPMD program
    is what we analyzed, so device quantities / per-chip rates)."""
    return {
        "compute_s": device_flops / PEAK_FLOPS,
        "memory_s": device_bytes / HBM_BW,
        "collective_s": collective_bytes / (N_LINKS * ICI_BW),
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


def model_flops(n_active_params: int, n_tokens: int, *,
                train: bool = True) -> float:
    """6*N*D for a train step (fwd+bwd), 2*N*D for inference."""
    return (6.0 if train else 2.0) * n_active_params * n_tokens
