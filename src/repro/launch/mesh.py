"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing the single real device.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_devices: int = 0):
    """1-D ("data",) mesh over all (or the first ``n_devices``) local
    devices — one mesh slot per GBN device shard; used by the shard_map
    data-parallel trainer (:mod:`repro.train.data_parallel`)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The axes the global batch is sharded over."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def fsdp_axes(mesh) -> Tuple[str, ...]:
    """The axes parameters are fully-sharded over (in addition to 'model')."""
    return (("data", "pod") if "pod" in mesh.axis_names else ("data",))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
