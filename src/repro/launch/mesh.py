"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing the single real device.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_data_mesh(n_devices: int = 0):
    """1-D ("data",) mesh over all (or the first ``n_devices``) local
    devices — one mesh slot per GBN device shard; used by the shard_map
    data-parallel trainer (:mod:`repro.train.data_parallel`)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_2d_mesh(n_devices: int = 0, model: int = 0):
    """2-D ("data", "model") mesh over the local devices — the small-scale
    twin of :func:`make_production_mesh`, used by the unified parallelism
    layer (:mod:`repro.train.parallel`) and the experiments runner.

    ``model=0`` picks the model-axis size automatically: 2 when the device
    count is even (the smallest non-degenerate model axis — expert shards
    stay coarse, dp stays wide), else 1.
    """
    n = n_devices or len(jax.devices())
    m = model or (2 if n > 1 and n % 2 == 0 else 1)
    if n % m:
        raise ValueError(f"{n} devices do not factor into model={m}")
    return jax.make_mesh((n // m, m), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The axes the global batch is sharded over (only those present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    """Total data-parallel ways: the product of the present dp axis sizes."""
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def dp_spec_entry(mesh):
    """The dp axes as one PartitionSpec entry: None when the mesh has no
    data axes, the bare name for one, the tuple for several."""
    axes = dp_axes(mesh)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def fsdp_axes(mesh) -> Tuple[str, ...]:
    """The axes parameters are fully-sharded over (in addition to 'model')."""
    return (("data", "pod") if "pod" in mesh.axis_names else ("data",))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
