"""Production mesh construction (single-host and multi-process).

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests must keep seeing the single real device.

The mesh axis NAMES live here as the module constants ``POD_AXIS`` /
``DATA_AXIS`` / ``MODEL_AXIS``. Collective call sites (``psum`` / ``pmean``
/ ``all_gather`` / ...) must reference these constants rather than spelling
the strings inline — enforced by lint rule ``axis-name-literal`` — so a
mesh-layout rename is one edit, not a repo-wide grep.

Multi-process: :func:`init_distributed` (routed through
:mod:`repro.core.compat`) brings up the ``jax.distributed`` runtime, after
which :func:`make_pod_mesh` lays the ``pod`` axis over processes.
:func:`make_local_mesh` builds the per-process compute mesh for backends
(CPU) whose collectives cannot cross processes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

# The canonical mesh axis names. Every psum/pmean/all_gather axis argument
# in src/ traces back to these (lint rule axis-name-literal).
POD_AXIS = "pod"
DATA_AXIS = "data"
MODEL_AXIS = "model"


def init_distributed(coordinator_address: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None) -> None:
    """Bring up the multi-process jax runtime (idempotent).

    Thin wrapper over :func:`repro.core.compat.distributed_initialize` — the
    version shim owns the actual ``jax.distributed.initialize`` call. With
    no arguments jax auto-detects the cluster environment (SLURM etc.); an
    explicit (coordinator, n, id) triple is what the tests and ad-hoc
    launches pass. Call BEFORE any jax device use, then build the
    process-spanning mesh with :func:`make_pod_mesh`.
    """
    from repro.core.compat import distributed_initialize
    distributed_initialize(coordinator_address=coordinator_address,
                           num_processes=num_processes,
                           process_id=process_id)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ((POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod
            else (DATA_AXIS, MODEL_AXIS))
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (CPU smoke tests)."""
    return jax.make_mesh((1, 1), (DATA_AXIS, MODEL_AXIS))


def make_data_mesh(n_devices: int = 0):
    """1-D ("data",) mesh over all (or the first ``n_devices``) local
    devices — one mesh slot per GBN device shard; used by the shard_map
    data-parallel trainer (:mod:`repro.train.data_parallel`)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), (DATA_AXIS,))


def make_2d_mesh(n_devices: int = 0, model: int = 0):
    """2-D ("data", "model") mesh over the local devices — the small-scale
    twin of :func:`make_production_mesh`, used by the unified parallelism
    layer (:mod:`repro.train.parallel`) and the experiments runner.

    ``model=0`` picks the model-axis size automatically: 2 when the device
    count is even (the smallest non-degenerate model axis — expert shards
    stay coarse, dp stays wide), else 1.
    """
    n = n_devices or len(jax.devices())
    m = model or (2 if n > 1 and n % 2 == 0 else 1)
    if n % m:
        raise ValueError(f"{n} devices do not factor into model={m}")
    return jax.make_mesh((n // m, m), (DATA_AXIS, MODEL_AXIS))


def make_pod_mesh(model: int = 1):
    """3-D ("pod", "data", "model") mesh spanning ALL processes: one pod
    slot per process, ``data`` over each process's remaining devices.

    Requires :func:`init_distributed` first. ``jax.make_mesh`` enumerates
    devices process-major, so each pod row is exactly one process's local
    devices — the pod axis IS the process axis. Cross-pod collectives need
    a backend with inter-process transport (TPU/GPU); the CPU backend can
    build this mesh, create/checkpoint global arrays on it, but not run a
    computation across it (XLA: "Multiprocess computations aren't
    implemented on the CPU backend") — use :func:`make_local_mesh` for the
    per-host compute there.
    """
    nproc = jax.process_count()
    n = len(jax.devices())
    local = n // nproc
    if model <= 0 or local % model:
        raise ValueError(
            f"{local} per-process devices do not factor into model={model}")
    return jax.make_mesh((nproc, local // model, model),
                         (POD_AXIS, DATA_AXIS, MODEL_AXIS))


def make_local_mesh(model: int = 1):
    """2-D ("data", "model") mesh over THIS process's addressable devices.

    The per-host compute mesh under a multi-process runtime whose backend
    lacks cross-process collectives (CPU): each host trains/serves its own
    shard of the work (see ``run_sweep(shard=...)``) on its local devices
    while the process-spanning :func:`make_pod_mesh` handles global array
    placement and per-shard checkpointing.
    """
    import numpy as np
    devs = np.asarray(jax.local_devices())
    n = len(devs)
    if model <= 0 or n % model:
        raise ValueError(
            f"{n} local devices do not factor into model={model}")
    return jax.sharding.Mesh(devs.reshape(n // model, model),
                             (DATA_AXIS, MODEL_AXIS))


def global_array(mesh, arr, spec):
    """A global jax.Array on ``mesh`` from a host-identical numpy array.

    Under a multi-process runtime a plain ``jnp.asarray`` is process-local
    and cannot feed a computation over a process-spanning mesh; this places
    each shard from the (identical on every host) ``arr`` — the standard
    way to feed replicated-input batches onto a pod mesh.
    """
    from jax.sharding import NamedSharding
    return jax.make_array_from_callback(
        arr.shape, NamedSharding(mesh, spec), lambda idx: arr[idx])


def dp_axes(mesh) -> Tuple[str, ...]:
    """The axes the global batch is sharded over (only those present)."""
    return tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.axis_names)


def dp_size(mesh) -> int:
    """Total data-parallel ways: the product of the present dp axis sizes."""
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def dp_spec_entry(mesh):
    """The dp axes as one PartitionSpec entry: None when the mesh has no
    data axes, the bare name for one, the tuple for several."""
    axes = dp_axes(mesh)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def fsdp_axes(mesh) -> Tuple[str, ...]:
    """The axes parameters are fully-sharded over (in addition to 'model')."""
    return ((DATA_AXIS, POD_AXIS) if POD_AXIS in mesh.axis_names
            else (DATA_AXIS,))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
