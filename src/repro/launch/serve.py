"""Serving launcher: batched generation against a (reduced or full)
architecture — the runnable counterpart of the decode dry-run shapes.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b-reduced \
        --batch 8 --prompt-len 16 --max-new 32 [--use-kernels] \
        [--temperature 0.8 --top-k 40] [--prompt-lens 5,16,9,...]

Reports cold (incl. compile) and warm (post-compile) tok/s; ``--use-kernels``
routes prefill through the fused flash-attention forward and decode through
the flash-decode Pallas kernel over a head-major cache.

``--continuous`` instead drives the continuous-batching engine
(:class:`repro.serving.ContinuousEngine`) under a synthetic Poisson arrival
trace (``--rate`` requests per decode step, ``--requests`` total) with a
paged KV cache (``--page-size``, ``--slots``), and reports sustained
useful AND raw tok/s (raw counts dead retired-lane decodes; the gap is the
engine's dropped work) plus the static lockstep baseline over the same
trace at equal cache memory.

Observability: ``--trace out.json`` writes a Chrome/Perfetto-loadable span
trace of the serving loop, ``--metrics-out out.jsonl`` the metrics registry
(for ``--continuous`` that includes the SLO set: TTFT/ITL/e2e percentiles,
queue depth, slot occupancy, page-pool utilization), and
``--device-trace LOGDIR`` captures a ``jax.profiler`` device trace whose
XLA activity lines up under the host spans.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.obs import NULL_TRACER, Observability
from repro.obs.trace import device_trace
from repro.serving import (ContinuousEngine, generate, poisson_trace,
                           run_static_trace)


def _write_obs(args, obs=None) -> None:
    if obs is None:
        return
    obs.write(args.trace, args.metrics_out)
    if args.trace:
        print(f"wrote span trace -> {args.trace} "
              "(load in ui.perfetto.dev or chrome://tracing)")
    if args.metrics_out:
        print(f"wrote metrics JSONL -> {args.metrics_out}")
    table = obs.summary()
    if table:
        print(table)


def _run_continuous(params, cfg, args, *, obs=None) -> None:
    max_len = args.max_len or 4 * args.prompt_len
    max_len = -(-max_len // args.page_size) * args.page_size
    reqs = poisson_trace(
        cfg, args.requests, rate=args.rate, seed=args.seed,
        prompt_len_choices=(args.prompt_len // 2, args.prompt_len),
        new_token_choices=(args.max_new // 2, args.max_new))
    n_blocks = max_len // args.page_size
    eng = ContinuousEngine(
        params, cfg, num_slots=args.slots, max_len=max_len, layout="paged",
        page_size=args.page_size, total_pages=1 + args.slots * n_blocks,
        use_kernels=args.use_kernels, eos_id=args.eos_id,
        temperature=args.temperature, top_k=args.top_k,
        rng=jax.random.PRNGKey(args.seed + 1), obs=obs)
    eng.run(reqs)                      # warm the compile caches
    if obs is not None:
        obs.clear()                    # drop warmup spans/latencies
    t0 = time.time()
    comps = eng.run(reqs)
    useful = sum(len(c.tokens) for c in comps.values())
    cont = time.time() - t0
    stats = eng.stats()
    # static lockstep baseline: same trace, equal cache memory (slots x
    # max_len contiguous rows == the paged pool above)
    run_static_trace(params, cfg, reqs, batch=args.slots, max_len=max_len,
                     use_kernels=args.use_kernels)   # warm
    t0 = time.time()
    static_useful = run_static_trace(params, cfg, reqs, batch=args.slots,
                                     max_len=max_len,
                                     use_kernels=args.use_kernels)
    stat = time.time() - t0
    print(f"continuous: {useful} useful tok in {cont:.2f}s "
          f"({useful / cont:.1f} useful tok/s, "
          f"{stats['raw_tok_s']:.1f} raw tok/s, "
          f"{int(stats['dropped_tokens'])} dropped, "
          f"{eng.steps} decode steps)")
    print(f"static:     {static_useful} tok in {stat:.2f}s "
          f"({static_useful / stat:.1f} tok/s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b-reduced")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--use-kernels", action="store_true",
                    help="fused flash prefill + flash-decode Pallas kernel")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples logits/temperature")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the top-k logits (0 = all)")
    ap.add_argument("--prompt-lens", default="",
                    help="comma-separated per-sequence prompt lengths "
                         "(<= --prompt-len); prompts are left-padded ragged")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine under a Poisson trace "
                         "(paged KV cache) vs the static baseline")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="--continuous: arrivals per decode step")
    ap.add_argument("--requests", type=int, default=16,
                    help="--continuous: total requests in the trace")
    ap.add_argument("--slots", type=int, default=4,
                    help="--continuous: decode slots (= static batch)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="--continuous: KV cache page size (slots/page)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="--continuous: cache depth (0 = 4x prompt-len)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="--continuous: retire rows on this token id")
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto span trace JSON here")
    ap.add_argument("--metrics-out", default="",
                    help="append the metrics registry as JSONL here")
    ap.add_argument("--device-trace", default="",
                    help="jax.profiler trace logdir (device activity "
                         "aligned under the host spans)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    obs = None
    if args.trace or args.metrics_out or args.device_trace:
        obs = Observability(annotate_device=bool(args.device_trace))
    cfg = dataclasses.replace(get_config(args.arch), dtype=args.dtype)
    rng = jax.random.PRNGKey(args.seed)
    params = T.init_params(rng, cfg)
    if args.continuous:
        if args.device_trace:
            with device_trace(args.device_trace):
                _run_continuous(params, cfg, args, obs=obs)
        else:
            _run_continuous(params, cfg, args, obs=obs)
        _write_obs(args, obs=obs)
        return
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    prompt_lens = None
    if args.prompt_lens:
        lens = [int(x) for x in args.prompt_lens.split(",")]
        if (len(lens) != args.batch or max(lens) > args.prompt_len
                or min(lens) < 1):
            raise SystemExit("--prompt-lens needs --batch entries, each in "
                             "[1, --prompt-len]")
        prompt_lens = jnp.array(lens, jnp.int32)
        # left-pad: real tokens right-aligned, pad id 0 on the left
        col = jnp.arange(args.prompt_len)[None]
        prompts = jnp.where(col >= args.prompt_len - prompt_lens[:, None],
                            prompts, 0)
    memory = None
    if cfg.vision is not None:
        memory = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.vision.n_image_tokens, cfg.d_model))
    if cfg.encoder is not None:
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 32, cfg.encoder.d_model))
        memory = T.encode(params, cfg, frames.astype(jnp.dtype(cfg.dtype)))

    gen = jax.jit(lambda p, toks: generate(
        p, cfg, toks, max_new_tokens=args.max_new, memory=memory,
        use_kernels=args.use_kernels, temperature=args.temperature,
        top_k=args.top_k, rng=jax.random.PRNGKey(args.seed + 1),
        prompt_lens=prompt_lens))

    def run():
        return gen(params, prompts)

    span = (obs.tracer if obs is not None else NULL_TRACER).span
    n_new = args.batch * args.max_new
    t0 = time.time()
    with span("serve.generate_cold", batch=args.batch, max_new=args.max_new):
        out = run()
        out.block_until_ready()
    cold = time.time() - t0
    # explicit warmup: a fully-blocked steady-state call, so neither compile
    # nor async dispatch from the cold run can leak into the warm number
    jax.block_until_ready(run())
    t0 = time.time()
    with span("serve.generate_warm", batch=args.batch, max_new=args.max_new):
        out = run()
        out.block_until_ready()
    warm = time.time() - t0
    if obs is not None:
        obs.registry.observe("serve/generate_warm_s", warm)
        obs.registry.set("serve/generate_warm_tok_s", n_new / warm)
    print(f"generated {out.shape} kernels={args.use_kernels} "
          f"temperature={args.temperature}")
    print(f"cold: {cold:.2f}s ({n_new / cold:.1f} tok/s incl. compile)   "
          f"warm: {warm:.2f}s ({n_new / warm:.1f} tok/s)")
    print("sample row:", out[0, :32].tolist())
    _write_obs(args, obs=obs)


if __name__ == "__main__":
    main()
