"""Serving launcher: batched generation against a (reduced or full)
architecture — the runnable counterpart of the decode dry-run shapes.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b-reduced \
        --batch 8 --prompt-len 16 --max-new 32 [--use-kernels] \
        [--temperature 0.8 --top-k 40] [--prompt-lens 5,16,9,...]

Reports cold (incl. compile) and warm (post-compile) tok/s; ``--use-kernels``
routes prefill through the fused flash-attention forward and decode through
the flash-decode Pallas kernel over a head-major cache.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.serving import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b-reduced")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--use-kernels", action="store_true",
                    help="fused flash prefill + flash-decode Pallas kernel")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples logits/temperature")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the top-k logits (0 = all)")
    ap.add_argument("--prompt-lens", default="",
                    help="comma-separated per-sequence prompt lengths "
                         "(<= --prompt-len); prompts are left-padded ragged")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch), dtype=args.dtype)
    rng = jax.random.PRNGKey(args.seed)
    params = T.init_params(rng, cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    prompt_lens = None
    if args.prompt_lens:
        lens = [int(x) for x in args.prompt_lens.split(",")]
        if (len(lens) != args.batch or max(lens) > args.prompt_len
                or min(lens) < 1):
            raise SystemExit("--prompt-lens needs --batch entries, each in "
                             "[1, --prompt-len]")
        prompt_lens = jnp.array(lens, jnp.int32)
        # left-pad: real tokens right-aligned, pad id 0 on the left
        col = jnp.arange(args.prompt_len)[None]
        prompts = jnp.where(col >= args.prompt_len - prompt_lens[:, None],
                            prompts, 0)
    memory = None
    if cfg.vision is not None:
        memory = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.vision.n_image_tokens, cfg.d_model))
    if cfg.encoder is not None:
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 32, cfg.encoder.d_model))
        memory = T.encode(params, cfg, frames.astype(jnp.dtype(cfg.dtype)))

    gen = jax.jit(lambda p, toks: generate(
        p, cfg, toks, max_new_tokens=args.max_new, memory=memory,
        use_kernels=args.use_kernels, temperature=args.temperature,
        top_k=args.top_k, rng=jax.random.PRNGKey(args.seed + 1),
        prompt_lens=prompt_lens))

    def run():
        return gen(params, prompts)

    n_new = args.batch * args.max_new
    t0 = time.time()
    out = run()
    out.block_until_ready()
    cold = time.time() - t0
    t0 = time.time()
    out = run()
    out.block_until_ready()
    warm = time.time() - t0
    print(f"generated {out.shape} kernels={args.use_kernels} "
          f"temperature={args.temperature}")
    print(f"cold: {cold:.2f}s ({n_new / cold:.1f} tok/s incl. compile)   "
          f"warm: {warm:.2f}s ({n_new / warm:.1f} tok/s)")
    print("sample row:", out[0, :32].tolist())


if __name__ == "__main__":
    main()
