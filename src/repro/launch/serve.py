"""Serving launcher: batched greedy generation against a (reduced or full)
architecture — the runnable counterpart of the decode dry-run shapes.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b-reduced \
        --batch 8 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.serving import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b-reduced")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch), dtype=args.dtype)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    memory = None
    if cfg.vision is not None:
        memory = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.vision.n_image_tokens, cfg.d_model))
    if cfg.encoder is not None:
        frames = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 32, cfg.encoder.d_model))
        memory = T.encode(params, cfg, frames.astype(jnp.dtype(cfg.dtype)))

    t0 = time.time()
    out = generate(params, cfg, prompts, max_new_tokens=args.max_new,
                   memory=memory)
    out.block_until_ready()
    dt = time.time() - t0
    n_new = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s incl. compile)")
    print("sample row:", out[0, :32].tolist())


if __name__ == "__main__":
    main()
