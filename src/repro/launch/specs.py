"""ShapeDtypeStruct input specs + shardings for every (arch x input-shape)
combination — the dry-run's stand-ins (weak-type-correct, shardable, no
device allocation).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core.large_batch import LargeBatchConfig
from repro.core.regime import Regime
from repro.launch.mesh import dp_axes
from repro.models import transformer as T
from repro.optim import sgd
from repro.serving.engine import make_serve_step
from repro.sharding import rules
from repro.train.trainer import make_lm_train_step

Sds = jax.ShapeDtypeStruct


def default_large_batch_config(shape: InputShape) -> LargeBatchConfig:
    """The paper-faithful large-batch recipe at production scale: sqrt-scaled
    LR + gradient clipping (noise off: the paper prefers the LR method)."""
    return LargeBatchConfig(batch_size=shape.global_batch,
                            base_batch_size=32, lr_rule="sqrt",
                            regime_adaptation=True, grad_clip=1.0)


def default_regime() -> Regime:
    return Regime(base_lr=0.01, total_steps=10_000, drop_every=2_000)


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh
                ) -> Tuple[Dict[str, Sds], Dict[str, P]]:
    """Token batch + modality stubs (audio frames / vision patch embeds)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    shapes = {"tokens": Sds((B, S), jnp.int32)}
    specs = {"tokens": rules.batch_spec(mesh, B, 2)}
    if cfg.encoder is not None:
        F = S // cfg.encoder.frame_ratio
        shapes["frames"] = Sds((B, F, cfg.encoder.d_model), dt)
        specs["frames"] = rules.batch_spec(mesh, B, 3)
    if cfg.vision is not None:
        n = cfg.vision.n_image_tokens
        shapes["image_embeds"] = Sds((B, n, cfg.d_model), dt)
        specs["image_embeds"] = rules.batch_spec(mesh, B, 3)
    return shapes, specs


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(params, momentum_dtype: str = "bfloat16"):
    return jax.eval_shape(lambda p: sgd.init(p, momentum_dtype), params)


def opt_state_specs(param_spec_tree, momentum_dtype: str = "bfloat16",
                    abstract_opt=None, mesh=None):
    """Momentum shards exactly like its parameter; step replicated.

    int8 momentum is stored as blockwise {q (Nblk, 256), scale (Nblk, 1)} —
    the block axis is sharded over all mesh axes when divisible."""
    if momentum_dtype == "int8":
        assert abstract_opt is not None and mesh is not None

        def _axsize(ax):
            if ax is None:
                return 1
            if isinstance(ax, tuple):
                n = 1
                for a in ax:
                    n *= mesh.shape[a]
                return n
            return mesh.shape[ax]

        def mom_specs(pspec, qleaf):
            # q: param dims with the last split into (nb, 256); inherit the
            # param spec, keeping the last-dim axis on nb when it divides —
            # otherwise move it onto the 256-block axis (always divisible).
            axes = list(pspec)
            nb = qleaf["q"].shape[-2]
            last_ax = axes[-1] if axes else None
            lead = axes[:-1]
            if last_ax is not None and nb % _axsize(last_ax) != 0:
                return {"q": P(*lead, None, last_ax),
                        "scale": P(*lead, None, None)}
            return {"q": P(*lead, last_ax, None),
                    "scale": P(*lead, last_ax, None)}

        mom = jax.tree.map(mom_specs, param_spec_tree, abstract_opt.momentum,
                           is_leaf=lambda x: isinstance(x, P))
        return sgd.SGDState(momentum=mom, step=P())
    return sgd.SGDState(momentum=param_spec_tree, step=P())


def train_setup(cfg: ModelConfig, shape: InputShape, mesh, *,
                momentum_dtype: str = "bfloat16",
                use_kernels: bool = False,
                remat: bool = True,
                seq_parallel: bool = True,
                ce_chunk: int = 0,
                lb: Optional[LargeBatchConfig] = None
                ) -> Tuple[Callable, Tuple, Any]:
    """Returns (train_step, abstract args, in_shardings) ready to lower.

    ``remat=True``: full-block activation checkpointing — the production
    default (stored per-layer activations would not fit HBM at 1M tokens).
    """
    lb = lb or default_large_batch_config(shape)
    step_fn = make_lm_train_step(cfg, lb, default_regime(),
                                 use_kernels=use_kernels,
                                 momentum_dtype=momentum_dtype,
                                 remat=remat, seq_parallel=seq_parallel,
                                 ce_chunk=ce_chunk)
    params = abstract_params(cfg)
    opt = abstract_opt_state(params, momentum_dtype)
    bshapes, bspecs = batch_specs(cfg, shape, mesh)
    pspecs = rules.param_specs(params, mesh, cfg)
    ospecs = opt_state_specs(pspecs, momentum_dtype, opt, mesh)
    args = (params, opt, bshapes,
            Sds((), jnp.int32),            # step
            Sds((2,), jnp.uint32))         # rng key data
    in_specs = (pspecs, ospecs, bspecs, P(), P())
    return step_fn, args, jax.tree.map(
        lambda s: NamedSharding(mesh, s), in_specs,
        is_leaf=lambda s: isinstance(s, P))


def prefill_setup(cfg: ModelConfig, shape: InputShape, mesh, *,
                  use_kernels: bool = False) -> Tuple[Callable, Tuple, Any]:
    """Prefill: full-sequence forward producing last-position logits."""

    def prefill_step(params, batch):
        memory = T.get_memory(params, cfg, batch, use_kernels)
        logits, _ = T.forward(params, cfg, batch["tokens"], memory=memory,
                              use_kernels=use_kernels)
        return jnp.argmax(logits[:, -1], axis=-1)

    params = abstract_params(cfg)
    bshapes, bspecs = batch_specs(cfg, shape, mesh)
    pspecs = rules.param_specs(params, mesh, cfg)
    args = (params, bshapes)
    in_specs = (pspecs, bspecs)
    return prefill_step, args, jax.tree.map(
        lambda s: NamedSharding(mesh, s), in_specs,
        is_leaf=lambda s: isinstance(s, P))


def decode_setup(cfg: ModelConfig, shape: InputShape, mesh, *,
                 use_kernels: bool = False) -> Tuple[Callable, Tuple, Any]:
    """serve_step: ONE new token against a seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    mem_len = T.memory_len(cfg, S)
    serve_step = make_serve_step(cfg, use_kernels)
    params = abstract_params(cfg)
    # kernel decode reads the head-major cache natively (flash-decode's
    # KV-block layout); the grouped-einsum path keeps the seq-major layout
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, B, S, memory_len=mem_len, dtype=dt,
                             layout="head" if use_kernels else "seq"))
    pspecs = rules.param_specs(params, mesh, cfg)
    cspecs = rules.cache_specs(cache, mesh, B)
    args = (params, cache, Sds((B, 1), jnp.int32), Sds((), jnp.int32))
    in_specs = (pspecs, cspecs, rules.batch_spec(mesh, B, 2), P())
    return serve_step, args, jax.tree.map(
        lambda s: NamedSharding(mesh, s), in_specs,
        is_leaf=lambda s: isinstance(s, P))


def setup_for(cfg: ModelConfig, shape: InputShape, mesh, *,
              momentum_dtype: str = "bfloat16", use_kernels: bool = False,
              seq_parallel: bool = True, ce_chunk: int = 0):
    if shape.kind == "train":
        return train_setup(cfg, shape, mesh, momentum_dtype=momentum_dtype,
                           use_kernels=use_kernels,
                           seq_parallel=seq_parallel, ce_chunk=ce_chunk)
    if shape.kind == "prefill":
        return prefill_setup(cfg, shape, mesh, use_kernels=use_kernels)
    return decode_setup(cfg, shape, mesh, use_kernels=use_kernels)
