"""Training launcher.

Two modes:
- host mode (default): runs a real training loop on the local device(s) —
  the end-to-end driver (examples/train_100m.py uses it to train a ~100M
  LM for a few hundred steps on synthetic data).
- mesh mode (--mesh single|multi): builds the production mesh and runs the
  same pjit train step the dry-run lowers (requires real hardware of that
  size; on this container use launch.dryrun instead).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b-reduced \
        --steps 200 --batch 64 --seq-len 128 --lr-rule sqrt --ra
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save as ckpt_save
from repro.configs.registry import get_config
from repro.core import DiffusionTracker, LargeBatchConfig, Regime
from repro.data.synthetic import lm_sequences, token_lm
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.obs import Observability
from repro.obs.trace import NULL_TRACER
from repro.optim import sgd
from repro.sharding import rules
from repro.train.trainer import make_lm_train_step


def build_batches(cfg, *, batch: int, seq_len: int, n_tokens: int,
                  seed: int = 0):
    stream = token_lm(seed, vocab_size=cfg.vocab_size, n_tokens=n_tokens)
    seqs = lm_sequences(stream, seq_len)
    return seqs


def extra_inputs(cfg, batch: int, seq_len: int, rng) -> Dict[str, jax.Array]:
    out = {}
    # one independent subkey per synthetic modality: a config with both an
    # encoder and a vision tower must not draw the same latents twice
    r_frames, r_image = jax.random.split(rng)
    if cfg.encoder is not None:
        F = max(1, seq_len // cfg.encoder.frame_ratio)
        out["frames"] = 0.1 * jax.random.normal(
            r_frames, (batch, F, cfg.encoder.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.vision is not None:
        out["image_embeds"] = 0.1 * jax.random.normal(
            r_image, (batch, cfg.vision.n_image_tokens, cfg.d_model),
            jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b-reduced")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--base-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--base-lr", type=float, default=0.05)
    ap.add_argument("--lr-rule", default="sqrt",
                    choices=["sqrt", "linear", "none"])
    ap.add_argument("--ra", action="store_true", help="regime adaptation")
    ap.add_argument("--ghost-noise", type=float, default=0.0)
    ap.add_argument("--grad-clip", type=float, default=1.0)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--parallel", default="pjit",
                    choices=["pjit", "shard_map"],
                    help="pjit: GSPMD auto-sharding from sharding/rules.py; "
                         "shard_map: the unified 2-D layer "
                         "(train/parallel.py) — batch over dp axes, expert "
                         "weights over 'model', explicit collectives")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--trace", default="",
                    help="write a Chrome/Perfetto span trace JSON here")
    ap.add_argument("--metrics-out", default="",
                    help="append the metrics registry as JSONL here")
    args = ap.parse_args()

    obs = (Observability() if (args.trace or args.metrics_out) else None)
    tracer = obs.tracer if obs is not None else NULL_TRACER
    reg = obs.registry if obs is not None else None

    cfg = get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype=args.dtype)
    lb = LargeBatchConfig(
        batch_size=args.batch, base_batch_size=args.base_batch,
        lr_rule=args.lr_rule, regime_adaptation=args.ra,
        grad_clip=args.grad_clip, ghost_noise=args.ghost_noise)
    small = Regime(base_lr=args.base_lr, total_steps=args.steps,
                   drop_every=max(1, args.steps // 3))
    regime = lb.build_regime(small)

    mesh = {"host": make_host_mesh,
            "single": lambda: make_production_mesh(multi_pod=False),
            "multi": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()

    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    opt_state = sgd.init(params)
    if args.parallel == "shard_map":
        # unified 2-D layer: the shard_map carries its own mesh/specs — no
        # ambient mesh context, no pjit placement (the first step shards).
        step_fn = make_lm_train_step(cfg, lb, regime, mesh=mesh,
                                     params=params)
        mesh_ctx = contextlib.nullcontext()
    else:
        pshard = rules.param_shardings(params, mesh, cfg)
        params = jax.device_put(params, pshard)
        step_fn = make_lm_train_step(cfg, lb, regime)
        mesh_ctx = mesh
    with mesh_ctx:
        step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

        seqs = build_batches(cfg, batch=args.batch, seq_len=args.seq_len,
                             n_tokens=args.batch * args.seq_len * 64)
        nprng = np.random.RandomState(1)
        tracker = DiffusionTracker(params)
        t0 = time.time()
        for step in range(regime.total_steps):
            idx = nprng.randint(0, seqs.shape[0], size=args.batch)
            batch = {"tokens": jnp.asarray(seqs[idx])}
            batch.update(extra_inputs(cfg, args.batch, args.seq_len,
                                      jax.random.fold_in(rng, 10_000 + step)))
            ts = time.perf_counter()
            with tracer.span("train.step", step=step, batch=args.batch):
                params, opt_state, metrics = step_jit(
                    params, opt_state, batch, jnp.int32(step),
                    jax.random.fold_in(rng, step))
                if reg is not None:
                    jax.block_until_ready(metrics["loss"])
            if reg is not None:
                reg.observe("train/step_time_s", time.perf_counter() - ts)
                reg.observe("train/loss", float(metrics["loss"]))
                reg.set("train/lr", float(metrics["lr"]))
                reg.set("train/batch_size", args.batch)
                if "grad_norm" in metrics:
                    reg.observe("train/grad_norm",
                                float(metrics["grad_norm"]))
                reg.inc("train/steps")
            if step % args.log_every == 0 or step == regime.total_steps - 1:
                d = tracker.record(step + 1, params)
                if reg is not None:
                    reg.observe("train/weight_dist", float(d))
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"ce {float(metrics['ce']):.4f} "
                      f"lr {float(metrics['lr']):.4f} |w-w0| {d:.3f}",
                      flush=True)
        dt = time.time() - t0
        fit = tracker.log_fit(burn_in=2)
        print(f"done in {dt:.1f}s; log-diffusion fit slope="
              f"{fit['slope']:.3f} r2={fit['r2']:.3f}")
        if args.ckpt:
            ckpt_save(args.ckpt, regime.total_steps, params, opt_state,
                      extra={"arch": args.arch})
            print(f"checkpoint written to {args.ckpt}")
    if obs is not None:
        obs.write(args.trace, args.metrics_out)
        table = obs.summary()
        if table:
            print(table)


if __name__ == "__main__":
    main()
