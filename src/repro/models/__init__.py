from repro.models import blocks, cnn, layers, mlp, moe, ssm, transformer

__all__ = ["blocks", "cnn", "layers", "mlp", "moe", "ssm", "transformer"]
