"""LayerSpec interpreter: builds and applies transformer/ssm blocks.

A "block" is one LayerSpec: optional cross-attention sublayer, a sequence
mixer (attention / sliding-window attention / mamba), and a feed-forward
(dense SwiGLU / MoE / none), each with pre-norms and residuals.

The repeating ``body_pattern`` is executed as a ``lax.scan`` over stacked
parameters (one stack of ``body_repeats`` per pattern slot) so that HLO size
and compile time stay flat in network depth.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.core import expert_parallel as EP
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.sharding.hints import current_mesh, hint

Params = Dict[str, Any]


def _tp_axis(local_dim: int, full_dim: int) -> Optional[str]:
    """Megatron-in-region detection: inside a manual shard_map region
    (:func:`EP.manual_mode`) a block may receive the LOCAL tensor-parallel
    slice of its weights. Sliced-ness is inferred from the actual leaf shape
    vs the config's full width — the same always-agrees-with-the-spec-builder
    trick as :func:`EP.manual_shard_mode` — and the model axis name is
    returned so the caller can fence the sublayer with the region_in /
    region_out adjoint pair."""
    st = EP.manual_state()
    if st is None or st[0] is None:
        return None
    return st[0] if local_dim != full_dim else None


def _sp_hint(x: jax.Array, enabled: bool) -> jax.Array:
    """Megatron-style sequence parallelism: between blocks the residual
    stream is sharded over ('model' x sequence) in addition to the batch
    axes, so remat-saved block inputs shrink by the model-parallel degree.
    GSPMD inserts the all-gather at the qkv/mlp projections and turns the
    output all-reduces into reduce-scatters."""
    if not enabled or x.ndim != 3:
        return x
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    if x.shape[1] % mesh.shape["model"] != 0:
        return x
    return hint(x, "dp", "model", None)

ZERO_AUX = {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_init(rng, cfg: ModelConfig, spec: LayerSpec, dtype=jnp.float32
               ) -> Params:
    ks = jax.random.split(rng, 4)
    p: Params = {}
    if spec.mixer in ("attn", "swa"):
        p["norm1"] = L.norm_init(cfg, cfg.d_model, jnp.float32)
        p["mixer"] = L.attention_init(ks[0], cfg, dtype)
    elif spec.mixer == "ssm":
        p["norm1"] = L.norm_init(cfg, cfg.d_model, jnp.float32)
        p["mixer"] = SSM.ssm_init(ks[0], cfg, dtype)
    if spec.cross_attn:
        p["norm_x"] = L.norm_init(cfg, cfg.d_model, jnp.float32)
        p["cross"] = L.cross_attention_init(ks[1], cfg, dtype)
    if spec.ff == "dense":
        p["norm2"] = L.norm_init(cfg, cfg.d_model, jnp.float32)
        p["ff"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    elif spec.ff == "moe":
        p["norm2"] = L.norm_init(cfg, cfg.d_model, jnp.float32)
        p["ff"] = MOE.moe_init(ks[2], cfg, dtype)
    return p


def block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                memory_len: int = 0, dtype=jnp.bfloat16,
                layout: str = "seq", page_size: int = 64,
                total_pages: Optional[int] = None,
                cache_dtype: Optional[str] = None) -> Params:
    """Decode-time cache for one block. ``layout`` picks the KV cache
    layout: "seq" (B, S, kv, hd), "head" (B, kv, S, hd) — the flash-decode
    kernel's native layout — or "paged" (page pool + per-row block tables;
    SWA layers keep their head-major ring). ``cache_dtype="int8"``
    quantizes the paged pool per slot (see ``layers.init_kv_cache``)."""
    c: Params = {}
    if spec.mixer in ("attn", "swa"):
        window = cfg.sliding_window if spec.mixer == "swa" else None
        c["attn"] = L.init_kv_cache(cfg, batch, max_len, window, dtype,
                                    layout=layout, page_size=page_size,
                                    total_pages=total_pages,
                                    cache_dtype=cache_dtype)
    elif spec.mixer == "ssm":
        c["ssm"] = SSM.init_ssm_cache(cfg, batch)
    if spec.cross_attn:
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        c["cross_k"] = jnp.zeros((batch, memory_len, kv, hd), dtype=dtype)
        c["cross_v"] = jnp.zeros((batch, memory_len, kv, hd), dtype=dtype)
    return c


def block_apply(params: Params, cfg: ModelConfig, spec: LayerSpec,
                x: jax.Array, *,
                positions: Optional[jax.Array] = None,
                memory: Optional[jax.Array] = None,
                cache: Optional[Params] = None,
                pos: Optional[jax.Array] = None,
                decode: bool = False,
                causal: bool = True,
                use_kernels: bool = False,
                offsets: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Optional[Params], Dict[str, jax.Array]]:
    """Apply one block. Returns (x, new_cache or None, aux).

    Three cache modes: no cache (train / plain forward), ``decode=True``
    (one token against the cache), and PREFILL (``cache`` given with
    ``decode=False``): the full-sequence mixers run once and the resulting
    K/V / SSM state is scattered into the cache in the same pass.
    ``offsets`` (B,) are per-sequence left-pad widths for ragged prompts
    (threaded into the attention validity masks and SSM input masking).
    """
    aux = dict(ZERO_AUX)
    prefill = cache is not None and not decode
    new_cache: Params = {} if cache is not None else None

    if spec.cross_attn:
        h = L.norm_apply(cfg, params["norm_x"], x)
        if decode or prefill:
            y = L.cross_attention_apply(
                params["cross"], cfg, h, cache["cross_k"], cache["cross_v"])
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        else:
            k, v = L.cross_kv(params["cross"], cfg, memory)
            y = L.cross_attention_apply(params["cross"], cfg, h, k, v)
        x = x + y

    y_mix = None                 # mixer output, residual-add deferred to ff
    if spec.mixer in ("attn", "swa"):
        window = cfg.sliding_window if spec.mixer == "swa" else None
        h = L.norm_apply(cfg, params["norm1"], x)
        if decode:
            y_mix, kvc = L.attention_decode(params["mixer"], cfg, h,
                                            cache["attn"], pos, window=window,
                                            offsets=offsets,
                                            use_kernels=use_kernels)
            new_cache["attn"] = kvc
        elif prefill:
            y_mix, kvc = L.attention_prefill(params["mixer"], cfg, h,
                                             positions, cache["attn"],
                                             window=window, offsets=offsets,
                                             use_kernels=use_kernels)
            new_cache["attn"] = kvc
        else:
            ax = _tp_axis(params["mixer"]["wq"].shape[-1],
                          cfg.n_heads * cfg.head_dim)
            if ax is not None:
                # Megatron attention: head-split qkv (column-parallel) +
                # head-split wo (row-parallel). The whole sublayer is one
                # partial-sum region: identity-fwd/psum-bwd on everything
                # replicated entering it (the stream AND the per-head-dim
                # qk_norm scales), psum-fwd/identity-bwd on the way out.
                mp = dict(params["mixer"])
                for nk in ("q_norm", "k_norm"):
                    if nk in mp:
                        mp[nk] = {"scale": EP.region_in(mp[nk]["scale"], ax)}
                y_mix = EP.region_out(
                    L.attention_full(mp, cfg, EP.region_in(h, ax), positions,
                                     window=window, causal=causal,
                                     use_kernels=use_kernels), ax)
            else:
                y_mix = L.attention_full(params["mixer"], cfg, h, positions,
                                         window=window, causal=causal,
                                         use_kernels=use_kernels)
    elif spec.mixer == "ssm":
        h = L.norm_apply(cfg, params["norm1"], x)
        if decode:
            y_mix, sc = SSM.ssm_decode(params["mixer"], cfg, h, cache["ssm"])
            new_cache["ssm"] = sc
        elif prefill:
            valid = None
            if offsets is not None:
                valid = jnp.arange(x.shape[1])[None] >= offsets[:, None]
            y_mix, sc = SSM.ssm_prefill(params["mixer"], cfg, h, valid=valid,
                                        use_kernels=use_kernels)
            old = cache["ssm"]
            new_cache["ssm"] = {"h": sc["h"].astype(old["h"].dtype),
                                "conv": sc["conv"].astype(old["conv"].dtype)}
        else:
            y_mix = SSM.ssm_forward(params["mixer"], cfg, h,
                                    use_kernels=use_kernels)

    if spec.ff == "dense":
        # Fuse the mixer residual add with the ff pre-norm: one pass over
        # the stream instead of add-then-norm (no-op reassociation when
        # use_kernels is off or the norm isn't rmsnorm).
        if y_mix is not None:
            h, x = L.norm_residual_apply(cfg, params["norm2"], x, y_mix,
                                         use_kernels=use_kernels)
        else:
            h = L.norm_apply(cfg, params["norm2"], x)
        ax = _tp_axis(params["ff"]["w_gate"].shape[-1], cfg.d_ff)
        if ax is not None:
            # Megatron MLP: column-parallel w_gate/w_up, row-parallel w_down.
            x = x + EP.region_out(
                L.mlp_apply(params["ff"], EP.region_in(h, ax),
                            use_kernels=use_kernels), ax)
        else:
            x = x + L.mlp_apply(params["ff"], h, use_kernels=use_kernels)
    elif spec.ff == "moe":
        if y_mix is not None:
            x = x + y_mix
        h = L.norm_apply(cfg, params["norm2"], x)
        y, moe_aux = MOE.moe_apply(params["ff"], cfg, h)
        aux.update(moe_aux)
        x = x + y
    elif y_mix is not None:
        x = x + y_mix

    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stacks: head (unrolled) + body (scanned) + tail (unrolled)
# ---------------------------------------------------------------------------


def stack_init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    rh, rb, rt = jax.random.split(rng, 3)
    p: Params = {"head": [], "body": [], "tail": []}
    for i, spec in enumerate(cfg.head_pattern):
        p["head"].append(block_init(jax.random.fold_in(rh, i), cfg, spec, dtype))
    for i, spec in enumerate(cfg.body_pattern):
        slot_rng = jax.random.fold_in(rb, i)
        rngs = jax.random.split(slot_rng, cfg.body_repeats)
        p["body"].append(
            jax.vmap(lambda r: block_init(r, cfg, spec, dtype))(rngs))
    for i, spec in enumerate(cfg.tail_pattern):
        p["tail"].append(block_init(jax.random.fold_in(rt, i), cfg, spec, dtype))
    return p


def stack_cache(cfg: ModelConfig, batch: int, max_len: int,
                memory_len: int = 0, dtype=jnp.bfloat16,
                layout: str = "seq", page_size: int = 64,
                total_pages: Optional[int] = None,
                cache_dtype: Optional[str] = None) -> Params:
    def one(spec):
        return block_cache(cfg, spec, batch, max_len, memory_len, dtype,
                           layout, page_size=page_size,
                           total_pages=total_pages, cache_dtype=cache_dtype)

    def stacked(spec):
        c = one(spec)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.body_repeats,) + a.shape).copy()
            if cfg.body_repeats > 1 else a[None], c)

    return {
        "head": [one(s) for s in cfg.head_pattern],
        "body": [stacked(s) for s in cfg.body_pattern],
        "tail": [one(s) for s in cfg.tail_pattern],
    }


def _sum_aux(acc: Dict, new: Dict) -> Dict:
    return {k: acc[k] + new[k] for k in acc}


def stack_apply(params: Params, cfg: ModelConfig, x: jax.Array, *,
                positions: Optional[jax.Array] = None,
                memory: Optional[jax.Array] = None,
                cache: Optional[Params] = None,
                pos: Optional[jax.Array] = None,
                decode: bool = False,
                causal: bool = True,
                use_kernels: bool = False,
                remat: bool = False,
                seq_parallel: bool = False,
                offsets: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Optional[Params], Dict[str, jax.Array]]:
    """Run the full head+body+tail stack.

    ``remat=True`` wraps each block in ``jax.checkpoint`` (full block
    rematerialization) — required for the production train configs, where
    storing per-layer activations for 4k x 256 batches would exceed HBM.
    ``seq_parallel=True`` additionally shards the residual stream over
    (sequence x 'model') between blocks (see ``_sp_hint``).
    ``cache`` with ``decode=False`` is the fused-prefill mode (see
    ``block_apply``); ``offsets`` are the ragged-prompt left-pad widths.
    """
    aux = dict(ZERO_AUX)
    new_cache = {"head": [], "body": [], "tail": []} if cache is not None else None

    def make_block_fn(spec: LayerSpec):
        """Bind the static arguments; optionally wrap in jax.checkpoint."""
        def fn(p, x, c, positions, memory):
            x = _sp_hint(x, seq_parallel)
            out = block_apply(p, cfg, spec, x, cache=c, positions=positions,
                              memory=memory, pos=pos, decode=decode,
                              causal=causal, use_kernels=use_kernels,
                              offsets=offsets)
            return (_sp_hint(out[0], seq_parallel),) + out[1:]
        if remat:
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn

    block_fns = {}

    def apply_block(p, spec, x, c):
        if spec not in block_fns:
            block_fns[spec] = make_block_fn(spec)
        return block_fns[spec](p, x, c, positions, memory)

    for i, spec in enumerate(cfg.head_pattern):
        c = cache["head"][i] if cache is not None else None
        x, nc, a = apply_block(params["head"][i], spec, x, c)
        aux = _sum_aux(aux, a)
        if cache is not None:
            new_cache["head"].append(nc)

    if cfg.body_pattern:
        def body(carry, xs):
            xb = carry
            slot_params, slot_caches = xs
            aux_b = dict(ZERO_AUX)
            ncs = []
            for j, spec in enumerate(cfg.body_pattern):
                c = slot_caches[j] if slot_caches is not None else None
                xb, nc, a = apply_block(slot_params[j], spec, xb, c)
                aux_b = _sum_aux(aux_b, a)
                ncs.append(nc)
            ys = (tuple(ncs) if slot_caches is not None else 0, aux_b)
            return xb, ys

        body_caches = (tuple(cache["body"]) if cache is not None else None)
        xs = (tuple(params["body"]), body_caches) if cache is not None \
            else (tuple(params["body"]), None)
        if cache is not None:
            x, (ncs, aux_b) = jax.lax.scan(body, x, xs)
            new_cache["body"] = list(ncs)
        else:
            # no cache: scan only over params
            def body_nc(carry, slot_params):
                xb, ys = body(carry, (slot_params, None))
                return xb, ys[1]
            x, aux_b = jax.lax.scan(body_nc, x, tuple(params["body"]))
        aux = _sum_aux(aux, jax.tree.map(jnp.sum, aux_b))

    for i, spec in enumerate(cfg.tail_pattern):
        c = cache["tail"][i] if cache is not None else None
        x, nc, a = apply_block(params["tail"][i], spec, x, c)
        aux = _sum_aux(aux, a)
        if cache is not None:
            new_cache["tail"].append(nc)

    return x, new_cache, aux
