"""The paper's convolutional models: C1/C3-style shallow convnets (Keskar et
al. 2017) and ResNet44 / WResNet-style residual networks (He et al. 2016;
Zagoruyko 2016), all with (ghost) batch normalization — the models behind
Table 1 and Figures 1-3.

NHWC layout; BN statistics reduce over (ghost-batch, H, W) per channel.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_models import VisionModelConfig
from repro.models.vision_common import norm_apply, norm_init

Params = Dict[str, Any]


def _conv_init(rng, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(rng, (kh, kw, cin, cout)) * math.sqrt(2.0 / fan_in)
    return w


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _avgpool_all(x):
    return x.mean(axis=(1, 2))


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


# ---------------------------------------------------------------------------
# C1/C3-style shallow convnet
# ---------------------------------------------------------------------------


def convnet_init(rng, cfg: VisionModelConfig) -> Tuple[Params, Params]:
    params: Params = {"stages": [], "out": None}
    state: Params = {"stages": []}
    cin = cfg.input_shape[2]
    for i, cout in enumerate(cfg.channels):
        r = jax.random.fold_in(rng, i)
        np_, ns = norm_init(cfg, cout)
        params["stages"].append({
            "w": _conv_init(r, 3, 3, cin, cout),
            "norm": np_,
        })
        state["stages"].append(ns)
        cin = cout
    feat = cin
    params["out"] = {
        "w": jax.random.normal(jax.random.fold_in(rng, 777),
                               (feat, cfg.n_classes)) / math.sqrt(feat),
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params, state


def convnet_apply(params: Params, state: Params, cfg: VisionModelConfig,
                  x: jax.Array, *, training: bool = True,
                  ghost_batch_size: Optional[int] = None,
                  use_gbn: Optional[bool] = None,
                  use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    new_state: Params = {"stages": []}
    for sp, ss in zip(params["stages"], state["stages"]):
        x = _conv(x, sp["w"])
        x, ns = norm_apply(cfg, sp["norm"], ss, x, training=training,
                           ghost_batch_size=ghost_batch_size,
                           use_gbn=use_gbn, use_kernels=use_kernels)
        new_state["stages"].append(ns)
        x = jax.nn.relu(x)
        if x.shape[1] > 2:
            x = _maxpool2(x)
    x = _avgpool_all(x)
    logits = x @ params["out"]["w"] + params["out"]["b"]
    return logits, new_state


# ---------------------------------------------------------------------------
# ResNet44 / WResNet16-4 style residual network
# ---------------------------------------------------------------------------


def resnet_init(rng, cfg: VisionModelConfig) -> Tuple[Params, Params]:
    params: Params = {"stem": None, "stages": [], "out": None}
    state: Params = {"stem": None, "stages": []}
    c0 = cfg.channels[0]
    params["stem"] = {"w": _conv_init(jax.random.fold_in(rng, 0), 3, 3,
                                      cfg.input_shape[2], c0)}
    np_, ns = norm_init(cfg, c0)
    params["stem"]["norm"] = np_
    state["stem"] = ns
    cin = c0
    for si, cout in enumerate(cfg.channels):
        stage_p, stage_s = [], []
        for bi in range(cfg.blocks_per_stage):
            r = jax.random.fold_in(rng, 100 * (si + 1) + bi)
            r1, r2, r3 = jax.random.split(r, 3)
            n1p, n1s = norm_init(cfg, cout)
            n2p, n2s = norm_init(cfg, cout)
            blk = {
                "w1": _conv_init(r1, 3, 3, cin, cout),
                "norm1": n1p,
                "w2": _conv_init(r2, 3, 3, cout, cout),
                "norm2": n2p,
            }
            if cin != cout:
                blk["proj"] = _conv_init(r3, 1, 1, cin, cout)
            stage_p.append(blk)
            stage_s.append({"norm1": n1s, "norm2": n2s})
            cin = cout
        params["stages"].append(stage_p)
        state["stages"].append(stage_s)
    params["out"] = {
        "w": jax.random.normal(jax.random.fold_in(rng, 888),
                               (cin, cfg.n_classes)) / math.sqrt(cin),
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params, state


def resnet_apply(params: Params, state: Params, cfg: VisionModelConfig,
                 x: jax.Array, *, training: bool = True,
                 ghost_batch_size: Optional[int] = None,
                 use_gbn: Optional[bool] = None,
                 use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    kw = dict(training=training, ghost_batch_size=ghost_batch_size,
              use_gbn=use_gbn, use_kernels=use_kernels)
    new_state: Params = {"stem": None, "stages": []}
    x = _conv(x, params["stem"]["w"])
    x, ns = norm_apply(cfg, params["stem"]["norm"], state["stem"], x, **kw)
    new_state["stem"] = ns
    x = jax.nn.relu(x)
    for si, (stage_p, stage_s) in enumerate(zip(params["stages"],
                                                state["stages"])):
        ns_stage = []
        for bi, (blk, bs) in enumerate(zip(stage_p, stage_s)):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _conv(x, blk["w1"], stride=stride)
            h, n1 = norm_apply(cfg, blk["norm1"], bs["norm1"], h, **kw)
            h = jax.nn.relu(h)
            h = _conv(h, blk["w2"])
            h, n2 = norm_apply(cfg, blk["norm2"], bs["norm2"], h, **kw)
            skip = x
            if "proj" in blk:
                skip = _conv(x, blk["proj"], stride=stride)
            elif stride != 1:
                skip = x[:, ::stride, ::stride, :]
            x = jax.nn.relu(h + skip)
            ns_stage.append({"norm1": n1, "norm2": n2})
        new_state["stages"].append(ns_stage)
    x = _avgpool_all(x)
    logits = x @ params["out"]["w"] + params["out"]["b"]
    return logits, new_state


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def init(rng, cfg: VisionModelConfig) -> Tuple[Params, Params]:
    if cfg.kind == "convnet":
        return convnet_init(rng, cfg)
    if cfg.kind == "resnet":
        return resnet_init(rng, cfg)
    raise ValueError(cfg.kind)


def apply(params, state, cfg, x, **kw):
    if cfg.kind == "convnet":
        return convnet_apply(params, state, cfg, x, **kw)
    if cfg.kind == "resnet":
        return resnet_apply(params, state, cfg, x, **kw)
    raise ValueError(cfg.kind)


def model_fns(cfg: VisionModelConfig):
    """Returns (init, apply) for any paper model config (mlp included)."""
    if cfg.kind == "mlp":
        from repro.models import mlp as M
        return M.init, M.apply
    return init, apply
