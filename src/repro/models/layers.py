"""Core neural-net layers: norms, RoPE, GQA attention (full / sliding-window /
cross), SwiGLU MLP.

All layers are pure functions over explicit parameter pytrees:

    params = <layer>_init(rng, ...)
    y      = <layer>_apply(params, x, ...)

Compute happens in ``compute_dtype`` (bf16 on the production configs, fp32 in
smoke tests); parameters are stored in the dtype they were initialised with.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.hints import hint, model_axis_if

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Glorot/He-style scaled normal init (paper uses Glorot & Bengio 2010)."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    if scale is None:
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (scale * jax.random.normal(rng, shape, dtype=jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm_apply(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


def norm_init(cfg: ModelConfig, d: int, dtype=jnp.float32) -> Params:
    if cfg.norm.kind == "layernorm":
        return layernorm_init(d, dtype)
    return rmsnorm_init(d, dtype)


def norm_apply(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    if cfg.norm.kind == "layernorm":
        return layernorm_apply(params, x, cfg.norm.eps)
    return rmsnorm_apply(params, x, cfg.norm.eps)


def norm_residual_apply(cfg: ModelConfig, params: Params, x: jax.Array,
                        r: jax.Array, *, use_kernels: bool = False
                        ) -> Tuple[jax.Array, jax.Array]:
    """Fused sublayer seam: residual add + pre-norm in one pass. Returns
    ``(norm(x + r) * scale, x + r)`` — the normed input of the next sublayer
    and the new residual stream. The fused Pallas kernel
    (:func:`repro.kernels.ops.rmsnorm_residual`) only covers rmsnorm; the
    layernorm configs take the unfused two-pass path."""
    if use_kernels and cfg.norm.kind == "rmsnorm":
        from repro.kernels import ops as kops
        return kops.rmsnorm_residual(x, r, params["scale"], eps=cfg.norm.eps)
    s = x + r
    return norm_apply(cfg, params, s), s


# ---------------------------------------------------------------------------
# rotary position embedding (half-rotation / llama convention)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    dt = x.dtype
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., T, hd/2)
    angles = angles[..., None, :]                          # (..., T, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
                 positions: Optional[jax.Array], rope: bool = True):
    B = x.shape[0]
    T = x.shape[1]
    hd = cfg.head_dim
    dt = x.dtype
    # head counts come from the WEIGHT shapes, not cfg: under Megatron-style
    # tensor parallelism the shard_map region hands this function the local
    # head-slice (h/msize heads), and every downstream op is per-head.
    h = params["wq"].shape[-1] // hd
    kv = params["wk"].shape[-1] // hd
    q = (x @ params["wq"].astype(dt)).reshape(B, T, h, hd)
    k = (x @ params["wk"].astype(dt)).reshape(B, T, kv, hd)
    v = (x @ params["wv"].astype(dt)).reshape(B, T, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q, cfg.norm.eps)
        k = rmsnorm_apply(params["k_norm"], k, cfg.norm.eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array]) -> jax.Array:
    """q: (B,T,h,hd); k,v: (B,S,kv,hd). GQA: kv heads are repeated to h —
    the repeat is transient (layer-local) and lets the head axis shard over
    the 'model' mesh axis regardless of the kv:q ratio.
    mask: broadcastable to (B, T, S), True = attend."""
    B, T, h, hd = q.shape
    S, kv = k.shape[1], k.shape[2]
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = hint(q, "dp", None, "model", None)
    k = hint(k, "dp", None, "model", None)
    v = hint(v, "dp", None, "model", None)
    logits = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
    logits = hint(logits / math.sqrt(hd), "dp", "model", None, None)
    if mask is not None:
        m = jnp.broadcast_to(mask, (B,) + mask.shape[-2:])
        logits = jnp.where(m[:, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhts,bshd->bthd", probs, v)
    return hint(out, "dp", None, "model", None)


def _sdpa_grouped(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: Optional[jax.Array]) -> jax.Array:
    """Decode-path attention WITHOUT repeating K/V to full heads (§Perf:
    repeating a 500k-token cache materialises gigabytes per layer per token).
    q: (B,T,h,hd); k,v: (B,S,kv,hd); GQA via grouped einsum; kv heads are
    sharded over 'model' when divisible (cache rule), so hint accordingly."""
    B, T, h, hd = q.shape
    S, kv = k.shape[1], k.shape[2]
    g = h // kv
    kv_ax = model_axis_if(kv)   # shard kv heads only when they divide evenly
    qg = q.reshape(B, T, kv, g, hd)
    if kv_ax is not None:
        # kv-head-parallel decode: keep q/k/v and logits head-sharded
        qg = hint(qg, "dp", None, kv_ax, None, None)
        k = hint(k, "dp", None, kv_ax, None)
        v = hint(v, "dp", None, kv_ax, None)
    # else: leave k/v alone — the cache is sequence-sharded over 'model'
    # (rules.cache_specs) and forcing replication here would all-gather it.
    logits = jnp.einsum("btkgd,bskd->bktgs", qg, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if kv_ax is not None:
        logits = hint(logits, "dp", kv_ax, None, None, None)
    if mask is not None:
        m = jnp.broadcast_to(mask, (B,) + mask.shape[-2:])
        logits = jnp.where(m[:, None, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bktgs,bskd->btkgd", probs, v)
    return hint(out.reshape(B, T, h, hd), "dp", None, None, None)


def causal_mask(T: int, S: int, offset: int = 0) -> jax.Array:
    """True where query t (global index t+offset) may attend key s."""
    qi = jnp.arange(T)[:, None] + offset
    ki = jnp.arange(S)[None, :]
    return ki <= qi


def window_mask(T: int, S: int, window: int, offset: int = 0) -> jax.Array:
    qi = jnp.arange(T)[:, None] + offset
    ki = jnp.arange(S)[None, :]
    return (ki <= qi) & (ki > qi - window)


def _local_attention(q, k, v, window: int, dtype) -> jax.Array:
    """Block-local sliding-window attention with O(T * 2*window) cost.

    Pads T to a multiple of ``window``; each query block attends its own and
    the previous key block, masked to exactly ``window`` history.
    """
    B, T, h, hd = q.shape
    kv = k.shape[2]
    W = window
    Tp = (T + W - 1) // W * W
    pad = Tp - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = Tp // W
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qb = hint(q.reshape(B, nb, W, h, hd), "dp", None, None, "model", None)
    kb = hint(k.reshape(B, nb, W, h, hd), "dp", None, None, "model", None)
    vb = hint(v.reshape(B, nb, W, h, hd), "dp", None, None, "model", None)
    # keys for block i = concat(block i-1, block i): (B, nb, 2W, h, hd)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    logits = jnp.einsum("bnwhd,bnshd->bnhws", qb, k2).astype(jnp.float32)
    logits = hint(logits / math.sqrt(hd), "dp", None, "model", None, None)
    # in-block relative positions: query w (0..W-1) at global offset W + w
    qi = jnp.arange(W)[:, None] + W
    ki = jnp.arange(2 * W)[None, :]
    m = (ki <= qi) & (ki > qi - W)                  # (W, 2W)
    # first block has no previous block
    first = jnp.arange(nb)[:, None, None] > 0
    m = m[None] & (first | (ki[None] >= W))
    logits = jnp.where(m[None, :, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    out = jnp.einsum("bnhws,bnshd->bnwhd", probs, v2)
    out = out.reshape(B, Tp, h, hd)
    return out[:, :T]


def attention_full(params: Params, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array, *, window: Optional[int] = None,
                   causal: bool = True,
                   segment_mask: Optional[jax.Array] = None,
                   use_kernels: bool = False) -> jax.Array:
    """Self-attention over a full sequence (training / prefill)."""
    B, T, _ = x.shape
    if use_kernels and causal and segment_mask is None:
        from repro.kernels import ops as kops
        # RoPE rides inside the kernel's q/k loads (no separate apply_rope
        # pass over the full (B, T, H, hd) tensors)
        q, k, v = _project_qkv(params, cfg, x, positions, rope=False)
        out = kops.flash_attention_rope(q, k, v, positions,
                                        theta=cfg.rope_theta, causal=True,
                                        window=window)
        return out.reshape(B, T, -1) @ params["wo"].astype(x.dtype)
    q, k, v = _project_qkv(params, cfg, x, positions)
    if window is not None and causal and T > 2 * window and segment_mask is None:
        out = _local_attention(q, k, v, window, x.dtype)
    else:
        if causal:
            m = (window_mask(T, T, window) if window is not None
                 else causal_mask(T, T))
        else:
            m = jnp.ones((T, T), dtype=bool)
        if segment_mask is not None:
            m = m & segment_mask
        out = _sdpa(q, k, v, m[None] if m.ndim == 2 else m)
    return out.reshape(B, T, -1) @ params["wo"].astype(x.dtype)


# -- decode (one new token against a KV cache) ------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int] = None, dtype=jnp.bfloat16,
                  layout: str = "seq", page_size: int = 64,
                  total_pages: Optional[int] = None,
                  cache_dtype: Optional[str] = None) -> Params:
    """KV cache for one attention layer. SWA layers use a ring buffer of
    ``window`` slots; full layers allocate ``max_len``.

    ``layout="seq"`` stores (B, S, kv, hd) — the layout the grouped-einsum
    decode path and the sharding rules expect. ``layout="head"`` stores
    (B, kv, S, hd) under keys ``kh``/``vh`` — the flash-decode kernel's
    native layout (the sequence axis lands on the sublane axis of its KV
    blocks). ``layout="paged"`` stores a physical page pool ``kp``/``vp``
    (total_pages, kv, page_size, hd) plus per-row int32 block tables ``pt``
    (batch, ceil(max_len / page_size)) mapping logical block i to a
    physical page — the continuous-batching layout where rows reserve
    pages as they grow instead of worst-case contiguous memory. Physical
    page 0 is RESERVED as the trash page: unallocated / retired table
    entries point at it, so stray writes land somewhere harmless and the
    kernel's gather never reads out of bounds. SWA layers under "paged"
    fall back to the head-major ring (a window-bounded ring is already its
    own worst case — paging it buys nothing). The key names carry the
    layout, so every consumer can self-describe instead of threading a
    flag.

    ``cache_dtype="int8"`` (paged only; other layouts raise) stores the
    page pool as int8 codes with per-slot f32 scales ``ks``/``vs``
    (pages, kv, page_size) — half the pool payload per slot, so the same
    pool memory holds ~2x the rows; decode dequantizes inside the kernel
    (see docs/serving.md for the accuracy trade-off). SWA layers riding a
    paged cache keep their full-precision head-major ring (the
    window-bounded ring is small; quantizing it buys ~nothing)."""
    if cache_dtype not in (None, "int8"):
        raise ValueError(f"unknown cache_dtype: {cache_dtype!r}")
    if cache_dtype == "int8" and layout != "paged":
        raise ValueError(
            "cache_dtype='int8' requires layout='paged' (the contiguous "
            "layouts have no per-slot scale planes)")
    S = min(max_len, window) if window is not None else max_len
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if layout == "paged" and window is None:
        nb = -(-max_len // page_size)
        pages = total_pages if total_pages is not None else 1 + batch * nb
        if cache_dtype == "int8":
            return {
                "kp": jnp.zeros((pages, kv, page_size, hd), dtype=jnp.int8),
                "vp": jnp.zeros((pages, kv, page_size, hd), dtype=jnp.int8),
                "ks": jnp.zeros((pages, kv, page_size), dtype=jnp.float32),
                "vs": jnp.zeros((pages, kv, page_size), dtype=jnp.float32),
                "pt": jnp.zeros((batch, nb), dtype=jnp.int32),
            }
        return {
            "kp": jnp.zeros((pages, kv, page_size, hd), dtype=dtype),
            "vp": jnp.zeros((pages, kv, page_size, hd), dtype=dtype),
            "pt": jnp.zeros((batch, nb), dtype=jnp.int32),
        }
    if layout in ("head", "paged"):
        return {
            "kh": jnp.zeros((batch, kv, S, hd), dtype=dtype),
            "vh": jnp.zeros((batch, kv, S, hd), dtype=dtype),
        }
    return {
        "k": jnp.zeros((batch, S, kv, hd), dtype=dtype),
        "v": jnp.zeros((batch, S, kv, hd), dtype=dtype),
    }


def _cache_kv(cache: Params) -> Tuple[jax.Array, jax.Array, bool]:
    """(k, v, head_major) for either cache layout."""
    if "kh" in cache:
        return cache["kh"], cache["vh"], True
    return cache["k"], cache["v"], False


def _cache_valid_mask(pos, S: int, *, ring: bool,
                      offsets: Optional[jax.Array]) -> jax.Array:
    """(B?, S) visibility of cache slots at query position ``pos``.

    Delegates to the SAME ``_slot_visibility`` predicate the flash-decode
    kernel and its blockwise lowering use, so the kernel and non-kernel
    decode masks cannot drift. ``pos`` is a scalar or a per-row (B,)
    vector. Slot ``s`` holds global position ``s`` (full cache) or
    ``pos - ((pos - s) mod S)`` (ring buffer); window membership is
    implied by the ring depth (S = min(max_len, window)). ``offsets`` adds
    the per-sequence left-pad bound for ragged prompts. Returns (S,) only
    for scalar ``pos`` with no offsets, (B, S) otherwise."""
    from repro.kernels.flash_decode import _slot_visibility
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim:
        pos = pos.reshape(-1, 1)                            # (B, 1)
    idx = jnp.arange(S) if (pos.ndim == 0 and offsets is None) \
        else jnp.arange(S)[None, :]
    return _slot_visibility(
        idx, pos, seq_k=S, window=None, ring=ring,
        offset=None if offsets is None else offsets[:, None])


def attention_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Params, pos: jax.Array, *,
                     window: Optional[int] = None,
                     offsets: Optional[jax.Array] = None,
                     use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    """One-token decode. x: (B, 1, D); pos: scalar int32 (every row at the
    same index) or per-row (B,) int32 (continuous batching).

    ``offsets`` (B,) int32: per-sequence left-pad widths for ragged
    prompts — RoPE positions become ``pos - offsets[b]`` and cache slots
    before each sequence's first real token are masked.
    ``use_kernels=True`` routes the cache attention through the Pallas
    flash-decode kernel (native on a head-major or paged cache; a
    seq-major cache is transposed on the fly — correct but not the fast
    path). A paged cache (``kp``/``vp``/``pt``, see ``init_kv_cache``)
    writes this token's K/V into the page holding slot ``pos`` via the
    row's block table and attends by gather — a retired row whose table
    was zeroed writes harmlessly into the reserved trash page 0.

    Returns (y (B,1,D), new_cache).
    """
    B = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = jnp.asarray(pos, jnp.int32)
    vector_pos = pos.ndim > 0
    posb = jnp.broadcast_to(pos.reshape(-1), (B,))
    if offsets is None:
        positions = posb[:, None]
    else:
        positions = (posb - offsets)[:, None].astype(jnp.int32)
    # kernel paths fuse the query rotation into the decode kernel
    # (rope_theta below) — only the cached key still needs its write-time
    # rotation here; the non-kernel paths rotate both as before
    q, k, v = _project_qkv(params, cfg, x, positions, rope=not use_kernels)
    if use_kernels:
        k = apply_rope(k, positions, cfg.rope_theta)

    if "pt" in cache:                  # paged pool + per-row block tables
        from repro.kernels import ops as kops
        from repro.kernels.flash_decode import _slot_visibility
        kp, vp, pt = cache["kp"], cache["vp"], cache["pt"]
        quantized = "ks" in cache
        ps, NB = kp.shape[2], pt.shape[1]
        b_idx = jnp.arange(B)
        page = pt[b_idx, jnp.clip(posb // ps, 0, NB - 1)]   # (B,)
        if quantized:
            # per-slot symmetric int8: one f32 scale per (row, kv head),
            # chosen so the largest |component| maps to 127
            kw, vw = k[:, 0], v[:, 0]                       # (B, kv, hd)
            ksc = jnp.maximum(jnp.abs(kw).max(axis=-1), 1e-8) / 127.0
            vsc = jnp.maximum(jnp.abs(vw).max(axis=-1), 1e-8) / 127.0
            kq = jnp.clip(jnp.round(kw / ksc[..., None]),
                          -127, 127).astype(jnp.int8)
            vq = jnp.clip(jnp.round(vw / vsc[..., None]),
                          -127, 127).astype(jnp.int8)
            kp = kp.at[page, :, posb % ps].set(kq)
            vp = vp.at[page, :, posb % ps].set(vq)
            ks_ = cache["ks"].at[page, :, posb % ps].set(
                ksc.astype(jnp.float32))
            vs_ = cache["vs"].at[page, :, posb % ps].set(
                vsc.astype(jnp.float32))
            new_cache = {"kp": kp, "vp": vp, "ks": ks_, "vs": vs_, "pt": pt}
        else:
            kp = kp.at[page, :, posb % ps].set(k[:, 0].astype(kp.dtype))
            vp = vp.at[page, :, posb % ps].set(v[:, 0].astype(vp.dtype))
            new_cache = {"kp": kp, "vp": vp, "pt": pt}
        if use_kernels:
            if quantized:
                out = kops.flash_decode_paged(
                    q, kp, vp, pt, posb, window=window, offsets=offsets,
                    k_scale=new_cache["ks"], v_scale=new_cache["vs"],
                    rope_theta=cfg.rope_theta)
            else:
                out = kops.flash_decode_paged(
                    q, kp.astype(q.dtype), vp.astype(q.dtype), pt, posb,
                    window=window, offsets=offsets,
                    rope_theta=cfg.rope_theta)
        else:
            S = NB * ps
            kg = kp[pt].transpose(0, 2, 1, 3, 4).reshape(B, kv, S, hd)
            vg = vp[pt].transpose(0, 2, 1, 3, 4).reshape(B, kv, S, hd)
            if quantized:
                ksg = new_cache["ks"][pt].transpose(0, 2, 1, 3) \
                    .reshape(B, kv, S, 1)
                vsg = new_cache["vs"][pt].transpose(0, 2, 1, 3) \
                    .reshape(B, kv, S, 1)
                kg = (kg.astype(jnp.float32) * ksg).astype(q.dtype)
                vg = (vg.astype(jnp.float32) * vsg).astype(q.dtype)
            m = _slot_visibility(
                jnp.arange(S)[None, :], posb[:, None], seq_k=S,
                window=window, ring=False,
                offset=None if offsets is None else offsets[:, None])
            out = _sdpa_grouped(q, kg.swapaxes(1, 2).astype(q.dtype),
                                vg.swapaxes(1, 2).astype(q.dtype),
                                m[:, None, :])
        y = out.reshape(B, 1, h * hd) @ params["wo"].astype(x.dtype)
        return y, new_cache

    ck, cv, head_major = _cache_kv(cache)
    seq_ax = 2 if head_major else 1
    S = ck.shape[seq_ax]
    if vector_pos:
        slot_b = posb % S if window is not None else posb
        b_idx = jnp.arange(B)
        if head_major:
            ck = ck.at[b_idx, :, slot_b].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[b_idx, :, slot_b].set(v[:, 0].astype(cv.dtype))
        else:
            ck = ck.at[b_idx, slot_b].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[b_idx, slot_b].set(v[:, 0].astype(cv.dtype))
    else:
        slot = pos % S if window is not None else pos
        start = (0, 0, slot, 0) if head_major else (0, slot, 0, 0)
        kw = k.swapaxes(1, 2) if head_major else k
        vw = v.swapaxes(1, 2) if head_major else v
        ck = jax.lax.dynamic_update_slice(ck, kw.astype(ck.dtype), start)
        cv = jax.lax.dynamic_update_slice(cv, vw.astype(cv.dtype), start)
    new_cache = {"kh": ck, "vh": cv} if head_major else {"k": ck, "v": cv}
    ring = window is not None
    kernel_pos = posb if vector_pos else pos
    if use_kernels:
        from repro.kernels import ops as kops
        khm = ck if head_major else ck.swapaxes(1, 2)
        vhm = cv if head_major else cv.swapaxes(1, 2)
        out = kops.flash_decode(q, khm.astype(q.dtype), vhm.astype(q.dtype),
                                kernel_pos, window=window, ring=ring,
                                offsets=offsets, rope_theta=cfg.rope_theta)
    else:
        valid = _cache_valid_mask(kernel_pos, S, ring=ring, offsets=offsets)
        m = jnp.broadcast_to(valid[None, None, :] if valid.ndim == 1
                             else valid[:, None, :], (B, 1, S))
        ks = ck.swapaxes(1, 2) if head_major else ck
        vs = cv.swapaxes(1, 2) if head_major else cv
        out = _sdpa_grouped(q, ks.astype(q.dtype), vs.astype(q.dtype), m)
    y = out.reshape(B, 1, h * hd) @ params["wo"].astype(x.dtype)
    return y, new_cache


def attention_prefill(params: Params, cfg: ModelConfig, x: jax.Array,
                      positions: jax.Array, cache: Params, *,
                      window: Optional[int] = None,
                      offsets: Optional[jax.Array] = None,
                      use_kernels: bool = False
                      ) -> Tuple[jax.Array, Params]:
    """Fused prefill for one attention layer: full-sequence attention that
    also scatters every position's K/V into the decode cache in one pass.

    x: (B, P, D); positions: (B, P) RoPE positions (already offset for
    left-padded ragged prompts). Full caches receive tokens 0..P-1 at slots
    0..P-1; SWA ring caches keep the last ``min(P, ring)`` tokens at their
    ring slots ``t % ring``. Returns (y (B, P, D), filled cache).
    """
    B, P, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    ck, cv, head_major = _cache_kv(cache)
    seq_ax = 2 if head_major else 1
    S = ck.shape[seq_ax]
    assert window is not None or P <= S, (P, S)

    def fill(c, t):
        if head_major:
            t = t.swapaxes(1, 2)
        if P <= S:
            return jax.lax.dynamic_update_slice(c, t.astype(c.dtype),
                                                (0, 0, 0, 0))
        # ring wrap: keep the last S tokens; token at global position g
        # lands at slot g % S, i.e. the (P - S)-rotated tail of the window
        tail = jax.lax.slice_in_dim(t, P - S, P, axis=seq_ax)
        return jnp.roll(tail, (P - S) % S, axis=seq_ax).astype(c.dtype)

    new_cache = {"kh": fill(ck, k), "vh": fill(cv, v)} if head_major \
        else {"k": fill(ck, k), "v": fill(cv, v)}

    if use_kernels:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window,
                                   kv_offsets=offsets)
    else:
        m = (window_mask(P, P, window) if window is not None
             else causal_mask(P, P))
        if offsets is not None:
            m = m[None] & (jnp.arange(P)[None, None, :]
                           >= offsets[:, None, None])
        else:
            m = m[None]
        out = _sdpa(q, k, v, m)
    y = out.reshape(B, P, -1) @ params["wo"].astype(x.dtype)
    return y, new_cache


# -- cross attention ---------------------------------------------------------


def cross_attention_init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    return attention_init(rng, cfg, dtype)


def cross_kv(params: Params, cfg: ModelConfig, memory: jax.Array):
    """Project the (encoder / vision) memory once; reused across decode steps."""
    B, S, _ = memory.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    dt = memory.dtype
    k = (memory @ params["wk"].astype(dt)).reshape(B, S, kv, hd)
    v = (memory @ params["wv"].astype(dt)).reshape(B, S, kv, hd)
    return k, v


def cross_attention_apply(params: Params, cfg: ModelConfig, x: jax.Array,
                          k: jax.Array, v: jax.Array) -> jax.Array:
    """x: (B,T,D) queries; k, v: projected memory (B,S,kv,hd)."""
    B, T, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, T, h, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q, cfg.norm.eps)
    out = _sdpa(q, k.astype(dt), v.astype(dt), None)
    return out.reshape(B, T, h * hd) @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(rng, d: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(ks[0], (d, d_ff), dtype=dtype),
        "w_up": dense_init(ks[1], (d, d_ff), dtype=dtype),
        "w_down": dense_init(ks[2], (d_ff, d), dtype=dtype),
    }


def mlp_apply(params: Params, x: jax.Array,
              use_kernels: bool = False) -> jax.Array:
    dt = x.dtype
    hid = ("dp",) + (None,) * (x.ndim - 2) + ("model",)
    if use_kernels:
        from repro.kernels import ops as kops
        # fused gate GEMM + up GEMM + silu product, single saved hidden
        # activation (docs/kernels.md: swiglu)
        h = hint(kops.swiglu(x, params["w_gate"].astype(dt),
                             params["w_up"].astype(dt)), *hid)
        return h @ params["w_down"].astype(dt)
    g = hint(jax.nn.silu(x @ params["w_gate"].astype(dt)), *hid)
    u = hint(x @ params["w_up"].astype(dt), *hid)
    return (g * u) @ params["w_down"].astype(dt)
