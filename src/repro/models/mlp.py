"""F1 (Keskar et al. 2017): fully-connected MNIST model with (ghost) batch
normalization after every hidden layer."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_models import VisionModelConfig
from repro.models.layers import dense_init
from repro.models.vision_common import norm_apply, norm_init

Params = Dict[str, Any]


def init(rng, cfg: VisionModelConfig) -> Tuple[Params, Params]:
    h, w, c = cfg.input_shape
    sizes = (h * w * c,) + tuple(cfg.hidden_sizes)
    params: Params = {"layers": [], "out": None}
    state: Params = {"layers": []}
    for i, (din, dout) in enumerate(zip(sizes[:-1], sizes[1:])):
        r = jax.random.fold_in(rng, i)
        np_, ns = norm_init(cfg, dout)
        params["layers"].append({
            "w": dense_init(r, (din, dout),
                            scale=math.sqrt(2.0 / din)),
            "b": jnp.zeros((dout,)),
            "norm": np_,
        })
        state["layers"].append(ns)
    params["out"] = {
        "w": dense_init(jax.random.fold_in(rng, 999),
                        (sizes[-1], cfg.n_classes),
                        scale=math.sqrt(1.0 / sizes[-1])),
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params, state


def apply(params: Params, state: Params, cfg: VisionModelConfig,
          x: jax.Array, *, training: bool = True,
          ghost_batch_size: Optional[int] = None,
          use_gbn: Optional[bool] = None,
          use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    """x: (B, H, W, C) -> (logits (B, n_classes), new_state)."""
    B = x.shape[0]
    h = x.reshape(B, -1)
    new_state = {"layers": []}
    for lp, ls in zip(params["layers"], state["layers"]):
        h = h @ lp["w"] + lp["b"]
        h, ns = norm_apply(cfg, lp["norm"], ls, h, training=training,
                           ghost_batch_size=ghost_batch_size,
                           use_gbn=use_gbn, use_kernels=use_kernels)
        new_state["layers"].append(ns)
        h = jax.nn.relu(h)
    logits = h @ params["out"]["w"] + params["out"]["b"]
    return logits, new_state
