"""Mixture-of-Experts feed-forward with top-k routing and capacity-based
dispatch.

Design notes (see DESIGN.md §5):

- Routing and capacity are computed **per sequence**, so under data-parallel
  sharding all routing bookkeeping is shard-local; only the expert GEMMs
  touch the model-sharded expert weights. Compiled FLOPs equal the *active*
  FLOPs (B * E * C * d * d_e with C = S * top_k / E * capacity_factor) —
  dense all-expert dispatch would inflate them by E / top_k.
- Dispatch is a scatter into a (B, E, C, d) buffer (not a one-hot matmul,
  whose (T, E, C) dispatch tensor would be enormous at E=384).
- Decode (S == 1) folds the batch into the token axis so capacity pools over
  the batch. (Consequence, tested & documented: capacity *drops* can differ
  between prefill and decode; with capacity_factor high enough to be
  dropless the two match exactly.)
- The load-balance auxiliary loss is the Switch/GShard form
  ``E * sum_e f_e * P_e``; a router z-loss is optional.
- Expert-parallel placement comes from the param specs (sharding/rules.py):
  experts over the "model" axis (``shard_axis="expert"``), or each expert's
  hidden dim (``shard_axis="ffn"`` when E % mesh_model != 0, e.g. qwen2's 60
  experts); activation hints keep the dispatch buffer expert-sharded.
  The beyond-paper optimized path (shard_map + all_to_all) lives in
  ``repro/core/expert_parallel.py``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import expert_parallel as EP
from repro.models.layers import dense_init, mlp_apply, mlp_init
from repro.sharding.hints import current_mesh, hint

Params = Dict[str, Any]


def moe_init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_expert), dtype=dtype),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_expert), dtype=dtype),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_expert, d), dtype=dtype),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, m.d_shared, dtype=dtype)
    return p


def _route(router_w: jax.Array, x: jax.Array, m: MoEConfig,
           dp_axes: Tuple[str, ...] = ()):
    """x: (B, S, d) -> (topi, topw (B,S,k), aux losses).

    ``dp_axes`` (set inside a manual shard_map region, see
    :func:`repro.core.expert_parallel.manual_mode`) makes the load-balance
    statistics GLOBAL: f/P are pmean'd over the data axes through an
    identity-backward fence, because the Switch loss is a product of means —
    per-shard products would neither equal nor differentiate like the
    single-device loss."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)                      # (B,S,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    sel = jax.nn.one_hot(topi[..., 0], m.n_experts, dtype=jnp.float32)
    f = sel.mean(axis=(0, 1))
    P = probs.mean(axis=(0, 1))
    if dp_axes:
        f = EP.mean_in_fwd(f, dp_axes)
        P = EP.mean_in_fwd(P, dp_axes)
    aux = m.n_experts * jnp.sum(f * P)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return topi, topw, {"moe_aux": aux, "moe_z": z}


def _expert_ff(p: Params, m: MoEConfig, buf: jax.Array) -> jax.Array:
    """buf: (B, E, C, d) -> (B, E, C, d) through the per-expert SwiGLU."""
    dt = buf.dtype
    e_ax = "model" if m.shard_axis == "expert" else None
    f_ax = None if m.shard_axis == "expert" else "model"
    buf = hint(buf, "dp", e_ax, None, None)
    g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dt)))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dt))
    h = hint(g * u, "dp", e_ax, None, f_ax)
    y = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dt))
    return hint(y, "dp", e_ax, None, None)


def moe_apply(params: Params, cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (y (B, S, d), aux losses)."""
    m = cfg.moe
    B0, S0, d = x.shape
    dt = x.dtype

    decode = S0 == 1
    if decode:
        # decode: pool capacity over the batch (one "sequence" of B tokens)
        x = x.reshape(1, B0, d)
    B, S, _ = x.shape
    E, k = m.n_experts, m.top_k
    C = m.tokens_capacity(S)

    manual = EP.manual_state()                 # inside a shard_map region?
    topi, topw, aux = _route(params["router"], x, m,
                             dp_axes=manual[2] if manual else ())  # (B,S,k)

    # position of assignment (t, j) within its expert, ordered by (t, j).
    # Sort-based (O(S*k log) time, O(S*k) memory) — the naive one-hot cumsum
    # would materialise an (S*k, E) tensor (e.g. 32768 x 384 per sequence for
    # kimi-k2) and dominate HBM; see EXPERIMENTS.md §Perf.
    Tk = S * k
    e_flat = topi.reshape(B, Tk)
    order = jnp.argsort(e_flat, axis=1, stable=True)        # (B, Tk)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=1)
    idx = jnp.arange(Tk, dtype=jnp.int32)[None]
    change = jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    seg_start = jax.lax.cummax(jnp.where(change, idx, 0), axis=1)
    pos_sorted = idx - seg_start                            # rank within expert
    # invert the permutation: slot[b, order[b, i]] = pos_sorted[b, i]
    slot_flat = jnp.zeros((B, Tk), jnp.int32).at[
        jnp.arange(B)[:, None], order].set(pos_sorted)
    slot = slot_flat.reshape(B, S, k)
    keep = slot < C

    mode = EP.manual_shard_mode(m, params) if manual else None
    mesh = current_mesh()
    if mode is not None:
        # already inside a shard_map region (the unified 2-D train step,
        # train/parallel.py): weights arrive pre-sliced, the combine psum
        # over the enclosing mesh's model axis is the only collective.
        y = EP.ep_manual_combine(params, m, x, topi, topw, slot, keep, C,
                                 axis=manual[0], mode=mode)
    elif manual is None and EP.ep_applicable(m, mesh, B, 1 if decode else 0):
        # production path: shard_map expert parallelism (see
        # core/expert_parallel.py) — one psum per layer, no global
        # scatter/gather across the expert-sharded dim.
        y = EP.ep_dispatch_combine(params, m, x, topi, topw, slot, keep, C,
                                   mesh, batch_axis=1 if decode else 0)
    else:
        # local/global fallback (CPU tests; 'ffn'-sharded experts e.g.
        # qwen2's 60): buffer is data-sharded only, scatter/gather local.
        # One k-assignment at a time keeps the transient at (B, S, d).
        s_idx = jnp.where(keep, slot, 0)
        b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S)).reshape(-1)
        buf = jnp.zeros((B, E, C, d), dtype=dt)
        for j in range(k):
            xj = x * keep[:, :, j, None].astype(dt)
            buf = buf.at[b_idx, topi[:, :, j].reshape(-1),
                         s_idx[:, :, j].reshape(-1)].add(
                xj.reshape(-1, d), mode="drop")

        y_buf = _expert_ff(params, m, buf)                  # (B, E, C, d)

        y = jnp.zeros((B, S, d), dtype=dt)
        for j in range(k):
            yj = y_buf[b_idx, topi[:, :, j].reshape(-1),
                       s_idx[:, :, j].reshape(-1)].reshape(B, S, d)
            y = y + yj * (topw[:, :, j].astype(dt)
                          * keep[:, :, j].astype(dt))[..., None]

    if decode:
        y = y.reshape(B0, S0, d)
        x = x.reshape(B0, S0, d)
    if m.n_shared_experts:
        y = y + mlp_apply(params["shared"], x)
    return y.astype(dt), aux
