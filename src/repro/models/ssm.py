"""Mamba-1 selective state-space mixer.

Training / prefill uses a chunked scan: ``lax.scan`` over sequence chunks
carrying the (B, d_inner, d_state) hidden state, with a parallel
(associative) scan inside each chunk. This bounds the materialised
(B, chunk, d_inner, d_state) tensor while keeping the sequential depth at
S / chunk — the TPU-native adaptation of the CUDA selective-scan kernel
(see also kernels/mamba_scan.py for the Pallas version of the inner chunk).

``use_kernels=True`` swaps the inner chunk for the Pallas kernel pair
(:func:`repro.kernels.ops.mamba_chunk`): the forward keeps the state tile
resident in VMEM and the backward is the dedicated reverse-time kernel via
``jax.custom_vjp`` — training through this path never replays the jnp
oracle's forward scan.

Decode is the O(1)-per-token recurrence with a ring conv state.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ref as SSM_REF
from repro.models.layers import dense_init
from repro.sharding.hints import hint

Params = Dict[str, Any]

DEFAULT_CHUNK = 256

# §Perf P2 ablation: sequential-in-time inner scan instead of the
# associative scan — h is carried step to step (2 h-sized r/w per step)
# instead of log2(c) full-chunk combiner passes. This is the pure-JAX
# approximation of what the Pallas kernel does with h resident in VMEM.
_SEQ_SCAN = os.environ.get("REPRO_MAMBA_SEQ_SCAN", "0") == "1"


def ssm_init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    dtr = s.resolved_dt_rank(d)
    ks = jax.random.split(rng, 6)
    # S4/Mamba init: A = -(1..d_state) broadcast over channels
    a = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
                 (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, di), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype=dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * s.d_state), dtype=dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), dtype=dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype=jnp.float32),  # softplus ~ 0.01
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), dtype=jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype=dtype),
    }


def _split_in(params: Params, cfg: ModelConfig, x: jax.Array):
    dt = x.dtype
    di = cfg.ssm.d_inner(cfg.d_model)
    xz = x @ params["in_proj"].astype(dt)
    return xz[..., :di], xz[..., di:]


def _bcdt(params: Params, cfg: ModelConfig, xc: jax.Array):
    """xc: (..., di) post-conv activations -> (dt, B, C) selective params."""
    s = cfg.ssm
    dtr = s.resolved_dt_rank(cfg.d_model)
    proj = xc @ params["x_proj"].astype(xc.dtype)
    dt_in, B, C = (proj[..., :dtr], proj[..., dtr:dtr + s.d_state],
                   proj[..., dtr + s.d_state:])
    dt = jax.nn.softplus(
        (dt_in @ params["dt_proj"].astype(xc.dtype)).astype(jnp.float32)
        + params["dt_bias"])
    return dt, B.astype(jnp.float32), C.astype(jnp.float32)


def _causal_conv_full(params: Params, cfg: ModelConfig, x: jax.Array,
                      conv_state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv over (B, S, di)."""
    k = cfg.ssm.d_conv
    w = params["conv_w"].astype(x.dtype)            # (k, di)
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + params["conv_b"].astype(x.dtype)


def _chunk_scan(a: jax.Array, b: jax.Array, h0: jax.Array):
    """Within-chunk parallel scan of h_t = a_t * h_{t-1} + b_t.

    a, b: (B, c, di, ds); h0: (B, di, ds). Returns (h_all (B,c,di,ds), h_last).
    """
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_all = a_cum * h0[:, None] + b_cum
    return h_all, h_all[:, -1]


def ssm_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                chunk: int = DEFAULT_CHUNK,
                use_kernels: bool = False,
                valid: Optional[jax.Array] = None,
                return_state: bool = False):
    """Full-sequence mamba mixer. x: (B, S, d_model) -> (B, S, d_model).

    ``valid`` (B, S) bool masks left-padded ragged prompts: invalid
    positions contribute zero conv taps (exactly the causal zero-padding an
    unpadded run sees before its first token) and identity state updates
    (``dt = 0`` => a = 1, b = 0), so the carried state matches the unpadded
    per-sequence run; outputs at invalid positions are garbage and must be
    discarded by the caller.

    ``return_state=True`` additionally returns the decode cache
    ``{"h", "conv"}`` at the last position — the fused-prefill handoff to
    :func:`ssm_decode`.
    """
    B, S, _ = x.shape
    dt_ = x.dtype
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    xin, z = _split_in(params, cfg, x)
    if valid is not None:
        xin = jnp.where(valid[..., None], xin, 0)
    xin = hint(xin, "dp", None, "model")
    xc = hint(jax.nn.silu(_causal_conv_full(params, cfg, xin)),
              "dp", None, "model")
    dt, Bmat, Cmat = _bcdt(params, cfg, xc)          # (B,S,di) (B,S,ds) (B,S,ds)
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)
    dt = hint(dt, "dp", None, "model")
    A = -jnp.exp(params["A_log"])                    # (di, ds)

    c = min(chunk, S)
    if S % c:
        # pad to a chunk multiple (padded steps have dt=0 -> identity updates)
        pad = c - S % c
        xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_p = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        C_p = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    else:
        pad = 0
        xc_p, dt_p, B_p, C_p = xc, dt, Bmat, Cmat
    Sp = S + pad
    nch = Sp // c

    def step(h, inputs):
        xc_c, dt_c, B_c, C_c = inputs                # (B,c,di) (B,c,di) (B,c,ds)
        if use_kernels:
            from repro.kernels import ops as kops
            y_c, h = kops.mamba_chunk(xc_c.astype(jnp.float32), dt_c, B_c,
                                      C_c, A, h)
        elif _SEQ_SCAN:
            y_c, h = SSM_REF.mamba_chunk_ref(
                xc_c.astype(jnp.float32), dt_c, B_c, C_c, A, h)
        else:
            a = hint(jnp.exp(dt_c[..., None] * A),
                     "dp", None, "model", None)                   # (B,c,di,ds)
            b = hint((dt_c * xc_c.astype(jnp.float32))[..., None]
                     * B_c[:, :, None, :], "dp", None, "model", None)
            h_all, h = _chunk_scan(a, b, h)
            h = hint(h, "dp", "model", None)
            y_c = jnp.einsum("bcds,bcs->bcd", h_all, C_c)
        return h, y_c

    xs = (xc_p.reshape(B, nch, c, di).swapaxes(0, 1),
          dt_p.reshape(B, nch, c, di).swapaxes(0, 1),
          B_p.reshape(B, nch, c, s.d_state).swapaxes(0, 1),
          C_p.reshape(B, nch, c, s.d_state).swapaxes(0, 1))
    h0 = jnp.zeros((B, di, s.d_state), dtype=jnp.float32)
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, Sp, di)[:, :S]
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(dt_) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    if not return_state:
        return out
    # decode handoff: conv state = the last d_conv-1 (masked) inputs, padded
    # with the same causal zeros a fresh sequence starts from
    k = s.d_conv - 1
    if S >= k:
        conv = xin[:, S - k:]
    else:
        conv = jnp.pad(xin, ((0, 0), (k - S, 0), (0, 0)))
    return out, {"h": h_last, "conv": conv.astype(dt_)}


def ssm_prefill(params: Params, cfg: ModelConfig, x: jax.Array, *,
                valid: Optional[jax.Array] = None,
                use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    """Fused prefill: full-sequence mixer that also returns the decode
    cache ``{"h", "conv"}`` ready for :func:`ssm_decode`."""
    return ssm_forward(params, cfg, x, use_kernels=use_kernels,
                       valid=valid, return_state=True)


# -- decode ------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    return {
        "h": jnp.zeros((batch, di, s.d_state), dtype=jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, di), dtype=dtype),
    }


def ssm_decode(params: Params, cfg: ModelConfig, x: jax.Array,
               cache: Params) -> Tuple[jax.Array, Params]:
    """One-token recurrent step. x: (B, 1, d_model)."""
    B = x.shape[0]
    dt_ = x.dtype
    s = cfg.ssm
    xin, z = _split_in(params, cfg, x)               # (B,1,di)
    xc = jax.nn.silu(
        _causal_conv_full(params, cfg, xin, conv_state=cache["conv"]))
    new_conv = jnp.concatenate(
        [cache["conv"][:, 1:], xin.astype(cache["conv"].dtype)], axis=1)
    dt, Bmat, Cmat = _bcdt(params, cfg, xc)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)               # (B,di,ds)
    b = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bmat[:, 0, None, :]
    h = a * cache["h"] + b
    y = jnp.einsum("bds,bs->bd", h, Cmat[:, 0])
    y = y + params["D"] * xc[:, 0].astype(jnp.float32)
    y = (y[:, None].astype(dt_)) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    return out, {"h": h, "conv": new_conv}
