"""Top-level model: embeddings + block stack (+ optional encoder / vision
memory) + LM head. Covers all six assigned families:

- dense / moe / ssm / hybrid decoders: ``forward`` (train / prefill) and
  ``decode_step`` (one token against caches).
- encdec (audio): ``encode`` runs the transformer encoder over the stubbed
  frame embeddings; the decoder cross-attends the encoded memory.
- vlm: the decoder cross-attends the stubbed projected patch embeddings.

``use_kernels=True`` on the forward/loss entry points routes the mixers
through the differentiable Pallas kernels (flash attention with its
dedicated backward pair, the Mamba chunk scan likewise) — the LM train
step's hot path under the paper's "train longer" regime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import blocks as B
from repro.models import layers as L
from repro.sharding import hints

Params = Dict[str, Any]


def _compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def encoder_config(cfg: ModelConfig) -> ModelConfig:
    """Internal ModelConfig for the (non-causal) encoder stack."""
    e = cfg.encoder
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-encoder",
        d_model=e.d_model,
        n_heads=e.n_heads,
        n_kv_heads=e.n_kv_heads,
        head_dim=e.d_model // e.n_heads,
        d_ff=e.d_ff,
        head_pattern=(),
        body_pattern=(LayerSpec(mixer="attn", ff="dense"),),
        body_repeats=e.n_layers,
        tail_pattern=(),
        causal=False,
        moe=None, ssm=None, encoder=None, vision=None,
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig) -> Params:
    dtype = _compute_dtype(cfg)
    r_embed, r_stack, r_head, r_enc = jax.random.split(rng, 4)
    Vp, d = cfg.padded_vocab, cfg.d_model
    p: Params = {
        "embed": L.dense_init(r_embed, (Vp, d), scale=0.02, dtype=dtype),
        "stack": B.stack_init(r_stack, cfg, dtype),
        "final_norm": L.norm_init(cfg, d, jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(r_head, (Vp, d), scale=0.02, dtype=dtype)
    if cfg.encoder is not None:
        ecfg = encoder_config(cfg)
        p["encoder"] = {
            "stack": B.stack_init(r_enc, ecfg, dtype),
            "final_norm": L.norm_init(ecfg, ecfg.d_model, jnp.float32),
        }
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           use_kernels: bool = False, remat: bool = False,
           seq_parallel: bool = False) -> jax.Array:
    """Encoder over stub frame embeddings (B, F, d_model)."""
    ecfg = encoder_config(cfg)
    Bsz, F, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(F)[None], (Bsz, F))
    x, _, _ = B.stack_apply(params["encoder"]["stack"], ecfg, frames,
                            positions=positions, causal=False,
                            use_kernels=use_kernels, remat=remat,
                            seq_parallel=seq_parallel)
    return L.norm_apply(ecfg, params["encoder"]["final_norm"], x)


def get_memory(params: Params, cfg: ModelConfig,
               batch: Dict[str, jax.Array],
               use_kernels: bool = False, remat: bool = False,
               seq_parallel: bool = False) -> Optional[jax.Array]:
    """Resolve the cross-attention memory for this family, if any."""
    if cfg.encoder is not None:
        return encode(params, cfg, batch["frames"], use_kernels,
                      remat=remat, seq_parallel=seq_parallel)
    if cfg.vision is not None:
        return batch["image_embeds"]
    return None


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            memory: Optional[jax.Array] = None,
            use_kernels: bool = False,
            remat: bool = False,
            seq_parallel: bool = False) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """tokens: (B, S) int32 -> (logits (B, S, V), aux losses)."""
    dtype = _compute_dtype(cfg)
    Bsz, S = tokens.shape
    x = params["embed"][tokens].astype(dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (Bsz, S))
    x, _, aux = B.stack_apply(params["stack"], cfg, x, positions=positions,
                              memory=memory, causal=cfg.causal,
                              use_kernels=use_kernels, remat=remat,
                              seq_parallel=seq_parallel)
    x = L.norm_apply(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = hints.hint(x @ head.astype(dtype).T, "dp", None, "model")
    return logits, aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               memory_len: int = 0, dtype=jnp.bfloat16,
               layout: str = "seq", page_size: int = 64,
               total_pages: Optional[int] = None,
               cache_dtype: Optional[str] = None) -> Params:
    """``layout="head"`` builds the flash-decode kernel's native head-major
    KV caches (serving ``use_kernels=True``); "seq" is the classic
    (B, S, kv, hd) layout the grouped-einsum decode and sharding rules
    expect; "paged" gives full-attention layers a physical page pool +
    per-row block tables (``page_size`` slots per page, ``total_pages``
    including the reserved trash page 0) for the continuous-batching
    engine — SWA ring and SSM/cross caches are unchanged by it.
    ``cache_dtype="int8"`` stores the paged pool as per-slot symmetric
    int8 codes plus f32 scale planes (``ks``/``vs``), halving the kp/vp
    payload so the same pool memory holds twice the slots."""
    return B.stack_cache(cfg, batch, max_len, memory_len, dtype, layout,
                         page_size=page_size, total_pages=total_pages,
                         cache_dtype=cache_dtype)


def memory_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.encoder is not None:
        return seq_len // cfg.encoder.frame_ratio
    if cfg.vision is not None:
        return cfg.vision.n_image_tokens
    return 0


def build_cross_cache(params: Params, cfg: ModelConfig, memory: jax.Array,
                      cache: Params) -> Params:
    """Fill the per-layer projected cross K/V into a fresh cache."""
    def fill(section, blk_params, spec, stacked: bool):
        if not spec.cross_attn:
            return section
        cross = blk_params["cross"]
        if stacked:
            k, v = jax.vmap(lambda cp: L.cross_kv(cp, cfg, memory))(cross)
        else:
            k, v = L.cross_kv(cross, cfg, memory)
        section = dict(section)
        section["cross_k"] = k.astype(section["cross_k"].dtype)
        section["cross_v"] = v.astype(section["cross_v"].dtype)
        return section

    new = {"head": [], "body": [], "tail": []}
    for i, spec in enumerate(cfg.head_pattern):
        new["head"].append(
            fill(cache["head"][i], params["stack"]["head"][i], spec, False))
    for j, spec in enumerate(cfg.body_pattern):
        new["body"].append(
            fill(cache["body"][j], params["stack"]["body"][j], spec, True))
    for i, spec in enumerate(cfg.tail_pattern):
        new["tail"].append(
            fill(cache["tail"][i], params["stack"]["tail"][i], spec, False))
    return new


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Params, pos: jax.Array, *,
                use_kernels: bool = False,
                offsets: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params]:
    """tokens: (B, 1) int32; pos: scalar int32 (lockstep batch) or per-row
    (B,) int32 (continuous batching) -> (logits (B,1,V), new cache).

    ``use_kernels=True`` routes cache attention through the Pallas
    flash-decode kernel. ``offsets`` (B,) are per-sequence left-pad widths
    for ragged (left-padded) prompts: RoPE positions shift to
    ``pos - offsets`` and padded cache slots are masked out of every
    attention."""
    dtype = _compute_dtype(cfg)
    x = params["embed"][tokens].astype(dtype)
    x, new_cache, _ = B.stack_apply(params["stack"], cfg, x, cache=cache,
                                    pos=pos, decode=True,
                                    use_kernels=use_kernels, offsets=offsets)
    x = L.norm_apply(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = x @ head.astype(dtype).T
    return logits, new_cache


def prefill_forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
                    cache: Params, *,
                    use_kernels: bool = False,
                    offsets: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Params]:
    """Fused prefill: ONE full-sequence forward that scatters every layer's
    K/V (and SSM state) into the decode cache and returns only the
    last-position logits.

    tokens: (B, P) int32 -> (logits (B, 1, V), filled cache). Cross-attention
    caches must already be filled (``build_cross_cache``). With ``offsets``
    (left-padded ragged prompts) the per-row RoPE positions start at each
    sequence's first real token and padded positions are masked out of the
    attention and SSM state — so the filled cache matches what each
    sequence would produce unpadded. The last column is each sequence's
    final prompt token (left padding), so one logits row serves every row.
    """
    dtype = _compute_dtype(cfg)
    Bsz, P = tokens.shape
    x = params["embed"][tokens].astype(dtype)
    base = jnp.broadcast_to(jnp.arange(P)[None], (Bsz, P))
    positions = base if offsets is None else base - offsets[:, None]
    x, new_cache, _ = B.stack_apply(params["stack"], cfg, x, cache=cache,
                                    positions=positions, decode=False,
                                    causal=cfg.causal,
                                    use_kernels=use_kernels, offsets=offsets)
    x = L.norm_apply(cfg, params["final_norm"], x[:, -1:])
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = x @ head.astype(dtype).T
    return logits, new_cache


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def hidden_states(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
                  memory: Optional[jax.Array] = None,
                  use_kernels: bool = False, remat: bool = False,
                  seq_parallel: bool = False):
    """Run the stack up to (but excluding) the LM head."""
    dtype = _compute_dtype(cfg)
    Bsz, S = tokens.shape
    x = params["embed"][tokens].astype(dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (Bsz, S))
    x, _, aux = B.stack_apply(params["stack"], cfg, x, positions=positions,
                              memory=memory, causal=cfg.causal,
                              use_kernels=use_kernels, remat=remat,
                              seq_parallel=seq_parallel)
    return L.norm_apply(cfg, params["final_norm"], x), aux


def _dense_ce(cfg: ModelConfig, logits: jax.Array,
              targets: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad[None, None, :], -1e30, logits)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def _chunked_ce(cfg: ModelConfig, x: jax.Array, head: jax.Array,
                targets: jax.Array, chunk: int) -> jax.Array:
    """Vocab-chunked streaming softmax CE (beyond-paper memory optimization,
    EXPERIMENTS.md #Perf): the (B, S, V) f32 logits tensor is never
    materialised — logits are computed one V-chunk at a time inside a scan
    (XLA rematerialises chunks in the backward pass)."""
    Vp = cfg.padded_vocab
    assert Vp % chunk == 0, (Vp, chunk)
    n = Vp // chunk
    dt = x.dtype
    B_, S_ = targets.shape
    head_c = head.reshape(n, chunk, x.shape[-1])

    def body(carry, inp):
        m_run, s_run, gold = carry
        hc, ci = inp
        lg = (x @ hc.astype(dt).T).astype(jnp.float32)     # (B, S, chunk)
        base = ci * chunk
        vid = base + jnp.arange(chunk)
        if cfg.padded_vocab != cfg.vocab_size:
            lg = jnp.where((vid >= cfg.vocab_size)[None, None, :], -1e30, lg)
        m_new = jnp.maximum(m_run, lg.max(-1))
        s_run = s_run * jnp.exp(m_run - m_new) \
            + jnp.exp(lg - m_new[..., None]).sum(-1)
        in_chunk = (targets >= base) & (targets < base + chunk)
        idx = jnp.clip(targets - base, 0, chunk - 1)
        g = jnp.take_along_axis(lg, idx[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_chunk, g, gold)
        return (m_new, s_run, gold), None

    init = (jnp.full((B_, S_), -1e30, jnp.float32),
            jnp.zeros((B_, S_), jnp.float32),
            jnp.zeros((B_, S_), jnp.float32))
    (m_run, s_run, gold), _ = jax.lax.scan(
        body, init, (head_c, jnp.arange(n)))
    logz = m_run + jnp.log(jnp.maximum(s_run, 1e-30))
    return (logz - gold).mean()


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            use_kernels: bool = False,
            remat: bool = False,
            seq_parallel: bool = False,
            ce_chunk: int = 0
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy + MoE auxiliary losses.

    ``ce_chunk > 0`` switches to the vocab-chunked streaming CE (#Perf)."""
    tokens = batch["tokens"]
    memory = get_memory(params, cfg, batch, use_kernels,
                        remat=remat, seq_parallel=seq_parallel)
    targets = tokens[:, 1:]
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    if ce_chunk and cfg.padded_vocab % ce_chunk == 0:
        x, aux = hidden_states(params, cfg, tokens, memory=memory,
                               use_kernels=use_kernels, remat=remat,
                               seq_parallel=seq_parallel)
        ce = _chunked_ce(cfg, x[:, :-1], head, targets, ce_chunk)
    else:
        logits, aux = forward(params, cfg, tokens, memory=memory,
                              use_kernels=use_kernels, remat=remat,
                              seq_parallel=seq_parallel)
        ce = _dense_ce(cfg, logits[:, :-1], targets)
    m = cfg.moe
    total = ce
    if m is not None:
        total = (total + m.router_aux_weight * aux["moe_aux"]
                 + m.router_z_weight * aux["moe_z"])
    metrics = {"ce": ce, **aux}
    return total, metrics
