"""Shared pieces for the paper's vision/MLP models: norm dispatch (GBN vs
conventional full-batch BN vs none) with explicit running-state threading."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_models import VisionModelConfig
from repro.core import gbn as GBN

Params = Dict[str, Any]


def norm_init(cfg: VisionModelConfig, n_features: int
              ) -> Tuple[Params, Params]:
    if cfg.norm == "none":
        return {}, {}
    return GBN.gbn_init(n_features)


def norm_apply(cfg: VisionModelConfig, params: Params, state: Params,
               x: jax.Array, *, training: bool,
               ghost_batch_size: Optional[int] = None,
               use_gbn: Optional[bool] = None,
               use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    """x: (B, ..., C). Dispatches GBN / equal-weight BN / identity.

    ``use_gbn=False`` degrades GBN to conventional full-batch BN (the LB
    baseline); ``ghost_batch_size`` overrides the config (LargeBatchConfig
    controls it at train time).
    """
    if cfg.norm == "none":
        return x, state
    gbs = ghost_batch_size or cfg.ghost_batch_size
    gbn_on = cfg.norm == "gbn" if use_gbn is None else use_gbn
    if gbn_on:
        return GBN.gbn_apply(params, state, x, ghost_batch_size=gbs,
                             momentum=cfg.bn_momentum, training=training,
                             use_kernels=use_kernels)
    return GBN.equal_weight_bn_apply(params, state, x,
                                     momentum=cfg.bn_momentum,
                                     training=training)
