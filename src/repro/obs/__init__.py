"""Unified observability: host spans + metrics, shared by training and
serving.

One :class:`Observability` object bundles the two sinks every subsystem
writes into:

- ``obs.tracer`` — nested wall-clock spans exported as Chrome/Perfetto
  trace JSON (:mod:`repro.obs.trace`), optionally mirrored into
  ``jax.profiler.TraceAnnotation`` so a device trace lines up under them;
- ``obs.registry`` — counters/gauges/streaming histograms with JSONL
  export and a plain-text summary table (:mod:`repro.obs.metrics`).

Call sites take ``obs=None`` and bind ``NULL_TRACER`` when absent, so an
un-observed run pays nothing (the disabled span path allocates no objects
and reads no clocks). See docs/observability.md for the span/metric naming
contract.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsLogger,
                               Registry)
from repro.obs.trace import NULL_TRACER, Tracer, device_trace

__all__ = ["Counter", "Gauge", "Histogram", "MetricsLogger", "Registry",
           "Tracer", "NULL_TRACER", "device_trace", "Observability"]


class Observability:
    """Tracer + registry bundle with one-call export.

    ``annotate_device=True`` additionally wraps every span in a
    ``jax.profiler.TraceAnnotation`` (pair with
    :class:`repro.obs.trace.device_trace` to capture the XLA side).
    """

    def __init__(self, *, trace: bool = True,
                 annotate_device: bool = False):
        self.tracer = Tracer(enabled=trace,
                             annotate_device=annotate_device)
        self.registry = Registry()

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    def clear(self) -> None:
        """Drop recorded spans and metrics (e.g. between a warmup run and
        the measured one) without rebinding call sites."""
        self.tracer.clear()
        self.registry.clear()

    def write(self, trace_path: str = "", metrics_path: str = "") -> None:
        if trace_path:
            self.tracer.write_chrome(trace_path)
        if metrics_path:
            self.registry.write_jsonl(metrics_path)

    def summary(self) -> str:
        return self.registry.summary_table()
