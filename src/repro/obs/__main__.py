"""``python -m repro.obs`` — the span-wrapper CLI (see
:func:`repro.obs.trace._main`). Running the package instead of the
``repro.obs.trace`` submodule avoids runpy's found-in-sys.modules warning
(the package __init__ imports the submodule).
"""
from repro.obs.trace import _main

if __name__ == "__main__":
    raise SystemExit(_main())
