"""Unified metrics: counters, gauges, streaming histograms, and the
per-run ``MetricsLogger`` series store — ONE implementation for training,
serving, and the experiments subsystem.

- :class:`Counter` / :class:`Gauge` — monotone totals and last-value
  signals (queue depth, slot occupancy, current LR/batch size).
- :class:`Histogram` — a log-bucketed streaming histogram: p50/p95/p99
  (and any quantile) to ~``growth``-relative accuracy WITHOUT storing the
  samples, so per-token serving latencies and per-step train times cost
  O(#buckets) memory however long the run.
- :class:`Registry` — the name -> metric table one process shares across
  subsystems, with JSONL event export (one record per metric, timestamped)
  and an aligned plain-text summary table.
- :class:`MetricsLogger` — the (step, name, value) series store the
  trainers log into (previously ``repro.core.metrics``; that module and
  ``repro.experiments.metrics`` now re-export this one). An attached
  :class:`Registry` mirrors every logged scalar into a histogram of the
  same (prefixed) name, which is how the experiments runner routes run
  series into the observability layer.

Naming contract (see docs/observability.md): ``<subsystem>/<signal>``
with unit suffixes — ``train/step_time_s``, ``serve/ttft_s``,
``serve/queue_depth``.
"""
from __future__ import annotations

import json
import math
import os
import time
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "MetricsLogger"]


class Counter:
    """Monotonically increasing total."""

    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def summary(self) -> Dict[str, Any]:
        return {"value": self.value}


class Gauge:
    """Last-value signal."""

    kind = "gauge"

    def __init__(self) -> None:
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def summary(self) -> Dict[str, Any]:
        return {"value": self.value}


class Histogram:
    """Streaming histogram over geometric buckets.

    A sample ``v`` lands in bucket ``floor(log(|v|) / log(growth))`` on the
    positive or negative side (zeros get their own bucket), so any quantile
    is reproducible to a relative error of ~``sqrt(growth) - 1`` (about 1%
    at the default ``growth=1.02``) from O(#occupied buckets) state. Exact
    count/sum/min/max/last ride along for the summary.
    """

    kind = "histogram"

    def __init__(self, growth: float = 1.02) -> None:
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        self.growth = growth
        self._log_g = math.log(growth)
        self._pos: Dict[int, int] = defaultdict(int)
        self._neg: Dict[int, int] = defaultdict(int)
        self._zero = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.last = float("nan")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.last = v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v > 0.0:
            self._pos[int(math.floor(math.log(v) / self._log_g))] += 1
        elif v < 0.0:
            self._neg[int(math.floor(math.log(-v) / self._log_g))] += 1
        else:
            self._zero += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def _items(self) -> Iterable[Tuple[float, int]]:
        """(representative value, count) in ascending value order."""
        g = self.growth
        for i in sorted(self._neg, reverse=True):       # most negative first
            yield -(g ** (i + 0.5)), self._neg[i]
        if self._zero:
            yield 0.0, self._zero
        for i in sorted(self._pos):
            yield g ** (i + 0.5), self._pos[i]

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (q in [0, 1]); NaN when empty."""
        if not self.count:
            return float("nan")
        if q <= 0.0:
            return self.vmin
        if q >= 1.0:
            return self.vmax
        target = q * self.count
        seen = 0
        for value, n in self._items():
            seen += n
            if seen >= target:
                # clamp the bucket representative into the exact range
                return min(max(value, self.vmin), self.vmax)
        return self.vmax                                  # pragma: no cover

    def summary(self) -> Dict[str, Any]:
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin if self.count else float("nan"),
                "max": self.vmax if self.count else float("nan"),
                "last": self.last,
                "p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class Registry:
    """Shared name -> metric table. A name keeps the kind it was first
    created with; asking for the same name as a different kind raises
    (silent kind-mixing is how two loggers drift apart — the exact disease
    this layer removes)."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(**kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {m.kind}, not "
                            f"{cls.__name__.lower()}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, growth: float = 1.02) -> Histogram:
        return self._get(name, Histogram, growth=growth)

    # shorthands for hot call sites
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def clear(self) -> None:
        self._metrics = {}

    def to_records(self, ts: Optional[float] = None) -> List[Dict[str, Any]]:
        """One JSON-ready record per metric: {ts, name, kind, **summary}."""
        ts = time.time() if ts is None else ts
        return [{"ts": ts, "name": name, "kind": m.kind, **m.summary()}
                for name, m in sorted(self._metrics.items())]

    def write_jsonl(self, path: str, append: bool = True) -> None:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "a" if append else "w") as f:
            for rec in self.to_records():
                f.write(json.dumps(rec, sort_keys=True) + "\n")

    def summary_table(self) -> str:
        """Aligned plain-text table: one row per metric."""
        lines = [f"{'metric':<32s} {'kind':>9s} {'count':>8s} {'value/mean':>12s} "
                 f"{'p50':>10s} {'p95':>10s} {'p99':>10s} {'max':>10s}"]
        for name, m in sorted(self._metrics.items()):
            s = m.summary()
            if m.kind == "histogram":
                lines.append(
                    f"{name:<32s} {m.kind:>9s} {s['count']:8d} "
                    f"{s['mean']:12.4g} {s['p50']:10.4g} {s['p95']:10.4g} "
                    f"{s['p99']:10.4g} {s['max']:10.4g}")
            else:
                lines.append(f"{name:<32s} {m.kind:>9s} {'':>8s} "
                             f"{s['value']:12.4g}")
        return "\n".join(lines)


class MetricsLogger:
    """Append-only (step, name, value) scalar series for one run.

    ``attach_registry`` mirrors every subsequently logged scalar into a
    same-named (optionally prefixed) :class:`Histogram` of the registry,
    so a run's series feed the shared observability sink without the
    trainers growing a second logging call.
    """

    def __init__(self) -> None:
        self._steps: Dict[str, List[int]] = defaultdict(list)
        self._values: Dict[str, List[float]] = defaultdict(list)
        self._registry: Optional[Registry] = None
        self._prefix = ""

    def attach_registry(self, registry: Registry, prefix: str = "") -> None:
        self._registry = registry
        self._prefix = prefix

    def log(self, step: int, **scalars: float) -> None:
        for name, value in scalars.items():
            self._steps[name].append(int(step))
            self._values[name].append(float(value))
            if self._registry is not None:
                self._registry.observe(self._prefix + name, value)

    def set_series(self, name: str, steps: Sequence[int],
                   values: Sequence[float]) -> None:
        """Replace one series wholesale (used for device-batched series like
        the diffusion distances, which are synced once at the end rather
        than logged float-by-float)."""
        self._steps[name] = [int(s) for s in steps]
        self._values[name] = [float(v) for v in values]
        if self._registry is not None:
            h = self._registry.histogram(self._prefix + name)
            for v in values:
                h.observe(v)

    def names(self) -> List[str]:
        return sorted(name for name in self._steps if self._steps[name])

    def series(self, name: str) -> Tuple[List[int], List[float]]:
        # .get, not [..]: reading a missing series must not create a
        # phantom empty one that would leak into to_json()/records
        return (list(self._steps.get(name, ())),
                list(self._values.get(name, ())))

    def last(self, name: str, default: float = float("nan")) -> float:
        vals = self._values.get(name)
        return vals[-1] if vals else default

    def max(self, name: str, default: float = 0.0) -> float:
        vals = self._values.get(name)
        return max(vals) if vals else default

    def to_json(self) -> Dict[str, Any]:
        return {name: [self._steps[name], self._values[name]]
                for name in self._steps if self._steps[name]}

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "MetricsLogger":
        lg = cls()
        for name, (steps, values) in obj.items():
            lg._steps[name] = [int(s) for s in steps]
            lg._values[name] = [float(v) for v in values]
        return lg

    def to_history(self) -> Dict[str, List[float]]:
        """The legacy ``train_vision`` history-dict view."""
        val_steps, val_acc = self.series("val_acc")
        _, train_loss = self.series("train_loss")
        dist_steps, distance = self.series("distance")
        return {"steps": val_steps, "val_acc": val_acc,
                "train_loss": train_loss,
                "dist_steps": dist_steps, "distance": distance}
