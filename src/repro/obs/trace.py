"""Low-overhead host-side span tracer with Chrome/Perfetto export.

A :class:`Tracer` records nested wall-clock spans around the hot host-side
loops (train step, prefill, decode step, admission, page allocation) and
exports them as Chrome trace-event JSON — a flat list of ``"ph": "X"``
complete events that ``chrome://tracing`` and https://ui.perfetto.dev load
directly (nesting is inferred from containment on one pid/tid track).

Design constraints, in order:

1. **Zero-cost disabled path.** ``Tracer(enabled=False).span(...)`` returns
   ONE module-level singleton no-op context manager — no object allocation,
   no clock read, no event append — so instrumentation can stay permanently
   compiled into the decode loop without taxing the benchmarked path. The
   module-level :data:`NULL_TRACER` is what un-instrumented call sites bind
   when no observability sink was passed in.
2. **Device alignment.** Host spans only see dispatch; with
   ``annotate_device=True`` each span also enters a
   ``jax.profiler.TraceAnnotation`` of the same name, so a device trace
   captured via :func:`device_trace` (``jax.profiler.start_trace``) lines
   its XLA activity up under the host span names in Perfetto.
3. **No timestamp surprises.** Spans are timed with ``perf_counter_ns``
   against a per-tracer origin, emitted in microseconds (the trace-event
   unit).

CLI: ``python -m repro.obs --label NAME [--out trace.json] -- cmd...``
runs ``cmd`` inside one span, prints ``[trace] NAME: <seconds>s``, and exits
with the command's status — scripts/test.sh uses it to report per-batch
wall time.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


class _NullSpan:
    """The shared no-op span: enter/exit do nothing, allocate nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """One live span; records a complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0", "_ann")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self._name = name
        self._args = args
        self._ann = None

    def __enter__(self):
        if self._tracer.annotate_device:
            from jax.profiler import TraceAnnotation
            self._ann = TraceAnnotation(self._name)
            self._ann.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        tr = self._tracer
        ev = {"name": self._name, "ph": "X", "pid": tr.pid,
              "tid": threading.get_ident(),
              "ts": (self._t0 - tr.origin_ns) / 1e3,
              "dur": (t1 - self._t0) / 1e3}
        if self._args:
            ev["args"] = self._args
        tr.events.append(ev)
        return False


class Tracer:
    """Host-side span recorder; ``enabled=False`` is the zero-cost path."""

    def __init__(self, enabled: bool = True,
                 annotate_device: bool = False):
        self.enabled = enabled
        self.annotate_device = annotate_device
        self.pid = os.getpid()
        self.origin_ns = time.perf_counter_ns()
        self.events: List[Dict[str, Any]] = []

    def span(self, name: str, **args):
        """Context manager timing one span; kwargs become event args."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args or None)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event (``"ph": "i"``)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "t", "pid": self.pid,
              "tid": threading.get_ident(),
              "ts": (time.perf_counter_ns() - self.origin_ns) / 1e3}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def clear(self) -> None:
        self.events = []

    def to_chrome(self) -> List[Dict[str, Any]]:
        """The Chrome trace-event list (already loadable as-is)."""
        return list(self.events)

    def write_chrome(self, path: str) -> None:
        """Write the trace as Chrome/Perfetto-loadable JSON."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


#: The disabled tracer un-instrumented call sites bind to. Spans on it are
#: the singleton no-op; never enable it in place — make your own Tracer.
NULL_TRACER = Tracer(enabled=False)


class device_trace:
    """Context manager around ``jax.profiler.start_trace/stop_trace``:
    captures an XLA device trace under ``logdir`` alongside the host spans.
    Fail-soft: a profiler that cannot start (already active, unsupported
    backend) degrades to a no-op with a warning instead of killing the run.
    """

    def __init__(self, logdir: str):
        self.logdir = logdir
        self._active = False

    def __enter__(self):
        import jax
        try:
            jax.profiler.start_trace(self.logdir)
            self._active = True
        except Exception as e:              # pragma: no cover - env specific
            import warnings
            warnings.warn(f"device trace unavailable: {e}")
        return self

    def __exit__(self, *exc):
        if self._active:
            import jax
            jax.profiler.stop_trace()
        return False


def _main() -> int:
    import argparse
    import subprocess
    import sys
    ap = argparse.ArgumentParser(
        description="run a command inside one tracer span and print its "
                    "wall time")
    ap.add_argument("--label", default="cmd")
    ap.add_argument("--out", default="",
                    help="write a Chrome trace JSON for the span")
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="-- command to run")
    args = ap.parse_args()
    cmd = args.cmd[1:] if args.cmd[:1] == ["--"] else args.cmd
    if not cmd:
        ap.error("no command given (use: ... --label NAME -- cmd args)")
    tracer = Tracer(enabled=True)
    with tracer.span(args.label, cmd=" ".join(cmd)):
        rc = subprocess.call(cmd)
    dur_s = tracer.events[-1]["dur"] / 1e6
    print(f"[trace] {args.label}: {dur_s:.1f}s (exit {rc})", flush=True)
    if args.out:
        tracer.write_chrome(args.out)
    return rc


if __name__ == "__main__":                   # pragma: no cover - CLI
    raise SystemExit(_main())
