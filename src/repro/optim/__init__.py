from repro.optim import adam, sgd
from repro.optim.adam import AdamState
from repro.optim.sgd import SGDState

__all__ = ["adam", "sgd", "AdamState", "SGDState"]
