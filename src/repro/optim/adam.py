"""Adam — the adaptive baseline the paper contrasts with ("many current
studies still use simple variants of SGD ... due to the tendency of these
methods to converge to a lower test error")."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.clipping import clip_by_global_norm

Params = Any


class AdamState(NamedTuple):
    mu: Params
    nu: Params
    step: jax.Array


def init(params: Params) -> AdamState:
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(mu=z, nu=jax.tree.map(jnp.zeros_like, z),
                     step=jnp.zeros((), jnp.int32))


def update(grads: Params, state: AdamState, params: Params, *,
           lr: jax.Array, b1: float = 0.9, b2: float = 0.999,
           eps: float = 1e-8, weight_decay: float = 0.0,
           grad_clip: float = 0.0,
           ) -> Tuple[Params, AdamState, Dict[str, jax.Array]]:
    metrics: Dict[str, jax.Array] = {}
    if grad_clip and grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        metrics["grad_norm"] = gnorm
    t = state.step + 1
    tf = t.astype(jnp.float32)

    def one(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        if weight_decay:
            gf = gf + weight_decay * p.astype(jnp.float32)
        mu2 = b1 * mu + (1 - b1) * gf
        nu2 = b2 * nu + (1 - b2) * jnp.square(gf)
        mu_hat = mu2 / (1 - b1 ** tf)
        nu_hat = nu2 / (1 - b2 ** tf)
        new_p = (p.astype(jnp.float32)
                 - lr * mu_hat / (jnp.sqrt(nu_hat) + eps)).astype(p.dtype)
        return new_p, mu2, nu2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [one(*args) for args in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdamState(new_mu, new_nu, t), metrics
