"""Momentum SGD — the paper's optimizer ("we focused on momentum SGD, with a
fixed learning rate that decreases exponentially every few epochs").

Integrates the large-batch toolkit: global-norm gradient clipping and
multiplicative (ghost) gradient noise are applied inside ``update`` so a
single LargeBatchConfig drives the whole recipe.

Optionally stores momentum in a block-wise int8 quantized form
(``momentum_dtype="int8"``) — a beyond-paper memory optimization used to fit
the 1T-param config's optimizer state in pod HBM (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.clipping import clip_by_global_norm
from repro.core.noise import multiplicative_noise_grads

Params = Any

_QBLOCK = 256


def _quantize_int8(x: jax.Array) -> Dict[str, jax.Array]:
    """Blockwise int8 along the LAST axis, keeping the leading dims — the
    quantized buffers then shard exactly like their parameter (flattening
    would force GSPMD reshards between the param and momentum layouts)."""
    xf = x.astype(jnp.float32)
    last = xf.shape[-1]
    pad = (-last) % _QBLOCK
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)])
    nb = xf.shape[-1] // _QBLOCK
    blocks = xf.reshape(xf.shape[:-1] + (nb, _QBLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize_int8(qs: Dict[str, jax.Array], shape, dtype) -> jax.Array:
    blocks = qs["q"].astype(jnp.float32) * qs["scale"]
    flat_last = blocks.reshape(blocks.shape[:-2]
                               + (blocks.shape[-2] * _QBLOCK,))
    out = flat_last[..., : shape[-1]]
    return out.reshape(shape).astype(dtype)


class SGDState(NamedTuple):
    momentum: Params
    step: jax.Array


def init(params: Params, momentum_dtype: str = "float32") -> SGDState:
    if momentum_dtype == "int8":
        mom = jax.tree.map(lambda p: _quantize_int8(jnp.zeros_like(p)), params)
    else:
        dt = jnp.dtype(momentum_dtype)
        mom = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype=dt), params)
    return SGDState(momentum=mom, step=jnp.zeros((), jnp.int32))


def update(grads: Params, state: SGDState, params: Params, *,
           lr: jax.Array, momentum: float = 0.9, nesterov: bool = False,
           weight_decay: float = 0.0, grad_clip: float = 0.0,
           noise_sigma: float = 0.0, rng: Optional[jax.Array] = None,
           momentum_dtype: str = "float32",
           ) -> Tuple[Params, SGDState, Dict[str, jax.Array]]:
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    metrics: Dict[str, jax.Array] = {}
    if grad_clip and grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        metrics["grad_norm"] = gnorm
    if noise_sigma and noise_sigma > 0:
        assert rng is not None, "gradient noise needs an rng"
        grads = multiplicative_noise_grads(rng, grads, noise_sigma)

    is_q = momentum_dtype == "int8"

    def one(p, g, m):
        gf = g.astype(jnp.float32)
        if weight_decay:
            gf = gf + weight_decay * p.astype(jnp.float32)
        mf = (_dequantize_int8(m, p.shape, jnp.float32) if is_q
              else m.astype(jnp.float32))
        mf = momentum * mf + gf
        step_dir = (gf + momentum * mf) if nesterov else mf
        new_p = (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype)
        new_m = _quantize_int8(mf) if is_q else mf.astype(m.dtype)
        return new_p, new_m

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.momentum)
    out = [one(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mom = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_params, SGDState(new_mom, state.step + 1), metrics
