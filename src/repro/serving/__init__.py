from repro.serving.engine import (generate, make_serve_step,
                                  mask_padded_vocab, prefill, prefill_fused,
                                  sample_tokens)

__all__ = ["generate", "make_serve_step", "mask_padded_vocab", "prefill",
           "prefill_fused", "sample_tokens"]
