from repro.serving.engine import (Completion, ContinuousEngine, Request,
                                  generate, make_serve_step,
                                  mask_padded_vocab, poisson_trace, prefill,
                                  prefill_fused, run_static_trace,
                                  sample_tokens)

__all__ = ["Completion", "ContinuousEngine", "Request", "generate",
           "make_serve_step", "mask_padded_vocab", "poisson_trace",
           "prefill", "prefill_fused", "run_static_trace", "sample_tokens"]
