"""Batched serving: fused prefill + KV-cache decode loop over the assigned
decoder models.

``serve_step`` — ONE new token against a seq_len-deep cache — is the unit
the decode dry-run shapes (decode_32k / long_500k) lower. ``generate``
drives it for real batched requests (greedy or temperature/top-k sampling):

- **prefill** runs as ONE fused full-sequence forward
  (:func:`repro.models.transformer.prefill_forward`) that scatters every
  layer's K/V (and SSM state) into the cache and keeps only the
  last-position logits — the token-at-a-time ``prefill`` loop remains as
  the cross-checking fallback;
- **decode** with ``use_kernels=True`` routes cache attention through the
  Pallas flash-decode kernel (:func:`repro.kernels.ops.flash_decode`) over
  a head-major cache;
- ragged prompts are LEFT-padded (real tokens right-aligned) with
  ``prompt_lens`` — an attention-validity mask and per-row RoPE offsets
  thread through the decode path so results match each sequence generated
  unpadded.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.obs.trace import NULL_TRACER

Params = Any


def mask_padded_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """-inf the padded-vocab tail so no sampler can emit an id >=
    vocab_size. The ONE shared helper for every logits->token path (a
    prefill that skipped it used to emit out-of-vocab first tokens when
    ``padded_vocab != vocab_size``)."""
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, -jnp.inf, logits)
    return logits


def sample_tokens(cfg: ModelConfig, logits: jax.Array, *,
                  temperature: float = 0.0, top_k: int = 0,
                  rng: Optional[jax.Array] = None) -> jax.Array:
    """logits (B, V) -> token ids (B,) int32.

    ``temperature <= 0`` is exact greedy argmax (no rng needed); otherwise
    categorical over ``logits / temperature``, optionally restricted to the
    per-row ``top_k`` logits. Padded-vocab ids are masked in all modes.
    """
    logits = mask_padded_vocab(cfg, logits.astype(jnp.float32))
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if rng is None:
        raise ValueError("temperature sampling requires an rng key")
    if top_k > 0:
        # clamp to the REAL vocab: a top_k past vocab_size used to fall
        # into clamped negative indexing on the sorted logits, silently
        # truncating to a much smaller k (the padded tail is all -inf, so
        # k >= vocab_size must mean "no truncation")
        k_eff = min(top_k, cfg.vocab_size)
        kth = jnp.sort(logits, axis=-1)[..., -k_eff][..., None]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    return jax.random.categorical(rng, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def make_serve_step(cfg: ModelConfig, use_kernels: bool = False,
                    temperature: float = 0.0, top_k: int = 0) -> Callable:
    """(params, cache, tokens (B,1), pos[, rng, offsets])
    -> (next_tokens (B,1), new_cache)."""

    def serve_step(params: Params, cache: Params, tokens: jax.Array,
                   pos: jax.Array, rng: Optional[jax.Array] = None,
                   offsets: Optional[jax.Array] = None):
        logits, cache = T.decode_step(params, cfg, tokens, cache, pos,
                                      use_kernels=use_kernels,
                                      offsets=offsets)
        nxt = sample_tokens(cfg, logits[:, -1], temperature=temperature,
                            top_k=top_k, rng=rng)
        return nxt[:, None], cache

    return serve_step


def prefill(params: Params, cfg: ModelConfig, prompts: jax.Array,
            cache: Params, *, use_kernels: bool = False
            ) -> Tuple[jax.Array, Params]:
    """Token-at-a-time prefill fallback: feed the prompt through decode
    steps. Returns (last-position logits (B, V), filled cache).

    The scan carries ONLY the last-position logits (a previous version
    stacked the full (P, B, 1, V) logits tensor and then threw away all but
    the last row — O(P·B·V) wasted memory on long prompts). The fused
    :func:`prefill_fused` supersedes this path for production; it stays as
    the independently-coded cross-check the equality tests compare against.
    """
    B, P = prompts.shape
    dtype = jnp.dtype(cfg.dtype)

    def body(carry, t):
        cache, _ = carry
        logits, cache = T.decode_step(params, cfg, prompts[:, t][:, None],
                                      cache, t, use_kernels=use_kernels)
        return (cache, logits[:, -1]), None

    init = (cache, jnp.zeros((B, cfg.padded_vocab), dtype))
    (cache, last), _ = jax.lax.scan(body, init, jnp.arange(P))
    return last, cache


def prefill_fused(params: Params, cfg: ModelConfig, prompts: jax.Array,
                  cache: Params, *, offsets: Optional[jax.Array] = None,
                  use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    """Fused prefill: one full-sequence forward pass scatters all layers'
    K/V into the cache. Returns (last-position logits (B, V), filled cache).
    """
    logits, cache = T.prefill_forward(params, cfg, prompts, cache,
                                      use_kernels=use_kernels,
                                      offsets=offsets)
    return logits[:, -1], cache


def generate(params: Params, cfg: ModelConfig, prompts: jax.Array, *,
             max_new_tokens: int = 32, max_len: Optional[int] = None,
             memory: Optional[jax.Array] = None,
             use_kernels: bool = False,
             temperature: float = 0.0, top_k: int = 0,
             rng: Optional[jax.Array] = None,
             prompt_lens: Optional[jax.Array] = None,
             fused_prefill: bool = True) -> jax.Array:
    """Batched generation. prompts: (B, P) -> (B, P + max_new_tokens).

    ``temperature == 0`` (default) is greedy; ``temperature > 0`` samples
    from ``softmax(logits / temperature)`` (optionally top-k-truncated) and
    requires ``rng``. ``prompt_lens`` (B,) marks LEFT-padded ragged
    prompts: row b's real tokens occupy the last ``prompt_lens[b]`` columns
    and the left padding is masked out of every attention, so each row's
    continuation equals its unpadded run. ``use_kernels=True`` uses the
    fused flash prefill + flash-decode Pallas kernels over a head-major
    cache.

    ``max_len`` (when given) is the cache depth and must cover the prompt
    plus every new token — a shallower cache would silently write decode
    steps past the cache depth and corrupt it, so it raises instead
    (``max_len=0`` is a zero-depth cache, not "use the default", and
    raises too).
    """
    B, P = prompts.shape
    if max_len is not None:
        total = max_len
        if total < P + max_new_tokens:
            raise ValueError(
                f"max_len={total} is shallower than prompt ({P}) + "
                f"max_new_tokens ({max_new_tokens}) = {P + max_new_tokens}; "
                f"decode steps would write past the cache depth")
    else:
        total = P + max_new_tokens
    if temperature > 0.0 and rng is None:
        raise ValueError("temperature > 0 requires an rng key")
    offsets = None
    if prompt_lens is not None:
        if not fused_prefill:
            raise ValueError(
                "ragged prompts (prompt_lens) require the fused prefill")
        lens = jnp.asarray(prompt_lens)
        try:
            bad = bool(((lens < 1) | (lens > P)).any())
        except jax.errors.ConcretizationTypeError:
            bad = False          # traced under jit: caller's responsibility
        if bad:
            raise ValueError(
                f"prompt_lens must be in [1, {P}] (the padded prompt "
                f"width); got {prompt_lens}")
        offsets = (P - lens).astype(jnp.int32)
    if max_new_tokens == 0:
        # zero new tokens means the prompts unchanged — the prefill-sampled
        # token used to be concatenated unconditionally, returning (B, P+1)
        return prompts
    mem_len = memory.shape[1] if memory is not None else 0
    cache = T.init_cache(cfg, B, total, memory_len=mem_len,
                         dtype=jnp.dtype(cfg.dtype),
                         layout="head" if use_kernels else "seq")
    if memory is not None:
        cache = T.build_cross_cache(params, cfg, memory, cache)
    if fused_prefill:
        last, cache = prefill_fused(params, cfg, prompts, cache,
                                    offsets=offsets, use_kernels=use_kernels)
    else:
        last, cache = prefill(params, cfg, prompts, cache,
                              use_kernels=use_kernels)
    step = make_serve_step(cfg, use_kernels, temperature, top_k)
    base_rng = rng if rng is not None else jax.random.PRNGKey(0)
    tok = sample_tokens(cfg, last, temperature=temperature, top_k=top_k,
                        rng=jax.random.fold_in(base_rng, 0))[:, None]

    # the prefill already sampled token P, so only N-1 decode steps remain —
    # the scan emits each step's OUTPUT (emitting the carry would burn one
    # extra full decode_step whose sampled token is discarded)
    def body(carry, i):
        tok, cache = carry
        nxt, cache = step(params, cache, tok, P + i,
                          rng=jax.random.fold_in(base_rng, i + 1),
                          offsets=offsets)
        return (nxt, cache), nxt[:, 0]

    (_, _), toks = jax.lax.scan(body, (tok, cache),
                                jnp.arange(max_new_tokens - 1))
    return jnp.concatenate([prompts, tok, toks.T], axis=1)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One serving request. ``arrival`` is in decode-step units (the
    engine's simulated clock): the request becomes visible to the scheduler
    once that many decode steps have executed."""
    id: int
    prompt: Any                     # (L,) int token ids (list / np / jnp)
    max_new_tokens: int
    arrival: float = 0.0


@dataclasses.dataclass
class Completion:
    """Finished request: the generated continuation (prompt excluded) and
    the decode-step clock at which the row retired."""
    id: int
    tokens: list
    finished_at: float


def _tree_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _is_stacked(p: str) -> bool:
    return "body" in p.split("/")


def _page_blocks(src: jax.Array, ps: int, stacked: bool) -> jax.Array:
    """Gather a batch-1 head-major kh/vh leaf into page-sized blocks:
    (nb, kv, ps, hd), with a leading repeats dim when ``stacked``."""
    if stacked:
        t = src[:, 0]                             # (R, kv, S, hd)
        R, kv, S, hd = t.shape
        return t.reshape(R, kv, S // ps, ps, hd).swapaxes(1, 2)
    t = src[0]                                    # (kv, S, hd)
    kv, S, hd = t.shape
    return t.reshape(kv, S // ps, ps, hd).swapaxes(0, 1)


def _slot_scales(blocks: jax.Array) -> jax.Array:
    """Per-slot symmetric int8 scales over the head dim (matches the
    quantized decode write in ``layers.attention_decode``)."""
    a = jnp.abs(blocks.astype(jnp.float32)).max(axis=-1)
    return jnp.maximum(a, 1e-8) / 127.0


def _scatter_admit(cache: Params, tmp: Params, slot: jax.Array,
                   pages: jax.Array) -> Params:
    """Scatter a freshly prefilled batch-1 contiguous cache ``tmp`` into
    row ``slot`` of the serving cache.

    Contiguous leaves (kh/vh ring buffers, seq k/v, SSM h/conv) are a row
    copy. Paged leaves gather the temp cache's full-depth kh/vh into
    page-sized blocks and scatter them at ``pages`` (the row's freshly
    assigned block table, trash page 0 for blocks past the prompt — those
    slots are masked until decode writes them); ``pt`` rows are set to
    ``pages``. int8 pools quantize the gathered blocks per slot on the way
    in and write the matching scale planes at ``ks``/``vs``. Stacked body
    leaves carry a leading repeats dim.
    """
    tmp_flat = {
        _tree_path_str(path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tmp)[0]}

    def upd(path, leaf):
        p = _tree_path_str(path)
        stacked = _is_stacked(p)
        if p.endswith("/pt"):
            return (leaf.at[:, slot].set(pages) if stacked
                    else leaf.at[slot].set(pages))
        if p.endswith("/kp") or p.endswith("/vp"):
            src = tmp_flat[p[:-2] + ("kh" if p.endswith("/kp") else "vh")]
            blocks = _page_blocks(src, leaf.shape[-2], stacked)
            if leaf.dtype == jnp.int8:
                bf = blocks.astype(jnp.float32)
                sc = _slot_scales(blocks)
                blocks = jnp.clip(jnp.round(bf / sc[..., None]), -127, 127)
            if stacked:
                return leaf.at[:, pages].set(blocks.astype(leaf.dtype))
            return leaf.at[pages].set(blocks.astype(leaf.dtype))
        if p.endswith("/ks") or p.endswith("/vs"):
            src = tmp_flat[p[:-2] + ("kh" if p.endswith("/ks") else "vh")]
            sc = _slot_scales(_page_blocks(src, leaf.shape[-1], stacked))
            return (leaf.at[:, pages].set(sc) if stacked
                    else leaf.at[pages].set(sc))
        src = tmp_flat[p]
        if stacked:
            return leaf.at[:, slot].set(src[:, 0].astype(leaf.dtype))
        return leaf.at[slot].set(src[0].astype(leaf.dtype))

    return jax.tree_util.tree_map_with_path(upd, cache)


def _write_pt(cache: Params, pt: jax.Array) -> Params:
    """Overwrite every layer's block table with ``pt`` (num_slots, NB) —
    the engine keeps ONE logical table shared by all layers (each layer
    has its own page pool, addressed by the same page ids)."""
    def upd(path, leaf):
        p = _tree_path_str(path)
        if p.endswith("/pt"):
            return jnp.broadcast_to(pt, leaf.shape).astype(leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(upd, cache)


class ContinuousEngine:
    """Continuous-batching scheduler over a fixed pool of decode slots.

    The static engine (:func:`generate`) decodes one batch in lockstep: a
    single long request holds every freed slot hostage until the whole
    batch drains. Here each row advances at its OWN position (the per-row
    ``pos`` vector threads through :func:`repro.models.transformer.
    decode_step` into the flash-decode kernels), a row that emits EOS or
    reaches its token budget RETIRES immediately, and the freed slot is
    refilled mid-flight by prefilling the next queued request into just
    that row (:func:`prefill_fused` on a batch-1 temp cache, scattered in
    by :func:`_scatter_admit`).

    ``layout="paged"`` backs full-attention layers with a physical page
    pool + per-row block tables (see ``layers.init_kv_cache``): pages are
    allocated from a host-side free list as rows grow and returned on
    retirement, so cache memory is bounded by TOTAL in-flight tokens, not
    num_slots x worst-case length. A retired row's table is zeroed — its
    (dead) decode writes land on the reserved trash page 0, which every
    visibility mask excludes, so survivors are bit-exact vs running each
    request alone (the equality tests assert exactly that).

    ``cache_dtype="int8"`` quantizes the paged pool per slot (symmetric
    over the head dim, f32 ``ks``/``vs`` scale planes): kp/vp payload
    bytes halve vs bf16, so the same pool memory holds twice the decode
    slots; admission quantizes the prefilled blocks on scatter and the
    decode kernels dequantize at load (see docs/serving.md for the
    accuracy trade-off).

    Host/device split: ``pos``/``active``/block tables/the arrival queue
    live host-side (numpy); the decode step is ONE jitted call per token
    over all slots with the cache donated. Retired rows keep stepping (a
    dead row's lane costs nothing extra in the fixed-shape batch) but
    their ``pos`` is frozen and their output discarded — those lanes are
    the raw-vs-useful throughput gap ``stats()`` reports as
    ``dropped_tokens``. Compiles are bounded: one decode step, one
    pt-write, plus one admission prefill per DISTINCT prompt length.

    ``obs`` (a :class:`repro.obs.Observability`) instruments the loop:
    spans around decode step / admission / page allocation, and the SLO
    set in the registry — ``serve/ttft_s`` (enqueue to first token),
    ``serve/itl_s`` (per-token inter-token gap), ``serve/e2e_s``
    (enqueue to retirement), plus per-tick ``serve/queue_depth``,
    ``serve/slot_occupancy``, and ``serve/page_pool_util`` histograms.
    Without ``obs`` every instrumentation point is the tracer's no-op
    singleton span / a skipped branch.
    """

    def __init__(self, params: Params, cfg: ModelConfig, *,
                 num_slots: int, max_len: int, layout: str = "paged",
                 page_size: int = 16, total_pages: Optional[int] = None,
                 cache_dtype: Optional[str] = None,
                 use_kernels: bool = False, eos_id: Optional[int] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 rng: Optional[jax.Array] = None, obs=None, mesh=None):
        if any(s.cross_attn for s in (tuple(cfg.head_pattern)
                                      + tuple(cfg.body_pattern)
                                      + tuple(cfg.tail_pattern))):
            raise ValueError("ContinuousEngine serves decoder-only models "
                             "(no cross-attention memory)")
        self.mesh = mesh
        if mesh is not None:
            # model-sharded serving: params per the pjit rules (Megatron
            # attention/MLP over "model"), and below the paged pool over
            # kv-heads per rules.cache_specs — GSPMD inserts the collectives.
            from repro.sharding import rules
            params = jax.device_put(params, rules.param_shardings(params,
                                                                  mesh))
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.layout = layout
        self.cache_dtype = cache_dtype
        self.use_kernels = use_kernels
        self.eos_id = eos_id
        self.temperature = temperature
        self.top_k = top_k
        self.dtype = jnp.dtype(cfg.dtype)
        self.obs = obs
        self._tracer = obs.tracer if obs is not None else NULL_TRACER
        self._reg = obs.registry if obs is not None else None
        self._base_rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.paged = layout == "paged"
        if self.paged:
            if max_len % page_size != 0:
                raise ValueError(
                    f"max_len={max_len} must be a multiple of "
                    f"page_size={page_size}")
            self.page_size = page_size
            self.n_blocks = max_len // page_size
            default_pages = 1 + num_slots * self.n_blocks
            self.total_pages = (total_pages if total_pages is not None
                                else default_pages)
            if self.total_pages < 1 + self.n_blocks:
                raise ValueError(
                    f"total_pages={self.total_pages} cannot hold even one "
                    f"full-length row (+ trash page)")
        else:
            self.page_size = self.n_blocks = self.total_pages = 0
        self._step_fn = jax.jit(
            make_serve_step(cfg, use_kernels, temperature, top_k),
            donate_argnums=(1,))
        self._write_pt_fn = jax.jit(_write_pt, donate_argnums=(0,))
        self._admit_fns: Dict[int, Callable] = {}
        self.reset()

    # -- state ---------------------------------------------------------------

    def reset(self) -> None:
        cfg, n = self.cfg, self.num_slots
        self.cache = T.init_cache(
            cfg, n, self.max_len, dtype=self.dtype, layout=self.layout,
            page_size=self.page_size or 64,
            total_pages=self.total_pages or None,
            cache_dtype=self.cache_dtype)
        if self.mesh is not None:
            from repro.sharding import rules
            self.cache = jax.device_put(
                self.cache, rules.to_shardings(
                    rules.cache_specs(self.cache, self.mesh, n), self.mesh))
        self.pos = np.zeros((n,), np.int32)
        self.active = np.zeros((n,), bool)
        self._last = jnp.zeros((n, 1), jnp.int32)
        self.slot_req: list = [None] * n
        if self.paged:
            self.pt_host = np.zeros((n, self.n_blocks), np.int32)
            self.free_pages = list(range(self.total_pages - 1, 0, -1))
        self.queue: list = []         # admitted-able requests, FIFO
        self.pending: list = []       # future arrivals (sorted, popped front)
        self.completions: Dict[int, Completion] = {}
        self._generated: Dict[int, list] = {}
        self.clock = 0.0              # decode steps executed
        self.steps = 0
        self.tokens_out = 0           # useful: tokens delivered to requests
        self.tokens_raw = 0           # every token the model decoded
        self.tokens_dropped = 0       # retired-lane tokens thrown away
        self._enq_wall: Dict[int, float] = {}   # req id -> queue-entry wall
        self._run_t0 = time.perf_counter()
        self._run_elapsed = 0.0       # frozen at run() end
        self._rng_i = 0

    # -- scheduling ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        L = int(jnp.asarray(req.prompt).shape[0])
        if L < 1 or L + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.id}: prompt ({L}) + max_new_tokens "
                f"({req.max_new_tokens}) must fit max_len={self.max_len}")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.id}: max_new_tokens must be >= 1")
        self._enqueue(req)

    def _enqueue(self, req: Request) -> None:
        """Make a request visible to the scheduler; the wall clock here is
        the zero point for its TTFT/e2e latencies."""
        self.queue.append(req)
        self._enq_wall.setdefault(req.id, time.perf_counter())

    def _next_rng(self) -> jax.Array:
        self._rng_i += 1
        return jax.random.fold_in(self._base_rng, self._rng_i)

    def _pages_for(self, n_needed: int, row: "Any") -> bool:
        """Allocate physical pages for row blocks [0, n_needed) that are
        still on the trash page. Returns False if the pool is exhausted."""
        for i in range(n_needed):
            if self.pt_host[row, i] == 0:
                if not self.free_pages:
                    return False
                self.pt_host[row, i] = self.free_pages.pop()
        return True

    def _make_admit(self, L: int) -> Callable:
        cfg = self.cfg
        # the temp cache must be head-major wherever the main cache is:
        # paged pools scatter from head-major blocks, and contiguous
        # head/seq leaves are copied row-for-row
        tmp_layout = "head" if (self.paged or self.layout == "head") \
            else "seq"
        uk, temp, tk = self.use_kernels, self.temperature, self.top_k
        max_len, dtype, paged = self.max_len, self.dtype, self.paged

        def admit(params, cache, prompt, slot, pages, rng):
            tmp = T.init_cache(cfg, 1, max_len, dtype=dtype,
                               layout=tmp_layout)
            last, tmp = prefill_fused(params, cfg, prompt[None], tmp,
                                      use_kernels=uk)
            tok = sample_tokens(cfg, last, temperature=temp, top_k=tk,
                                rng=rng)
            cache = _scatter_admit(cache, tmp, slot, pages)
            return tok, cache

        if not paged:
            # pages is unused; close over a dummy so the jit signature is
            # stable
            def admit_nopage(params, cache, prompt, slot, rng):
                return admit(params, cache, prompt, slot,
                             jnp.zeros((0,), jnp.int32), rng)
            return jax.jit(admit_nopage, donate_argnums=(1,))
        return jax.jit(admit, donate_argnums=(1,))

    def _admit(self, req: Request, slot: int) -> bool:
        prompt = jnp.asarray(req.prompt, jnp.int32)
        L = int(prompt.shape[0])
        with self._tracer.span("serve.admit", req=req.id, prompt_len=L,
                               slot=slot):
            if self.paged:
                if not self._pages_for(-(-L // self.page_size), slot):
                    return False           # pool exhausted; stay queued
            fn = self._admit_fns.get(L)
            if fn is None:
                fn = self._admit_fns[L] = self._make_admit(L)
            rng = self._next_rng()
            if self.paged:
                pages = jnp.asarray(self.pt_host[slot], jnp.int32)
                tok, self.cache = fn(self.params, self.cache, prompt,
                                     jnp.int32(slot), pages, rng)
            else:
                tok, self.cache = fn(self.params, self.cache, prompt,
                                     jnp.int32(slot), rng)
            self._last = self._last.at[slot].set(tok)
        self.pos[slot] = L
        self.active[slot] = True
        self.slot_req[slot] = req
        self._generated[req.id] = []
        self.tokens_out += 1
        self.tokens_raw += 1
        if self._reg is not None:
            # the admission prefill sampled the request's FIRST token
            wall = time.perf_counter()
            self._reg.observe("serve/ttft_s",
                              wall - self._enq_wall.get(req.id, wall))
        self._record(slot, int(tok[0]))
        return True

    def _record(self, slot: int, tok: int) -> None:
        """Append one generated token to the slot's request; retire on EOS
        or budget exhaustion."""
        req = self.slot_req[slot]
        out = self._generated[req.id]
        out.append(tok)
        if ((self.eos_id is not None and tok == self.eos_id)
                or len(out) >= req.max_new_tokens):
            self._retire(slot)

    def _retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        self.completions[req.id] = Completion(
            id=req.id, tokens=list(self._generated.pop(req.id)),
            finished_at=self.clock)
        self.active[slot] = False     # pos intentionally frozen
        self.slot_req[slot] = None
        enq = self._enq_wall.pop(req.id, None)
        if self._reg is not None and enq is not None:
            self._reg.observe("serve/e2e_s", time.perf_counter() - enq)
            self._reg.inc("serve/completions")
        if self.paged:
            row = self.pt_host[slot]
            self.free_pages.extend(int(p) for p in row[row != 0])
            self.pt_host[slot] = 0
            self.cache = self._write_pt_fn(
                self.cache, jnp.asarray(self.pt_host))

    def _release_arrivals(self) -> None:
        while self.pending and self.pending[0].arrival <= self.clock:
            self._enqueue(self.pending.pop(0))

    def _admit_ready(self) -> None:
        free = [s for s in range(self.num_slots) if not self.active[s]]
        while free and self.queue:
            if not self._admit(self.queue[0], free[0]):
                break                 # page pool exhausted — wait for frees
            self.queue.pop(0)
            free.pop(0)

    def _ensure_pages(self) -> None:
        """Pre-step page allocation: every active row is about to write its
        K/V at slot ``pos`` — make sure the block holding it is backed."""
        dirty = 0
        for s in range(self.num_slots):
            if not self.active[s]:
                continue
            blk = int(self.pos[s]) // self.page_size
            if blk < self.n_blocks and self.pt_host[s, blk] == 0:
                if not self.free_pages:
                    raise RuntimeError(
                        "page pool exhausted mid-decode: total_pages too "
                        "small for the admitted working set")
                self.pt_host[s, blk] = self.free_pages.pop()
                dirty += 1
        if dirty:
            with self._tracer.span("serve.page_alloc", pages=dirty):
                self.cache = self._write_pt_fn(
                    self.cache, jnp.asarray(self.pt_host))

    # -- the loop ------------------------------------------------------------

    def step(self) -> None:
        """One decode step over all slots (active rows advance; retired
        rows write into masked slots / the trash page and are ignored)."""
        t0 = time.perf_counter()
        with self._tracer.span("serve.decode_step", step=self.steps):
            if self.paged:
                self._ensure_pages()
            rng = (self._next_rng() if self.temperature > 0 else None)
            toks, self.cache = self._step_fn(
                self.params, self.cache, self._last,
                jnp.asarray(self.pos), rng)
            self._last = toks
            host = jax.device_get(toks)[:, 0]
        was_active = [s for s in range(self.num_slots) if self.active[s]]
        self.steps += 1
        self.clock += 1.0
        # every lane decoded a token; only active lanes delivered one
        self.tokens_raw += self.num_slots
        self.tokens_dropped += self.num_slots - len(was_active)
        if self._reg is not None:
            dt = time.perf_counter() - t0
            reg = self._reg
            reg.observe("serve/step_time_s", dt)
            itl = reg.histogram("serve/itl_s")
            for _ in was_active:   # each active row got one token this tick
                itl.observe(dt)
            reg.observe("serve/queue_depth", len(self.queue))
            reg.observe("serve/slot_occupancy",
                        len(was_active) / self.num_slots)
            if self.paged:
                in_use = self.total_pages - 1 - len(self.free_pages)
                reg.observe("serve/page_pool_util",
                            in_use / (self.total_pages - 1))
        for s in was_active:
            self.pos[s] += 1
            self.tokens_out += 1
            self._record(s, int(host[s]))

    def run(self, requests) -> Dict[int, Completion]:
        """Drive the arrival queue to completion: admit requests as their
        ``arrival`` clock passes and slots free up, decode until every
        request has finished. Returns {request id: Completion}."""
        self.reset()
        self.pending = sorted(requests, key=lambda r: r.arrival)
        for r in self.pending:
            L = int(jnp.asarray(r.prompt).shape[0])
            if L < 1 or r.max_new_tokens < 1 \
                    or L + r.max_new_tokens > self.max_len:
                raise ValueError(f"request {r.id} does not fit max_len="
                                 f"{self.max_len}")
        with self._tracer.span("serve.run", requests=len(self.pending)):
            while self.pending or self.queue or self.active.any():
                self._release_arrivals()
                self._admit_ready()
                if not self.active.any():
                    if self.pending:  # idle: jump the clock to next arrival
                        self.clock = max(self.clock, self.pending[0].arrival)
                        continue
                    break             # queue non-empty but nothing admitted
                self.step()
        if self.queue:
            raise RuntimeError(
                f"{len(self.queue)} requests could never be admitted "
                f"(prompt longer than any slot's page budget?)")
        self._run_elapsed = time.perf_counter() - self._run_t0
        if self._reg is not None:
            for name, value in self.stats().items():
                self._reg.set(f"serve/{name}", value)
        return self.completions

    def stats(self) -> Dict[str, float]:
        """Throughput accounting for the last/current ``run``: raw tok/s is
        every token the model decoded (dead retired lanes included);
        useful tok/s counts only tokens delivered to a request — the gap
        (``dropped_tokens``) is the engine's wasted work."""
        elapsed = max(self._run_elapsed
                      or time.perf_counter() - self._run_t0, 1e-9)
        return {"steps": float(self.steps),
                "useful_tokens": float(self.tokens_out),
                "raw_tokens": float(self.tokens_raw),
                "dropped_tokens": float(self.tokens_dropped),
                "useful_tok_s": self.tokens_out / elapsed,
                "raw_tok_s": self.tokens_raw / elapsed,
                "elapsed_s": elapsed}


def poisson_trace(cfg: ModelConfig, n_requests: int, *, rate: float,
                  prompt_len_choices=(8, 16, 24),
                  new_token_choices=(4, 16, 32),
                  seed: int = 0) -> list:
    """Synthetic serving trace: request inter-arrival times are
    exponential(1/rate) in decode-step units (a Poisson process over the
    engine clock); prompt and output lengths are drawn uniformly from the
    given choice sets (small sets keep admission-prefill compiles
    bounded)."""
    r = np.random.RandomState(seed)
    t, out = 0.0, []
    for i in range(n_requests):
        t += float(r.exponential(1.0 / rate))
        L = int(r.choice(prompt_len_choices))
        N = int(r.choice(new_token_choices))
        prompt = r.randint(0, cfg.vocab_size, size=(L,)).astype("int32")
        out.append(Request(id=i, prompt=prompt, max_new_tokens=N, arrival=t))
    return out


def run_static_trace(params: Params, cfg: ModelConfig, requests, *,
                     batch: int, max_len: int,
                     use_kernels: bool = False) -> int:
    """Static-batch baseline for the same trace: serve requests in arrival
    order in fixed lockstep groups of ``batch`` via :func:`generate`.

    Every group is padded to ONE shape — (batch, P_max) prompts (ragged via
    ``prompt_lens``) decoding N_max steps — so the whole baseline compiles
    once; that is also its weakness, which the continuous engine exploits:
    each group runs as long as its LONGEST member while finished rows idle.
    Returns the number of USEFUL new tokens (each request's own budget;
    lockstep overshoot is discarded).
    """
    reqs = sorted(requests, key=lambda r: r.arrival)
    P_max = max(int(jnp.asarray(r.prompt).shape[0]) for r in reqs)
    N_max = max(r.max_new_tokens for r in reqs)
    assert P_max + N_max <= max_len, (P_max, N_max, max_len)
    gen = jax.jit(lambda p, toks, lens: generate(
        p, cfg, toks, max_new_tokens=N_max, max_len=max_len,
        use_kernels=use_kernels, prompt_lens=lens))
    useful = 0
    for g0 in range(0, len(reqs), batch):
        group = reqs[g0:g0 + batch]
        while len(group) < batch:     # pad the tail group by repetition
            group.append(group[-1])
        prompts = np.zeros((batch, P_max), np.int32)
        lens = np.zeros((batch,), np.int32)
        for i, r in enumerate(group):
            p = np.asarray(r.prompt, np.int32)
            prompts[i, P_max - len(p):] = p       # LEFT-padded
            lens[i] = len(p)
        out = gen(params, jnp.asarray(prompts), jnp.asarray(lens))
        jax.block_until_ready(out)
    for r in reqs:
        useful += r.max_new_tokens
    return useful
