"""Batched serving: fused prefill + KV-cache decode loop over the assigned
decoder models.

``serve_step`` — ONE new token against a seq_len-deep cache — is the unit
the decode dry-run shapes (decode_32k / long_500k) lower. ``generate``
drives it for real batched requests (greedy or temperature/top-k sampling):

- **prefill** runs as ONE fused full-sequence forward
  (:func:`repro.models.transformer.prefill_forward`) that scatters every
  layer's K/V (and SSM state) into the cache and keeps only the
  last-position logits — the token-at-a-time ``prefill`` loop remains as
  the cross-checking fallback;
- **decode** with ``use_kernels=True`` routes cache attention through the
  Pallas flash-decode kernel (:func:`repro.kernels.ops.flash_decode`) over
  a head-major cache;
- ragged prompts are LEFT-padded (real tokens right-aligned) with
  ``prompt_lens`` — an attention-validity mask and per-row RoPE offsets
  thread through the decode path so results match each sequence generated
  unpadded.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

Params = Any


def mask_padded_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """-inf the padded-vocab tail so no sampler can emit an id >=
    vocab_size. The ONE shared helper for every logits->token path (a
    prefill that skipped it used to emit out-of-vocab first tokens when
    ``padded_vocab != vocab_size``)."""
    if cfg.padded_vocab != cfg.vocab_size:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, -jnp.inf, logits)
    return logits


def sample_tokens(cfg: ModelConfig, logits: jax.Array, *,
                  temperature: float = 0.0, top_k: int = 0,
                  rng: Optional[jax.Array] = None) -> jax.Array:
    """logits (B, V) -> token ids (B,) int32.

    ``temperature <= 0`` is exact greedy argmax (no rng needed); otherwise
    categorical over ``logits / temperature``, optionally restricted to the
    per-row ``top_k`` logits. Padded-vocab ids are masked in all modes.
    """
    logits = mask_padded_vocab(cfg, logits.astype(jnp.float32))
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if rng is None:
        raise ValueError("temperature sampling requires an rng key")
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    return jax.random.categorical(rng, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def make_serve_step(cfg: ModelConfig, use_kernels: bool = False,
                    temperature: float = 0.0, top_k: int = 0) -> Callable:
    """(params, cache, tokens (B,1), pos[, rng, offsets])
    -> (next_tokens (B,1), new_cache)."""

    def serve_step(params: Params, cache: Params, tokens: jax.Array,
                   pos: jax.Array, rng: Optional[jax.Array] = None,
                   offsets: Optional[jax.Array] = None):
        logits, cache = T.decode_step(params, cfg, tokens, cache, pos,
                                      use_kernels=use_kernels,
                                      offsets=offsets)
        nxt = sample_tokens(cfg, logits[:, -1], temperature=temperature,
                            top_k=top_k, rng=rng)
        return nxt[:, None], cache

    return serve_step


def prefill(params: Params, cfg: ModelConfig, prompts: jax.Array,
            cache: Params, *, use_kernels: bool = False
            ) -> Tuple[jax.Array, Params]:
    """Token-at-a-time prefill fallback: feed the prompt through decode
    steps. Returns (last-position logits (B, V), filled cache).

    The scan carries ONLY the last-position logits (a previous version
    stacked the full (P, B, 1, V) logits tensor and then threw away all but
    the last row — O(P·B·V) wasted memory on long prompts). The fused
    :func:`prefill_fused` supersedes this path for production; it stays as
    the independently-coded cross-check the equality tests compare against.
    """
    B, P = prompts.shape
    dtype = jnp.dtype(cfg.dtype)

    def body(carry, t):
        cache, _ = carry
        logits, cache = T.decode_step(params, cfg, prompts[:, t][:, None],
                                      cache, t, use_kernels=use_kernels)
        return (cache, logits[:, -1]), None

    init = (cache, jnp.zeros((B, cfg.padded_vocab), dtype))
    (cache, last), _ = jax.lax.scan(body, init, jnp.arange(P))
    return last, cache


def prefill_fused(params: Params, cfg: ModelConfig, prompts: jax.Array,
                  cache: Params, *, offsets: Optional[jax.Array] = None,
                  use_kernels: bool = False) -> Tuple[jax.Array, Params]:
    """Fused prefill: one full-sequence forward pass scatters all layers'
    K/V into the cache. Returns (last-position logits (B, V), filled cache).
    """
    logits, cache = T.prefill_forward(params, cfg, prompts, cache,
                                      use_kernels=use_kernels,
                                      offsets=offsets)
    return logits[:, -1], cache


def generate(params: Params, cfg: ModelConfig, prompts: jax.Array, *,
             max_new_tokens: int = 32, max_len: Optional[int] = None,
             memory: Optional[jax.Array] = None,
             use_kernels: bool = False,
             temperature: float = 0.0, top_k: int = 0,
             rng: Optional[jax.Array] = None,
             prompt_lens: Optional[jax.Array] = None,
             fused_prefill: bool = True) -> jax.Array:
    """Batched generation. prompts: (B, P) -> (B, P + max_new_tokens).

    ``temperature == 0`` (default) is greedy; ``temperature > 0`` samples
    from ``softmax(logits / temperature)`` (optionally top-k-truncated) and
    requires ``rng``. ``prompt_lens`` (B,) marks LEFT-padded ragged
    prompts: row b's real tokens occupy the last ``prompt_lens[b]`` columns
    and the left padding is masked out of every attention, so each row's
    continuation equals its unpadded run. ``use_kernels=True`` uses the
    fused flash prefill + flash-decode Pallas kernels over a head-major
    cache.

    ``max_len`` (when given) is the cache depth and must cover the prompt
    plus every new token — a shallower cache would silently write decode
    steps past the cache depth and corrupt it, so it raises instead
    (``max_len=0`` is a zero-depth cache, not "use the default", and
    raises too).
    """
    B, P = prompts.shape
    if max_len is not None:
        total = max_len
        if total < P + max_new_tokens:
            raise ValueError(
                f"max_len={total} is shallower than prompt ({P}) + "
                f"max_new_tokens ({max_new_tokens}) = {P + max_new_tokens}; "
                f"decode steps would write past the cache depth")
    else:
        total = P + max_new_tokens
    if temperature > 0.0 and rng is None:
        raise ValueError("temperature > 0 requires an rng key")
    offsets = None
    if prompt_lens is not None:
        if not fused_prefill:
            raise ValueError(
                "ragged prompts (prompt_lens) require the fused prefill")
        lens = jnp.asarray(prompt_lens)
        try:
            bad = bool(((lens < 1) | (lens > P)).any())
        except jax.errors.ConcretizationTypeError:
            bad = False          # traced under jit: caller's responsibility
        if bad:
            raise ValueError(
                f"prompt_lens must be in [1, {P}] (the padded prompt "
                f"width); got {prompt_lens}")
        offsets = (P - lens).astype(jnp.int32)
    mem_len = memory.shape[1] if memory is not None else 0
    cache = T.init_cache(cfg, B, total, memory_len=mem_len,
                         dtype=jnp.dtype(cfg.dtype),
                         layout="head" if use_kernels else "seq")
    if memory is not None:
        cache = T.build_cross_cache(params, cfg, memory, cache)
    if fused_prefill:
        last, cache = prefill_fused(params, cfg, prompts, cache,
                                    offsets=offsets, use_kernels=use_kernels)
    else:
        last, cache = prefill(params, cfg, prompts, cache,
                              use_kernels=use_kernels)
    step = make_serve_step(cfg, use_kernels, temperature, top_k)
    base_rng = rng if rng is not None else jax.random.PRNGKey(0)
    tok = sample_tokens(cfg, last, temperature=temperature, top_k=top_k,
                        rng=jax.random.fold_in(base_rng, 0))[:, None]

    # the prefill already sampled token P, so only N-1 decode steps remain —
    # the scan emits each step's OUTPUT (emitting the carry would burn one
    # extra full decode_step whose sampled token is discarded)
    def body(carry, i):
        tok, cache = carry
        nxt, cache = step(params, cache, tok, P + i,
                          rng=jax.random.fold_in(base_rng, i + 1),
                          offsets=offsets)
        return (nxt, cache), nxt[:, 0]

    (_, _), toks = jax.lax.scan(body, (tok, cache),
                                jnp.arange(max_new_tokens - 1))
    return jnp.concatenate([prompts, tok, toks.T], axis=1)
