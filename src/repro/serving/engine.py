"""Batched serving: KV-cache decode loop over the assigned decoder models.

``serve_step`` — ONE new token against a seq_len-deep cache — is the unit the
decode dry-run shapes (decode_32k / long_500k) lower. ``generate`` drives it
for real batched requests (greedy or temperature sampling).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

Params = Any


def make_serve_step(cfg: ModelConfig, use_kernels: bool = False) -> Callable:
    """(params, cache, tokens (B,1), pos) -> (next_tokens (B,1), new_cache)."""

    def serve_step(params: Params, cache: Params, tokens: jax.Array,
                   pos: jax.Array):
        logits, cache = T.decode_step(params, cfg, tokens, cache, pos,
                                      use_kernels=use_kernels)
        if cfg.padded_vocab != cfg.vocab_size:
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad[None, None, :], -jnp.inf, logits)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step


def prefill(params: Params, cfg: ModelConfig, prompts: jax.Array,
            cache: Params, *, use_kernels: bool = False
            ) -> Tuple[jax.Array, Params]:
    """Feed the prompt through decode steps (token-at-a-time prefill).

    Production prefill would run the fused full-sequence forward and scatter
    K/V into the cache; at demo scale the step loop is adequate and reuses
    the exact decode path under test.
    """
    B, P = prompts.shape

    def body(carry, t):
        cache = carry
        logits, cache = T.decode_step(params, cfg, prompts[:, t][:, None],
                                      cache, t, use_kernels=use_kernels)
        return cache, logits

    cache, logits = jax.lax.scan(body, cache, jnp.arange(P))
    last = logits[-1]                       # (B, 1, V)
    nxt = jnp.argmax(last[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return nxt, cache


def generate(params: Params, cfg: ModelConfig, prompts: jax.Array, *,
             max_new_tokens: int = 32, max_len: Optional[int] = None,
             memory: Optional[jax.Array] = None,
             use_kernels: bool = False) -> jax.Array:
    """Greedy generation. prompts: (B, P) -> (B, P + max_new_tokens).

    ``max_len`` (when given) is the cache depth and must cover the prompt
    plus every new token — a shallower cache would silently write decode
    steps past the cache depth and corrupt it, so it raises instead.
    """
    B, P = prompts.shape
    total = max_len or (P + max_new_tokens)
    if total < P + max_new_tokens:
        raise ValueError(
            f"max_len={total} is shallower than prompt ({P}) + "
            f"max_new_tokens ({max_new_tokens}) = {P + max_new_tokens}; "
            f"decode steps would write past the cache depth")
    mem_len = memory.shape[1] if memory is not None else 0
    cache = T.init_cache(cfg, B, total, memory_len=mem_len,
                         dtype=jnp.dtype(cfg.dtype))
    if memory is not None:
        cache = T.build_cross_cache(params, cfg, memory, cache)
    tok, cache = prefill(params, cfg, prompts, cache,
                         use_kernels=use_kernels)
    step = make_serve_step(cfg, use_kernels)

    def body(carry, i):
        tok, cache = carry
        nxt, cache = step(params, cache, tok, P + i)
        return (nxt, cache), tok[:, 0]

    (_, _), toks = jax.lax.scan(body, (tok, cache),
                                jnp.arange(max_new_tokens))
    return jnp.concatenate([prompts, toks.T], axis=1)
