"""Activation sharding hints (the MaxText "logical constraint" pattern).

GSPMD's propagation cannot by itself keep attention heads / MoE experts /
mamba channels sharded through reshapes and gathers, so the model code marks
the key activations with ``with_sharding_constraint``. Hints are no-ops when
no mesh is active (CPU smoke tests) or when a named logical axis is absent
from the ambient mesh.

Logical axes:
- "dp":    the batch axes — ("pod", "data") when present
- "model": tensor-parallel axis

Uneven dimensions (e.g. phi3's 40 heads on a 16-way model axis) are allowed —
GSPMD pads; the waste shows up in the roofline and is called out there.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax._src import mesh as _mesh_lib
from jax.sharding import PartitionSpec as P


def current_mesh():
    m = _mesh_lib.thread_resources.env.physical_mesh
    if m is None or m.empty:
        return None
    return m


def _resolve(mesh, axis):
    if axis is None:
        return None
    if axis == "dp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    if axis in mesh.axis_names:
        return axis
    return None


def model_axis_if(dim: int):
    """'model' when the ambient mesh has it AND it divides ``dim`` evenly
    (used where padded/uneven sharding would be wasteful, e.g. kv caches)."""
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    return "model" if dim % mesh.shape["model"] == 0 else None


def hint(x: jax.Array, *axes) -> jax.Array:
    """Constrain ``x`` with the given logical axes (None = unconstrained)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"hint rank mismatch: {axes} vs {x.shape}")
    spec = P(*[_resolve(mesh, a) for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)
