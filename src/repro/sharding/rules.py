"""Parameter / activation PartitionSpec rules (Megatron + FSDP hybrid).

Weights are sharded along their "model-parallel" dimension over the mesh's
``model`` axis (attention fused head dim, MLP hidden dim, MoE expert axis)
AND fully-sharded along a second dimension over the data axes (FSDP /
ZeRO-3 style) so trillion-parameter configs fit pod HBM. GSPMD inserts the
FSDP all-gathers.

Every rule degrades gracefully: an axis is only applied if the corresponding
dimension is divisible by the mesh axis size (otherwise that dimension is
replicated) — this is what makes e.g. qwen2's 60 experts or phi3's 40 heads
lower cleanly (the *fused* head*head_dim projections are always divisible).

Specs are derived by walking the parameter pytree's path strings, so any
new substrate that follows the naming conventions (wq/wk/wv/wo, w_gate/w_up/
w_down, in_proj/out_proj, embed/head) inherits correct sharding.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, fsdp_axes


def path_str(path) -> str:
    """Render a tree_map_with_path key path as the "a/b/c" strings the
    parameter rules (and :mod:`repro.train.parallel`) match against."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_path_str = path_str


def _fits(dim: int, mesh, axis) -> bool:
    """Is `dim` divisible by the (possibly tuple) mesh axis size?"""
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
    else:
        size = mesh.shape[axis]
    return dim % size == 0


def _spec(mesh, shape, *axes) -> P:
    """Build a PartitionSpec, dropping axes that don't divide evenly."""
    out = []
    for dim, ax in zip(shape, axes):
        out.append(ax if _fits(dim, mesh, ax) else None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# (path regex, axes per trailing dim) — longest-match wins; the leading
# stacked-layer dim of scanned body params is prepended automatically.
def _param_rule(path: str, shape: Tuple[int, ...], mesh, fsdp) -> P:
    ndim = len(shape)

    def spec(*axes):
        return _spec(mesh, shape, *axes)

    # --- embeddings / unembedding: (V, D) -> vocab on model, D fsdp
    if re.search(r"(^|/)(embed|head)$", path) and ndim == 2:
        return spec("model", fsdp)
    # --- norms, biases, small vectors: replicated
    if re.search(r"(norm|scale|bias|gamma|beta|dt_bias|(^|/)D$)", path):
        return P(*([None] * ndim))
    # --- MoE ---
    if "/ff/router" in path:
        return P(*([None] * ndim))
    if re.search(r"/ff/w_(gate|up)$", path) and ndim == 3:
        # (E, D, d_expert): expert-sharded (or ffn-sharded fallback)
        if _fits(shape[0], mesh, "model"):
            return spec("model", fsdp, None)
        return spec(None, fsdp, "model")
    if re.search(r"/ff/w_down$", path) and ndim == 3:
        if _fits(shape[0], mesh, "model"):
            return spec("model", None, fsdp)
        return spec(None, "model", fsdp)
    # --- dense mlp / shared expert: (D, F) and (F, D)
    if re.search(r"w_(gate|up)$", path) and ndim == 2:
        return spec(fsdp, "model")
    if re.search(r"w_down$", path) and ndim == 2:
        return spec("model", fsdp)
    # --- attention: fused (D, H*hd) / (H*hd, D)
    if re.search(r"w[qkv]$", path) and ndim == 2:
        return spec(fsdp, "model")
    if re.search(r"wo$", path) and ndim == 2:
        return spec("model", fsdp)
    # --- mamba ---
    if re.search(r"in_proj$", path):
        return spec(fsdp, "model")
    if re.search(r"out_proj$", path):
        return spec("model", fsdp)
    if re.search(r"conv_w$", path):
        return spec(None, "model")
    if re.search(r"x_proj$", path):
        return spec("model", None)
    if re.search(r"dt_proj$", path):
        return spec(None, "model")
    if re.search(r"A_log$", path):
        return spec("model", None)
    # --- vision head (paper models) and anything else: replicate
    return P(*([None] * ndim))


def param_specs(params_or_shapes: Any, mesh, cfg=None) -> Any:
    """PartitionSpec pytree matching the parameter pytree.

    Stacked body params (path contains ``stack/body``) get a leading None
    for the scan dimension.
    """
    fsdp = fsdp_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]

    def one(path, leaf):
        p = _path_str(path)
        shape = tuple(leaf.shape)
        stacked = "stack/body" in p or re.search(r"(^|/)body/", p)
        if stacked:
            inner = _param_rule(p, shape[1:], mesh, fsdp)
            return P(None, *inner)
        return _param_rule(p, shape, mesh, fsdp)

    return jax.tree_util.tree_map_with_path(one, params_or_shapes)


def param_shardings(params_or_shapes: Any, mesh, cfg=None) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_or_shapes, mesh, cfg))


# ---------------------------------------------------------------------------
# activation / batch / cache rules
# ---------------------------------------------------------------------------


def batch_spec(mesh, global_batch: int, ndim: int = 2) -> P:
    """Shard the batch dim over the data(+pod) axes when divisible."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    if not _fits(global_batch, mesh, dp):
        dp = None
    return P(dp, *([None] * (ndim - 1)))


def cache_specs(cache: Any, mesh, global_batch: int) -> Any:
    """KV/SSM/cross cache specs: batch over data axes; kv cache prefers
    kv-head sharding over 'model' (update_slice stays shard-local — the
    seq-sharded variant forces an SPMD full rematerialization on every token,
    see EXPERIMENTS.md §Perf); falls back to sharding the cache sequence
    when kv_heads doesn't divide (decode softmax reduces over it with an
    all-reduce). Mamba state shards d_inner over 'model'."""
    dp = dp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]
    b_ax = dp if _fits(global_batch, mesh, dp) else None

    def one(path, leaf):
        p = _path_str(path)
        shape = tuple(leaf.shape)
        # strip the stacked body dim
        stacked = "body" in p.split("/")
        core = shape[1:] if stacked else shape
        if p.endswith("/h"):          # (B, d_inner, d_state)
            inner = _spec(mesh, core, b_ax, "model", None)
        elif p.endswith("/conv"):     # (B, k-1, d_inner)
            inner = _spec(mesh, core, b_ax, None, "model")
        elif "cross_" in p:           # (B, mem, kv, hd)
            inner = _spec(mesh, core, b_ax, None, "model", None)
        elif re.search(r"/(kh|vh)$", p):  # head-major k/v: (B, kv, S, hd)
            if _fits(core[1], mesh, "model"):
                inner = _spec(mesh, core, b_ax, "model", None, None)
            else:
                inner = _spec(mesh, core, b_ax, None, "model", None)
        elif re.search(r"/(kp|vp)$", p):  # page pool: (pages, kv, ps, hd)
            # pages are row-agnostic (any row's block may land on any
            # page), so the pool cannot shard over the batch axes — shard
            # kv heads over 'model' when divisible, else replicate (a
            # seq-sharded page would split the kernel's per-page gather).
            if _fits(core[1], mesh, "model"):
                inner = _spec(mesh, core, None, "model", None, None)
            else:
                inner = P(*([None] * len(core)))
        elif re.search(r"/(ks|vs)$", p):  # int8 pool scales: (pages, kv, ps)
            # co-sharded with the kp/vp pool they dequantize: kv heads on
            # 'model' when divisible, else replicated.
            if _fits(core[1], mesh, "model"):
                inner = _spec(mesh, core, None, "model", None)
            else:
                inner = P(*([None] * len(core)))
        elif p.endswith("/pt"):       # block table: (B, n_blocks) int32
            inner = _spec(mesh, core, b_ax, None)
        else:                         # k/v: (B, S, kv, hd)
            if _fits(core[2], mesh, "model"):
                inner = _spec(mesh, core, b_ax, None, "model", None)
            else:
                inner = _spec(mesh, core, b_ax, "model", None, None)
        if stacked:
            return P(None, *inner)
        return inner

    return jax.tree_util.tree_map_with_path(one, cache)


def to_shardings(spec_tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda s: isinstance(s, P))
