from repro.train.trainer import (make_lm_eval_step, make_lm_train_step,
                                 make_vision_eval, make_vision_train_step,
                                 train_vision)

__all__ = [
    "make_lm_eval_step", "make_lm_train_step", "make_vision_eval",
    "make_vision_train_step", "train_vision",
]
