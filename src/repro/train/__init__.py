from repro.train.data_parallel import (dp_gbn_forward,
                                       make_dp_vision_train_step,
                                       mesh_compatible)
from repro.train.parallel import (make_mesh_lm_train_step,
                                  make_mesh_vision_train_step,
                                  mesh_param_specs)
from repro.train.trainer import (make_lm_eval_step, make_lm_train_step,
                                 make_vision_eval, make_vision_loss_fn,
                                 make_vision_train_step, train_lm,
                                 train_vision)

__all__ = [
    "dp_gbn_forward", "make_dp_vision_train_step", "mesh_compatible",
    "make_mesh_lm_train_step", "make_mesh_vision_train_step",
    "mesh_param_specs",
    "make_lm_eval_step", "make_lm_train_step", "make_vision_eval",
    "make_vision_loss_fn", "make_vision_train_step", "train_lm",
    "train_vision",
]
