"""Sharded data-parallel training: the paper's ghost batches made literal
on hardware.

Hoffer et al. compute normalization statistics over small "ghost" slices of
the large batch — and note this is exactly what a data-parallel cluster does
for free, since each device only ever sees its own shard. This module maps
that observation onto a 1-D ``("data",)`` mesh with ``shard_map``:

- the batch is sharded over the mesh; parameters, BN running state, and the
  optimizer state are replicated;
- every device evaluates the SAME vision loss as the single-device trainer
  (:func:`repro.train.trainer.make_vision_loss_fn`) on its local shard, so
  the ghost-batch statistics that NORMALIZE activations are per-device by
  construction and never cross the wire;
- cross-device traffic per step is one gradient ``pmean`` plus two cheap
  (C,)-sized ones — the running-EMA state (averaged so the replicated
  inference statistics stay identical everywhere) and the scalar metrics —
  after which the replicated SGD update keeps every device's parameters
  bit-identical.

Because a local shard of ``B/ndev`` rows split into ghost batches of
``|B_S|`` rows partitions the global batch exactly like the single-device
GBN step does, the data-parallel step's loss and gradients MATCH the
single-device step (same ghost boundaries, mean-of-means over equal shards)
— only the running-statistics EMA differs, since each device folds its own
ghosts sequentially before the cross-device average (tested in
``tests/test_data_parallel.py``).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.paper_models import VisionModelConfig
from repro.core.compat import shard_map
from repro.core.large_batch import LargeBatchConfig
from repro.core.regime import Regime
from repro.optim import sgd
from repro.train.trainer import make_vision_loss_fn

Params = Any


def _pmean_state(state: Params, axis: str) -> Params:
    """Average the BN running stats across devices so the replicated state
    stays identical everywhere; boolean flags ('initialized') are already
    replicated and cannot be pmean'd."""
    return jax.tree.map(
        lambda s: s if s.dtype == jnp.bool_ else jax.lax.pmean(s, axis),
        state)


def mesh_compatible(lb: LargeBatchConfig, mesh, *, axis: str = "data",
                    batch_size: int = 0) -> bool:
    """True when a batch can shard evenly over ``mesh``: the (possibly
    schedule-overridden) batch splits across devices AND each device's local
    shard still splits into whole ghost batches — the invariant that makes
    the DP step's statistics match the single-device GBN step. The sweep
    runner uses this to decide per run whether to fan over the mesh."""
    b = batch_size or lb.batch_size
    ndev = mesh.shape[axis]
    if b % ndev:
        return False
    local = b // ndev
    return (not lb.use_gbn) or local % lb.ghost_batch_size == 0


def make_dp_vision_train_step(model_apply: Callable, cfg: VisionModelConfig,
                              lb: LargeBatchConfig, regime: Regime, mesh, *,
                              weight_decay: float = 5e-4,
                              use_kernels: bool = False,
                              axis: str = "data") -> Callable:
    """shard_map twin of :func:`repro.train.trainer.make_vision_train_step`.

    Same signature as the single-device step —
    (params, bn_state, opt_state, x, y, step, rng) ->
    (params, bn_state, opt_state, metrics) — with x, y sharded over ``axis``
    and everything else replicated. Ghost statistics stay per-device; the
    collectives are the gradient pmean plus the small EMA/metric averages.
    """
    sigma = lb.effective_noise_sigma()
    loss_fn = make_vision_loss_fn(model_apply, cfg, lb,
                                  use_kernels=use_kernels)

    def local_step(params: Params, bn_state: Params,
                   opt_state: sgd.SGDState, x: jax.Array, y: jax.Array,
                   step: jax.Array, rng: jax.Array):
        # local shard, local ghost statistics — Alg. 1 on this device only
        (loss, (new_state, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, bn_state, x, y)
        # grads (+ EMA state and scalar metrics) cross devices; the
        # normalization statistics never do
        grads = jax.lax.pmean(grads, axis)
        loss = jax.lax.pmean(loss, axis)
        acc = jax.lax.pmean(acc, axis)
        new_state = _pmean_state(new_state, axis)
        lr = regime.lr_at(step)
        params2, opt_state2, m = sgd.update(
            grads, opt_state, params, lr=lr, momentum=lb.momentum,
            weight_decay=weight_decay, grad_clip=lb.grad_clip,
            noise_sigma=sigma, rng=rng)
        return params2, new_state, opt_state2, {
            "loss": loss, "acc": acc, "lr": lr, **m}

    rep = P()
    data = P(axis)
    return shard_map(local_step, mesh=mesh,
                     in_specs=(rep, rep, rep, data, data, rep, rep),
                     out_specs=(rep, rep, rep, rep),
                     check_vma=False)


def dp_gbn_forward(x: jax.Array, gamma: jax.Array, beta: jax.Array, mesh, *,
                   ghost_batch_size: int, eps: float = 1e-5,
                   use_kernels: bool = False, axis: str = "data"
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Data-parallel GBN forward exposing the per-device ghost statistics.

    x: (B, ..., C) sharded over ``axis``; gamma/beta: (C,) replicated.
    Returns (y (B, ..., C) sharded, mu, var) where mu/var have shape
    (ndev * G_local, C), stacked device-major — literally one row of
    statistics per ghost batch per device, none of them synchronized.
    """
    C = x.shape[-1]
    ndev = mesh.shape[axis]
    if x.shape[0] % ndev:
        raise ValueError(f"batch {x.shape[0]} not divisible by {ndev} devices")
    if (x.shape[0] // ndev) % ghost_batch_size:
        raise ValueError(
            f"local batch {x.shape[0] // ndev} not divisible by "
            f"ghost_batch_size={ghost_batch_size}")
    dt = x.dtype

    def local(xb, g, b):
        G = xb.shape[0] // ghost_batch_size
        # fold spatial/feature dims into the row axis per ghost (NHWC convs
        # reduce over N, H, W per channel), matching core.gbn.gbn_apply
        xg = xb.astype(jnp.float32).reshape(G, -1, C)
        if use_kernels:
            from repro.kernels import ops as kops
            y, mu, var = kops.gbn_forward(xg, g, b, eps=eps)
        else:
            from repro.kernels import ref
            y, mu, var = ref.gbn_ref(xg, g, b, eps=eps)
        return y.reshape(xb.shape).astype(dt), mu, var

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P(), P()),
                   out_specs=(P(axis), P(axis), P(axis)),
                   check_vma=False)
    return fn(x, gamma.astype(jnp.float32), beta.astype(jnp.float32))
