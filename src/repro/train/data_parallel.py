"""Sharded data-parallel training: the paper's ghost batches made literal
on hardware.

Hoffer et al. compute normalization statistics over small "ghost" slices of
the large batch — and note this is exactly what a data-parallel cluster does
for free, since each device only ever sees its own shard. This module maps
that observation onto a mesh with ``shard_map`` (historically the 1-D
``("data",)`` mesh; the general data x model implementation now lives in
:mod:`repro.train.parallel` and this module delegates to it):

- the batch is sharded over the mesh; parameters, BN running state, and the
  optimizer state are replicated;
- every device evaluates the SAME vision loss as the single-device trainer
  (:func:`repro.train.trainer.make_vision_loss_fn`) on its local shard, so
  the ghost-batch statistics that NORMALIZE activations are per-device by
  construction and never cross the wire;
- cross-device traffic per step is one gradient ``pmean`` plus two cheap
  (C,)-sized ones — the running-EMA state (averaged so the replicated
  inference statistics stay identical everywhere) and the scalar metrics —
  after which the replicated SGD update keeps every device's parameters
  bit-identical.

Because a local shard of ``B/ndev`` rows split into ghost batches of
``|B_S|`` rows partitions the global batch exactly like the single-device
GBN step does, the data-parallel step's loss and gradients MATCH the
single-device step (same ghost boundaries, mean-of-means over equal shards)
— only the running-statistics EMA differs, since each device folds its own
ghosts sequentially before the cross-device average (tested in
``tests/test_data_parallel.py``).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.paper_models import VisionModelConfig
from repro.core.compat import shard_map
from repro.core.large_batch import LargeBatchConfig
from repro.core.regime import Regime
from repro.launch.mesh import dp_axes
from repro.train import parallel

Params = Any


def _check_axis(axis: str, mesh) -> None:
    """The kept-for-compat ``axis`` kwarg must name a dp axis of ``mesh`` —
    silently ignoring a custom name would skip every pmean (the dp axes come
    from the mesh itself now, see launch.mesh.dp_axes)."""
    if axis not in dp_axes(mesh):
        raise ValueError(
            f"axis {axis!r} is not a data-parallel axis of mesh "
            f"{tuple(mesh.axis_names)}; the batch shards over "
            f"{dp_axes(mesh)}")


def mesh_compatible(lb: LargeBatchConfig, mesh, *, axis: str = "data",
                    batch_size: int = 0,
                    cfg: Optional[ModelConfig] = None) -> bool:
    """True when a batch can shard evenly over ``mesh`` — the general 2-D
    geometry gate of :func:`repro.train.parallel.mesh_compatible` (batch
    over the dp axes, whole ghost batches per dp shard, experts over the
    model axis). ``axis`` is kept for 1-D callers and must name a mesh dp
    axis."""
    _check_axis(axis, mesh)
    return parallel.mesh_compatible(lb, mesh, batch_size=batch_size, cfg=cfg)


def make_dp_vision_train_step(model_apply: Callable, cfg: VisionModelConfig,
                              lb: LargeBatchConfig, regime: Regime, mesh, *,
                              weight_decay: float = 5e-4,
                              use_kernels: bool = False,
                              axis: str = "data") -> Callable:
    """shard_map twin of :func:`repro.train.trainer.make_vision_train_step`.

    Same signature as the single-device step —
    (params, bn_state, opt_state, x, y, step, rng) ->
    (params, bn_state, opt_state, metrics) — with x, y sharded over the dp
    axes and everything else replicated. Ghost statistics stay per-device;
    the collectives are the gradient pmean plus the small EMA/metric
    averages. Delegates to the unified mesh layer
    (:func:`repro.train.parallel.make_mesh_vision_train_step`), which
    accepts any ``(pod?, data, model?)`` mesh — this 1-D-era name is kept
    for its call sites.
    """
    _check_axis(axis, mesh)
    return parallel.make_mesh_vision_train_step(
        model_apply, cfg, lb, regime, mesh, weight_decay=weight_decay,
        use_kernels=use_kernels)


def dp_gbn_forward(x: jax.Array, gamma: jax.Array, beta: jax.Array, mesh, *,
                   ghost_batch_size: int, eps: float = 1e-5,
                   use_kernels: bool = False, axis: str = "data"
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Data-parallel GBN forward exposing the per-device ghost statistics.

    x: (B, ..., C) sharded over ``axis``; gamma/beta: (C,) replicated.
    Returns (y (B, ..., C) sharded, mu, var) where mu/var have shape
    (ndev * G_local, C), stacked device-major — literally one row of
    statistics per ghost batch per device, none of them synchronized.
    """
    C = x.shape[-1]
    ndev = mesh.shape[axis]
    if x.shape[0] % ndev:
        raise ValueError(f"batch {x.shape[0]} not divisible by {ndev} devices")
    if (x.shape[0] // ndev) % ghost_batch_size:
        raise ValueError(
            f"local batch {x.shape[0] // ndev} not divisible by "
            f"ghost_batch_size={ghost_batch_size}")
    dt = x.dtype

    def local(xb, g, b):
        G = xb.shape[0] // ghost_batch_size
        # fold spatial/feature dims into the row axis per ghost (NHWC convs
        # reduce over N, H, W per channel), matching core.gbn.gbn_apply
        xg = xb.astype(jnp.float32).reshape(G, -1, C)
        if use_kernels:
            from repro.kernels import ops as kops
            y, mu, var = kops.gbn_forward(xg, g, b, eps=eps)
        else:
            from repro.kernels import ref
            y, mu, var = ref.gbn_ref(xg, g, b, eps=eps)
        return y.reshape(xb.shape).astype(dt), mu, var

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P(), P()),
                   out_specs=(P(axis), P(axis), P(axis)),
                   check_vma=False)
    return fn(x, gamma.astype(jnp.float32), beta.astype(jnp.float32))
