"""Unified 2-D parallelism: one shard_map layer composing data x model.

The repo grew three disjoint parallelism islands — the 1-D ``("data",)``
shard_map vision trainer (:mod:`repro.train.data_parallel`), the
expert-parallel MoE dispatch assuming a ``"model"`` axis
(:mod:`repro.core.expert_parallel`), and the pjit-rules LM launcher
(:mod:`repro.launch.train` + :mod:`repro.sharding.rules`). This module
collapses them into one production path over any mesh from
:mod:`repro.launch.mesh` — ``(pod?, data, model)`` or any degenerate slice:

- the global batch shards over ``mesh.dp_axes`` (pod x data);
- MoE expert weights shard over ``"model"`` — the expert axis when it
  divides, else each expert's hidden dim — with the spec derived from the
  same :func:`repro.sharding.rules.param_specs` rules the pjit launcher
  lowers with (restricted to the axes manual SPMD can honor, see
  :func:`mesh_param_specs`);
- everything else (non-expert params, optimizer state, BN state) is
  replicated, and the per-step collectives are: the gradient ``pmean`` over
  the dp axes ONLY, one combine ``psum`` over ``"model"`` per MoE layer
  (:func:`repro.core.expert_parallel.ep_manual_combine` composes inside the
  same shard_map region), a scalar psum for the corrected grad-clip norm,
  and the small metric/EMA averages.

Ghost statistics (the paper's central device-local quantity) never cross
the wire: each dp shard normalizes — and draws ghost gradient noise — from
its own slice, exactly as in the 1-D trainer.

Gradient exactness: the expert-partial region is fenced with the adjoint
pair ``region_in``/``region_out`` (see expert_parallel.py), so the sharded
step's loss, gradients, and parameter trajectory MATCH the single-device
step (tests/test_parallel_2d.py asserts multi-step equality for dense,
expert-sharded, and ffn-sharded configs).
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import expert_parallel as EP
from repro.core.clipping import clip_by_global_norm
from repro.core.compat import shard_map
from repro.core.large_batch import LargeBatchConfig
from repro.core.regime import Regime
from repro.launch import mesh as mesh_lib
from repro.models import transformer as T
from repro.optim import sgd
from repro.sharding import rules

Params = Any

_EXPERT_RE = re.compile(r"/ff/w_(gate|up|down)$")


# ---------------------------------------------------------------------------
# spec derivation
# ---------------------------------------------------------------------------


def mesh_param_specs(params_or_shapes: Params, mesh) -> Params:
    """shard_map in/out specs for the parameter pytree: the
    :func:`repro.sharding.rules.param_specs` rules restricted to what a
    manual (shard_map) region can honor.

    Only the MoE expert tensors keep their ``"model"`` entry — their local
    math + combine psum live in expert_parallel.py. Attention/MLP/mamba
    weights, which the pjit path Megatron-shards via GSPMD propagation, are
    replicated here (manual tensor parallelism for them would need psums the
    model code doesn't carry), and the FSDP/data axes are dropped — the
    unified layer is pure DP outside the experts.
    """
    if "model" not in mesh.axis_names:
        # pure-dp mesh (e.g. the 1-D ("data",) mesh): everything replicates;
        # the pjit rules would KeyError on their "model" lookups.
        return jax.tree.map(lambda l: P(*([None] * len(l.shape))),
                            params_or_shapes)
    full = rules.param_specs(params_or_shapes, mesh)

    def one(path, leaf, spec):
        p = rules.path_str(path)
        stacked = "stack/body" in p or re.search(r"(^|/)body/", p)
        # expert tensors are (E, d, f) — rank 3 plus the scanned body dim.
        # The dense-MLP weights share the w_gate/w_up/w_down names at rank
        # 2: GSPMD Megatron-shards those, manual SPMD must replicate them.
        keep = (bool(_EXPERT_RE.search(p))
                and len(leaf.shape) - (1 if stacked else 0) == 3)
        return P(*[e if (keep and e == "model") else None for e in spec])

    return jax.tree_util.tree_map_with_path(one, params_or_shapes, full)


def mesh_compatible(lb: LargeBatchConfig, mesh, *, batch_size: int = 0,
                    cfg: Optional[ModelConfig] = None) -> bool:
    """True when a run's geometry fits ``mesh``:

    - the (possibly schedule-overridden) batch splits evenly over the dp
      axes, and each dp shard's slice still splits into whole ghost batches
      (the invariant that keeps sharded statistics identical to the
      single-device GBN step);
    - with a >1 model axis and an MoE ``cfg``, the experts shard — either
      the expert axis or each expert's hidden dim divides the model size.

    The sweep runner uses this to decide per run whether (and over which
    topology) to fan out.
    """
    b = batch_size or lb.batch_size
    nd = mesh_lib.dp_size(mesh)
    if nd == 0 or b % nd:
        return False
    local = b // nd
    if lb.use_gbn and local % lb.ghost_batch_size:
        return False
    msize = mesh_lib.axis_size(mesh, "model")
    if msize > 1 and cfg is not None and getattr(cfg, "moe", None) is not None:
        m = cfg.moe
        if m.n_experts % msize and m.d_expert % msize:
            return False
    return True


# ---------------------------------------------------------------------------
# LM train step (data x model)
# ---------------------------------------------------------------------------


def _sharded_global_norm(grads: Params, pspecs: Params,
                         model_axis: Optional[str]) -> jax.Array:
    """Global grad norm inside the region: leaves sharded over the model
    axis contribute their local sum-of-squares through one scalar psum;
    replicated leaves (identical on every model shard) are counted once."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(pspecs)
    sq_rep = jnp.zeros((), jnp.float32)
    sq_sh = jnp.zeros((), jnp.float32)
    for g, s in zip(flat_g, flat_s):
        ss = jnp.sum(jnp.square(g.astype(jnp.float32)))
        if model_axis is not None and any(e == "model" for e in s):
            sq_sh = sq_sh + ss
        else:
            sq_rep = sq_rep + ss
    if model_axis is not None:
        sq_sh = jax.lax.psum(sq_sh, model_axis)
    return jnp.sqrt(sq_rep + sq_sh)


def make_mesh_lm_train_step(cfg: ModelConfig, lb: LargeBatchConfig,
                            regime: Regime, mesh, params: Params, *,
                            weight_decay: float = 0.0,
                            use_kernels: bool = False,
                            momentum_dtype: str = "float32",
                            remat: bool = False,
                            seq_parallel: bool = False,
                            ce_chunk: int = 0) -> Callable:
    """The LM train step sharded data x model over ``mesh``.

    Same signature as :func:`repro.train.trainer.make_lm_train_step`'s
    result — (params, opt_state, batch, step, rng) -> (params, opt_state,
    metrics) — with the batch sharded over the dp axes, expert weights over
    ``"model"``, and everything else replicated. ``params`` provides the
    pytree/shapes the in/out specs are derived from. Differentiates through
    the Pallas kernels (``use_kernels=True``) exactly like the unsharded
    step; gradients are ``pmean`` ed over the dp axes only.

    Note: with ``lb.ghost_noise > 0`` each model shard draws its noise for
    its local expert slice, so the realization differs from the unsharded
    step (the distribution does not); run equivalence tests noise-free.
    """
    if momentum_dtype == "int8":
        raise NotImplementedError(
            "int8 momentum blocks the trailing dim; its quantized buffers "
            "need their own specs — use the pjit path or float32 momentum")
    sigma = lb.effective_noise_sigma()
    dp = mesh_lib.dp_axes(mesh)
    dp_arg = mesh_lib.dp_spec_entry(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    msize = mesh_lib.axis_size(mesh, "model")
    pspecs = mesh_param_specs(params, mesh)
    rep = P()
    opt_specs = sgd.SGDState(momentum=pspecs, step=rep)

    def local_step(params: Params, opt_state: sgd.SGDState,
                   batch: Dict[str, jax.Array], step: jax.Array,
                   rng: jax.Array):
        def loss_fn(p):
            with EP.manual_mode(model_ax, msize, dp):
                return T.lm_loss(p, cfg, batch, use_kernels=use_kernels,
                                 remat=remat, seq_parallel=seq_parallel,
                                 ce_chunk=ce_chunk)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if dp:
            grads = jax.lax.pmean(grads, dp)
            loss = jax.lax.pmean(loss, dp)
            metrics = jax.lax.pmean(metrics, dp)
        clip_metrics: Dict[str, jax.Array] = {}
        if lb.grad_clip and lb.grad_clip > 0:
            norm = _sharded_global_norm(grads, pspecs, model_ax)
            grads, gnorm = clip_by_global_norm(grads, lb.grad_clip, norm=norm)
            clip_metrics["grad_norm"] = gnorm
        lr = regime.lr_at(step)
        params2, opt_state2, opt_metrics = sgd.update(
            grads, opt_state, params,
            lr=lr, momentum=lb.momentum, nesterov=lb.nesterov,
            weight_decay=weight_decay, grad_clip=0.0,
            noise_sigma=sigma, rng=rng, momentum_dtype=momentum_dtype)
        metrics = {"loss": loss, "lr": lr, **metrics, **opt_metrics,
                   **clip_metrics}
        return params2, opt_state2, metrics

    return shard_map(local_step, mesh=mesh,
                     in_specs=(pspecs, opt_specs, P(dp_arg), rep, rep),
                     out_specs=(pspecs, opt_specs, rep),
                     check_vma=False)


# ---------------------------------------------------------------------------
# vision train step (dp over any mesh; model axis replicates)
# ---------------------------------------------------------------------------


def _pmean_state(state: Params, axes) -> Params:
    """Average the BN running stats across dp shards so the replicated state
    stays identical everywhere; boolean flags ('initialized') are already
    replicated and cannot be pmean'd."""
    return jax.tree.map(
        lambda s: s if s.dtype == jnp.bool_ else jax.lax.pmean(s, axes),
        state)


def make_mesh_vision_train_step(model_apply: Callable, cfg, lb:
                                LargeBatchConfig, regime: Regime, mesh, *,
                                weight_decay: float = 5e-4,
                                use_kernels: bool = False) -> Callable:
    """shard_map twin of :func:`repro.train.trainer.make_vision_train_step`
    over ANY production mesh: x, y shard over the dp axes; params, BN state,
    and optimizer state are replicated (vision models carry no
    model-sharded weights — a model axis just replicates the local step).
    Ghost statistics stay per-dp-shard; the collectives are the gradient
    pmean plus the small EMA/metric averages, all over the dp axes only."""
    from repro.train.trainer import make_vision_loss_fn
    sigma = lb.effective_noise_sigma()
    loss_fn = make_vision_loss_fn(model_apply, cfg, lb,
                                  use_kernels=use_kernels)
    dp = mesh_lib.dp_axes(mesh)
    dp_arg = mesh_lib.dp_spec_entry(mesh)

    def local_step(params: Params, bn_state: Params,
                   opt_state: sgd.SGDState, x: jax.Array, y: jax.Array,
                   step: jax.Array, rng: jax.Array):
        # local shard, local ghost statistics — Alg. 1 on this device only
        (loss, (new_state, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, bn_state, x, y)
        # grads (+ EMA state and scalar metrics) cross devices; the
        # normalization statistics never do
        if dp:
            grads = jax.lax.pmean(grads, dp)
            loss = jax.lax.pmean(loss, dp)
            acc = jax.lax.pmean(acc, dp)
            new_state = _pmean_state(new_state, dp)
        lr = regime.lr_at(step)
        params2, opt_state2, m = sgd.update(
            grads, opt_state, params, lr=lr, momentum=lb.momentum,
            weight_decay=weight_decay, grad_clip=lb.grad_clip,
            noise_sigma=sigma, rng=rng)
        return params2, new_state, opt_state2, {
            "loss": loss, "acc": acc, "lr": lr, **m}

    rep = P()
    data = P(dp_arg)
    return shard_map(local_step, mesh=mesh,
                     in_specs=(rep, rep, rep, data, data, rep, rep),
                     out_specs=(rep, rep, rep, rep),
                     check_vma=False)
