"""Unified 3-D parallelism: one shard_map layer composing pod x data x model.

The repo grew three disjoint parallelism islands — the 1-D ``("data",)``
shard_map vision trainer (:mod:`repro.train.data_parallel`), the
expert-parallel MoE dispatch assuming a ``"model"`` axis
(:mod:`repro.core.expert_parallel`), and the pjit-rules LM launcher
(:mod:`repro.launch.train` + :mod:`repro.sharding.rules`). This module
collapses them into one production path over any mesh from
:mod:`repro.launch.mesh` — ``(pod?, data, model)`` or any degenerate slice:

- the global batch shards over ``mesh.dp_axes`` (pod x data);
- MoE expert weights shard over ``"model"`` — the expert axis when it
  divides, else each expert's hidden dim — with the spec derived from the
  same :func:`repro.sharding.rules.param_specs` rules the pjit launcher
  lowers with (restricted to the axes manual SPMD can honor, see
  :func:`mesh_param_specs`);
- ``tp=True`` additionally Megatron-shards the attention (head-split
  qkv/o: column-parallel in, row-parallel out) and dense-MLP weights over
  ``"model"`` — the model code detects the local slice by shape and fences
  each sublayer with the expert_parallel adjoint pair (see
  :func:`repro.models.blocks._tp_axis`), so the only extra collective is
  one output psum per fenced sublayer, exactly Megatron's count;
- ``fsdp=True`` shards every remaining large parameter — and with it the
  optimizer moments — over the dp axes: the step all-gathers each such
  leaf on entry to the loss (autodiff transposes the gather into the
  reduce-scatter, so gradients come back dp-sharded), and the optimizer
  update runs shard-local (both optimizers here are elementwise per leaf),
  cutting per-device param+state memory by ~dp_size;
- everything else stays replicated, gradients of replicated leaves are
  ``pmean`` ed over the dp axes, and grad-clip's global norm is assembled
  from per-group psums (:func:`_sharded_global_norm`).

Ghost statistics (the paper's central device-local quantity) never cross
the wire: each dp shard normalizes — and draws ghost gradient noise — from
its own slice, exactly as in the 1-D trainer.

Gradient exactness: every partial-sum region is fenced with the adjoint
pair ``region_in``/``region_out`` (see expert_parallel.py), so the sharded
step's loss, gradients, and parameter trajectory MATCH the single-device
step (tests/test_parallel_2d.py asserts multi-step equality for dense,
expert-sharded, ffn-sharded, Megatron-TP, and FSDP configs).
"""
from __future__ import annotations

import re
from types import SimpleNamespace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import expert_parallel as EP
from repro.core.clipping import clip_by_global_norm
from repro.core.compat import shard_map
from repro.core.large_batch import LargeBatchConfig
from repro.core.regime import Regime
from repro.launch import mesh as mesh_lib
from repro.launch.mesh import MODEL_AXIS
from repro.models import transformer as T
from repro.optim import adam, sgd
from repro.sharding import rules

Params = Any

_EXPERT_RE = re.compile(r"/ff/w_(gate|up|down)$")
_TP_ATTN_RE = re.compile(r"/mixer/w[qkvo]$")


# ---------------------------------------------------------------------------
# spec derivation
# ---------------------------------------------------------------------------


def _spec_axes(spec) -> Tuple[str, ...]:
    """All mesh axis names a spec shards over (tuples flattened)."""
    axes = []
    for e in spec:
        if e is None:
            continue
        axes.extend(e if isinstance(e, tuple) else (e,))
    return tuple(axes)


def _fsdp_entry(spec) -> Optional[Tuple[int, Tuple[str, ...]]]:
    """(dim, dp-axes) of a spec's FSDP entry — the first entry naming
    non-model axes — or None for TP-only / replicated leaves."""
    for i, e in enumerate(spec):
        if e is None or e == MODEL_AXIS:
            continue
        return i, (e if isinstance(e, tuple) else (e,))
    return None


def _tree_with_specs(fn, tree: Params, specs: Params) -> Params:
    """tree_map over (leaf, spec) pairs. PartitionSpec subclasses tuple, so
    a plain jax.tree.map would flatten INTO the specs — flatten_up_to keeps
    them opaque."""
    flat, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(specs)
    return treedef.unflatten([fn(l, s) for l, s in zip(flat, flat_s)])


def mesh_param_specs(params_or_shapes: Params, mesh, *,
                     cfg: Optional[ModelConfig] = None,
                     tp: bool = False, fsdp: bool = False) -> Params:
    """shard_map in/out specs for the parameter pytree: the
    :func:`repro.sharding.rules.param_specs` rules restricted to what a
    manual (shard_map) region can honor.

    Default (``tp=fsdp=False``): only the MoE expert tensors keep their
    ``"model"`` entry — their local math + combine psum live in
    expert_parallel.py — and everything else replicates.

    ``tp=True`` (requires ``cfg``) also keeps ``"model"`` on the Megatron
    targets the fenced model code handles: rank-2 attention projections
    (``/mixer/w[qkvo]``, gated on BOTH head counts dividing the model size
    so q and kv slices stay aligned) and rank-2 dense-MLP weights
    (``/ff/w_(gate|up|down)``, gated on ``d_ff`` dividing). Embedding /
    lm-head stay replicated — vocab-parallel would need a fenced
    cross-entropy the model code doesn't carry.

    ``fsdp=True`` keeps the rules' dp-axes entries wherever they landed
    (large rank-2+ tensors whose dim divides), marking those leaves for the
    train step's gather-on-entry / reduce-scatter-on-grad path. Works on
    meshes without a ``"model"`` axis too (pure-dp FSDP).
    """
    if tp and cfg is None:
        raise ValueError("tp=True needs cfg to gate the head/ff splits")
    has_model = MODEL_AXIS in mesh.axis_names
    if not has_model and not fsdp:
        # pure-dp mesh (e.g. the 1-D ("data",) mesh): everything replicates;
        # the pjit rules would KeyError on their "model" lookups.
        return jax.tree.map(lambda l: P(*([None] * len(l.shape))),
                            params_or_shapes)
    rules_mesh = mesh
    if not has_model:
        # give the rules a model=1 view of the mesh; every "model" entry
        # they produce is dropped below.
        rules_mesh = SimpleNamespace(
            axis_names=tuple(mesh.axis_names) + (MODEL_AXIS,),
            shape={**dict(mesh.shape), MODEL_AXIS: 1})
    full = rules.param_specs(params_or_shapes, rules_mesh)
    msize = mesh_lib.axis_size(mesh, MODEL_AXIS)

    def one(path, leaf, spec):
        p = rules.path_str(path)
        stacked = "stack/body" in p or re.search(r"(^|/)body/", p)
        rank = len(leaf.shape) - (1 if stacked else 0)
        # expert tensors are (E, d, f) — rank 3 plus the scanned body dim.
        keep_model = bool(_EXPERT_RE.search(p)) and rank == 3
        if tp and has_model and msize > 1 and rank == 2:
            if _TP_ATTN_RE.search(p):
                keep_model = (cfg.n_heads % msize == 0
                              and cfg.n_kv_heads % msize == 0)
            elif _EXPERT_RE.search(p):
                keep_model = cfg.d_ff % msize == 0
        def ent(e):
            if e is None:
                return None
            if e == MODEL_AXIS or (isinstance(e, tuple) and MODEL_AXIS in e):
                return e if (keep_model and has_model) else None
            return e if fsdp else None
        return P(*[ent(e) for e in spec])

    return jax.tree_util.tree_map_with_path(one, params_or_shapes, full)


def mesh_compatible(lb: LargeBatchConfig, mesh, *, batch_size: int = 0,
                    cfg: Optional[ModelConfig] = None) -> bool:
    """True when a run's geometry fits ``mesh``:

    - the (possibly schedule-overridden) batch splits evenly over the dp
      axes, and each dp shard's slice still splits into whole ghost batches
      (the invariant that keeps sharded statistics identical to the
      single-device GBN step);
    - with a >1 model axis and an MoE ``cfg``, the experts shard — either
      the expert axis or each expert's hidden dim divides the model size.

    The sweep runner uses this to decide per run whether (and over which
    topology) to fan out.
    """
    b = batch_size or lb.batch_size
    nd = mesh_lib.dp_size(mesh)
    if nd == 0 or b % nd:
        return False
    local = b // nd
    if lb.use_gbn and local % lb.ghost_batch_size:
        return False
    msize = mesh_lib.axis_size(mesh, MODEL_AXIS)
    if msize > 1 and cfg is not None and getattr(cfg, "moe", None) is not None:
        m = cfg.moe
        if m.n_experts % msize and m.d_expert % msize:
            return False
    return True


# ---------------------------------------------------------------------------
# LM train step (data x model)
# ---------------------------------------------------------------------------


def _sharded_global_norm(grads: Params, pspecs: Params) -> jax.Array:
    """Global grad norm inside the region: leaves sharded over some set of
    mesh axes (model for TP/experts, dp axes for FSDP, both for TP+FSDP)
    contribute their local sum-of-squares through one scalar psum per
    distinct axis-set; replicated leaves are counted once."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(pspecs)
    groups: Dict[Tuple[str, ...], jax.Array] = {}
    for g, s in zip(flat_g, flat_s):
        axes = tuple(sorted(_spec_axes(s)))
        ss = jnp.sum(jnp.square(g.astype(jnp.float32)))
        groups[axes] = groups.get(axes, jnp.zeros((), jnp.float32)) + ss
    total = jnp.zeros((), jnp.float32)
    for axes, ss in groups.items():
        total = total + (jax.lax.psum(ss, axes) if axes else ss)
    return jnp.sqrt(total)


def make_mesh_lm_train_step(cfg: ModelConfig, lb: LargeBatchConfig,
                            regime: Regime, mesh, params: Params, *,
                            weight_decay: float = 0.0,
                            use_kernels: bool = False,
                            momentum_dtype: str = "float32",
                            remat: bool = False,
                            seq_parallel: bool = False,
                            ce_chunk: int = 0,
                            tp: bool = False,
                            fsdp: bool = False,
                            optimizer: str = "sgd") -> Callable:
    """The LM train step sharded pod? x data x model over ``mesh``.

    Same signature as :func:`repro.train.trainer.make_lm_train_step`'s
    result — (params, opt_state, batch, step, rng) -> (params, opt_state,
    metrics) — with the batch sharded over the dp axes and the parameters
    laid out per :func:`mesh_param_specs` (``tp``: Megatron attention/MLP
    over "model"; ``fsdp``: large leaves + optimizer moments over the dp
    axes; both compose). ``params`` provides the pytree/shapes the in/out
    specs are derived from; the CALLER device_puts params/opt_state with
    ``rules.to_shardings(mesh, pspecs)`` when they are sharded.

    FSDP leaves are all-gathered on entry to the loss; autodiff transposes
    the (tiled) all-gather into a reduce-scatter, so their gradients come
    back dp-sharded as SUMS over the gather axes — rescaled to means here.
    The optimizer (``"sgd"`` | ``"adam"``) then updates shard-local: both
    are elementwise per leaf, so each dp shard's update IS the slice of the
    full update. Replicated leaves keep the plain gradient ``pmean``.

    Note: with ``lb.ghost_noise > 0`` each shard draws noise for its local
    slice, so the realization differs from the unsharded step (the
    distribution does not); run equivalence tests noise-free.
    """
    if momentum_dtype == "int8":
        raise NotImplementedError(
            "int8 momentum blocks the trailing dim; its quantized buffers "
            "need their own specs — use the pjit path or float32 momentum")
    if optimizer not in ("sgd", "adam"):
        raise ValueError(f"unknown optimizer {optimizer!r}")
    sigma = lb.effective_noise_sigma()
    if optimizer == "adam" and sigma:
        raise NotImplementedError("ghost noise is wired into sgd.update only")
    dp = mesh_lib.dp_axes(mesh)
    dp_arg = mesh_lib.dp_spec_entry(mesh)
    model_ax = MODEL_AXIS if MODEL_AXIS in mesh.axis_names else None
    msize = mesh_lib.axis_size(mesh, MODEL_AXIS)
    pspecs = mesh_param_specs(params, mesh, cfg=cfg, tp=tp, fsdp=fsdp)
    rep = P()
    if optimizer == "adam":
        opt_specs = adam.AdamState(mu=pspecs, nu=pspecs, step=rep)
    else:
        opt_specs = sgd.SGDState(momentum=pspecs, step=rep)
    dp_sizes = {a: mesh.shape[a] for a in dp}

    def gather_leaf(leaf, spec):
        ent = _fsdp_entry(spec)
        if ent is None:
            return leaf
        dim, axes = ent
        return jax.lax.all_gather(leaf, axes, axis=dim, tiled=True)

    def finalize_grad(g, spec):
        # FSDP leaves arrive as reduce-scattered SUMS over their gather
        # axes; everything else still needs averaging over the dp axes.
        ent = _fsdp_entry(spec)
        scattered = ent[1] if ent is not None else ()
        rest = tuple(a for a in dp if a not in scattered)
        if scattered:
            n = 1
            for a in scattered:
                n *= dp_sizes.get(a, 1)
            g = g / float(n)
        if rest:
            g = jax.lax.pmean(g, rest)
        return g

    def local_step(params: Params, opt_state, batch: Dict[str, jax.Array],
                   step: jax.Array, rng: jax.Array):
        def loss_fn(p):
            pg = _tree_with_specs(gather_leaf, p, pspecs) if fsdp else p
            with EP.manual_mode(model_ax, msize, dp):
                return T.lm_loss(pg, cfg, batch, use_kernels=use_kernels,
                                 remat=remat, seq_parallel=seq_parallel,
                                 ce_chunk=ce_chunk)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if dp:
            grads = _tree_with_specs(finalize_grad, grads, pspecs)
            loss = jax.lax.pmean(loss, dp)
            metrics = jax.lax.pmean(metrics, dp)
        clip_metrics: Dict[str, jax.Array] = {}
        if lb.grad_clip and lb.grad_clip > 0:
            norm = _sharded_global_norm(grads, pspecs)
            grads, gnorm = clip_by_global_norm(grads, lb.grad_clip, norm=norm)
            clip_metrics["grad_norm"] = gnorm
        lr = regime.lr_at(step)
        if optimizer == "adam":
            params2, opt_state2, opt_metrics = adam.update(
                grads, opt_state, params,
                lr=lr, weight_decay=weight_decay, grad_clip=0.0)
        else:
            params2, opt_state2, opt_metrics = sgd.update(
                grads, opt_state, params,
                lr=lr, momentum=lb.momentum, nesterov=lb.nesterov,
                weight_decay=weight_decay, grad_clip=0.0,
                noise_sigma=sigma, rng=rng, momentum_dtype=momentum_dtype)
        metrics = {"loss": loss, "lr": lr, **metrics, **opt_metrics,
                   **clip_metrics}
        return params2, opt_state2, metrics

    return shard_map(local_step, mesh=mesh,
                     in_specs=(pspecs, opt_specs, P(dp_arg), rep, rep),
                     out_specs=(pspecs, opt_specs, rep),
                     check_vma=False)


def state_bytes_per_device(tree: Params, specs: Params, mesh) -> int:
    """Per-device bytes of a (params or optimizer-state) pytree laid out by
    ``specs`` on ``mesh`` — the number the FSDP memory assertion checks
    (Adam state shrinks ~dp_size when its leaves carry dp entries)."""
    flat, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(specs)
    total = 0
    for leaf, spec in zip(flat, flat_s):
        n = 1
        for a in _spec_axes(spec):
            n *= mesh.shape[a]
        nbytes = getattr(leaf, "nbytes", None)
        if nbytes is None:        # ShapeDtypeStruct from a dryrun eval_shape
            sz = 1
            for d in leaf.shape:
                sz *= d
            nbytes = sz * jnp.dtype(leaf.dtype).itemsize
        total += int(nbytes // n)
    return total


# ---------------------------------------------------------------------------
# vision train step (dp over any mesh; model axis replicates)
# ---------------------------------------------------------------------------


def _pmean_state(state: Params, axes) -> Params:
    """Average the BN running stats across dp shards so the replicated state
    stays identical everywhere; boolean flags ('initialized') are already
    replicated and cannot be pmean'd."""
    return jax.tree.map(
        lambda s: s if s.dtype == jnp.bool_ else jax.lax.pmean(s, axes),
        state)


def make_mesh_vision_train_step(model_apply: Callable, cfg, lb:
                                LargeBatchConfig, regime: Regime, mesh, *,
                                weight_decay: float = 5e-4,
                                use_kernels: bool = False) -> Callable:
    """shard_map twin of :func:`repro.train.trainer.make_vision_train_step`
    over ANY production mesh: x, y shard over the dp axes; params, BN state,
    and optimizer state are replicated (vision models carry no
    model-sharded weights — a model axis just replicates the local step).
    Ghost statistics stay per-dp-shard; the collectives are the gradient
    pmean plus the small EMA/metric averages, all over the dp axes only."""
    from repro.train.trainer import make_vision_loss_fn
    sigma = lb.effective_noise_sigma()
    loss_fn = make_vision_loss_fn(model_apply, cfg, lb,
                                  use_kernels=use_kernels)
    dp = mesh_lib.dp_axes(mesh)
    dp_arg = mesh_lib.dp_spec_entry(mesh)

    def local_step(params: Params, bn_state: Params,
                   opt_state: sgd.SGDState, x: jax.Array, y: jax.Array,
                   step: jax.Array, rng: jax.Array):
        # local shard, local ghost statistics — Alg. 1 on this device only
        (loss, (new_state, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, bn_state, x, y)
        # grads (+ EMA state and scalar metrics) cross devices; the
        # normalization statistics never do
        if dp:
            grads = jax.lax.pmean(grads, dp)
            loss = jax.lax.pmean(loss, dp)
            acc = jax.lax.pmean(acc, dp)
            new_state = _pmean_state(new_state, dp)
        lr = regime.lr_at(step)
        params2, opt_state2, m = sgd.update(
            grads, opt_state, params, lr=lr, momentum=lb.momentum,
            weight_decay=weight_decay, grad_clip=lb.grad_clip,
            noise_sigma=sigma, rng=rng)
        return params2, new_state, opt_state2, {
            "loss": loss, "acc": acc, "lr": lr, **m}

    rep = P()
    data = P(dp_arg)
    return shard_map(local_step, mesh=mesh,
                     in_specs=(rep, rep, rep, data, data, rep, rep),
                     out_specs=(rep, rep, rep, rep),
                     check_vma=False)
