"""Training loops wiring the large-batch toolkit into both model classes.

- ``make_lm_train_step``: next-token LM training for the assigned
  architectures (momentum SGD + clipping + noise + regime LR). The returned
  step is pjit-compatible: (params, opt_state, batch, step, rng) ->
  (params, opt_state, metrics).
- ``make_vision_train_step`` / ``train_vision``: the paper's Table-1 style
  experiments — models with (ghost) BN running state, SB/LB/+LR/+GBN/+RA
  presets, weight-distance (diffusion) tracking.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.paper_models import VisionModelConfig
from repro.core.diffusion import DiffusionTracker
from repro.core.large_batch import LargeBatchConfig
from repro.core.metrics import MetricsLogger
from repro.core.regime import Regime
from repro.models import transformer as T
from repro.obs.trace import NULL_TRACER
from repro.optim import adam, sgd

Params = Any


def _obs_step_metrics(reg, t0: float, m: Dict[str, jax.Array],
                      batch_size: int) -> None:
    """Per-step training telemetry: step wall time (the caller blocked on
    the step's output first), grad norm, and the current schedule state
    (LR / batch size) — the signals the paper's measurement rests on.

    The metrics dict crosses to the host ONCE (``jax.device_get`` of the
    whole pytree); per-metric ``float(...)`` reads used to force a
    separate device sync each (lint rule ``host-sync``)."""
    reg.observe("train/step_time_s", time.perf_counter() - t0)
    m = jax.device_get(m)
    reg.set("train/lr", float(m["lr"]))
    reg.set("train/batch_size", batch_size)
    if "grad_norm" in m:
        reg.observe("train/grad_norm", float(m["grad_norm"]))
    reg.inc("train/steps")


# ---------------------------------------------------------------------------
# LM training (assigned architectures)
# ---------------------------------------------------------------------------


def make_lm_train_step(cfg: ModelConfig, lb: LargeBatchConfig,
                       regime: Regime, *, weight_decay: float = 0.0,
                       use_kernels: bool = False,
                       momentum_dtype: str = "float32",
                       remat: bool = False,
                       seq_parallel: bool = False,
                       ce_chunk: int = 0,
                       mesh=None, params: Optional[Params] = None,
                       tp: bool = False, fsdp: bool = False,
                       optimizer: str = "sgd") -> Callable:
    """Build the jit-able LM train step implementing the paper's recipe.

    ``use_kernels=True`` routes both LM mixers through the Pallas kernels —
    flash attention and the Mamba chunk scan — which are fully trainable:
    each pairs its forward with a dedicated Pallas backward kernel via
    ``jax.custom_vjp`` (see docs/kernels.md), so ``jax.value_and_grad`` here
    never differentiates through an interpreted kernel body or replays an
    oracle forward.

    With ``mesh`` (any mesh from :mod:`repro.launch.mesh`) the step runs
    sharded pod? x data x model through the unified parallelism layer
    (:mod:`repro.train.parallel`): batch over the dp axes, MoE expert
    weights over ``"model"``, plus ``tp=True`` (Megatron attention/MLP
    over "model") and ``fsdp=True`` (params + optimizer moments over the dp
    axes) — see :func:`repro.train.parallel.make_mesh_lm_train_step`.
    ``params`` (the parameter pytree or its shapes) is required then — the
    shard_map specs are derived from it. ``optimizer`` picks "sgd"
    (the paper's recipe) or "adam" (its adaptive baseline) on either path.
    """
    if mesh is not None:
        if params is None:
            raise ValueError("mesh-sharded LM step needs the params "
                             "pytree to derive its specs")
        from repro.train.parallel import make_mesh_lm_train_step
        return make_mesh_lm_train_step(
            cfg, lb, regime, mesh, params, weight_decay=weight_decay,
            use_kernels=use_kernels, momentum_dtype=momentum_dtype,
            remat=remat, seq_parallel=seq_parallel, ce_chunk=ce_chunk,
            tp=tp, fsdp=fsdp, optimizer=optimizer)
    if tp or fsdp:
        raise ValueError("tp/fsdp need a mesh")
    if optimizer not in ("sgd", "adam"):
        raise ValueError(f"unknown optimizer {optimizer!r}")
    sigma = lb.effective_noise_sigma()

    def train_step(params: Params, opt_state, batch: Dict[str, jax.Array],
                   step: jax.Array, rng: jax.Array):
        def loss_fn(p):
            return T.lm_loss(p, cfg, batch, use_kernels=use_kernels,
                             remat=remat, seq_parallel=seq_parallel,
                             ce_chunk=ce_chunk)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr = regime.lr_at(step)
        if optimizer == "adam":
            params2, opt_state2, opt_metrics = adam.update(
                grads, opt_state, params, lr=lr,
                weight_decay=weight_decay, grad_clip=lb.grad_clip)
        else:
            params2, opt_state2, opt_metrics = sgd.update(
                grads, opt_state, params,
                lr=lr, momentum=lb.momentum, nesterov=lb.nesterov,
                weight_decay=weight_decay, grad_clip=lb.grad_clip,
                noise_sigma=sigma, rng=rng, momentum_dtype=momentum_dtype)
        metrics = {"loss": loss, "lr": lr, **metrics, **opt_metrics}
        return params2, opt_state2, metrics

    return train_step


def make_lm_eval_step(cfg: ModelConfig, use_kernels: bool = False) -> Callable:
    def eval_step(params: Params, batch: Dict[str, jax.Array]):
        loss, metrics = T.lm_loss(params, cfg, batch,
                                  use_kernels=use_kernels)
        return metrics["ce"]

    return eval_step


# ---------------------------------------------------------------------------
# Vision training (the paper's own experiments)
# ---------------------------------------------------------------------------


def make_vision_loss_fn(model_apply: Callable, cfg: VisionModelConfig,
                        lb: LargeBatchConfig, *,
                        use_kernels: bool = False) -> Callable:
    """(params, bn_state, x, y) -> (nll, (new_bn_state, acc)).

    Shared by the single-device step below and the shard_map data-parallel
    step (:mod:`repro.train.data_parallel`) — in the sharded case it runs on
    each device's LOCAL batch, so the ghost-batch statistics inside
    ``model_apply`` are per-device by construction. Fully differentiable
    through the ``use_kernels=True`` GBN path (Pallas backward kernel via
    ``jax.custom_vjp``).
    """

    def loss_fn(p: Params, bn_state: Params, x: jax.Array, y: jax.Array):
        logits, new_state = model_apply(
            p, bn_state, cfg, x, training=True,
            ghost_batch_size=lb.ghost_batch_size,
            use_gbn=lb.use_gbn, use_kernels=use_kernels)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
        acc = (logits.argmax(-1) == y).mean()
        return nll, (new_state, acc)

    return loss_fn


def make_vision_train_step(model_apply: Callable, cfg: VisionModelConfig,
                           lb: LargeBatchConfig, regime: Regime,
                           *, weight_decay: float = 5e-4,
                           use_kernels: bool = False) -> Callable:
    """Vision train step with GBN state threading.

    ``lb.use_gbn`` selects ghost vs full-batch statistics;
    ``lb.ghost_batch_size`` is Alg. 1's |B_S|.
    """
    sigma = lb.effective_noise_sigma()
    loss_fn = make_vision_loss_fn(model_apply, cfg, lb,
                                  use_kernels=use_kernels)

    def train_step(params: Params, bn_state: Params, opt_state: sgd.SGDState,
                   x: jax.Array, y: jax.Array, step: jax.Array,
                   rng: jax.Array):
        (loss, (new_state, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, bn_state, x, y)
        lr = regime.lr_at(step)
        params2, opt_state2, m = sgd.update(
            grads, opt_state, params, lr=lr, momentum=lb.momentum,
            weight_decay=weight_decay, grad_clip=lb.grad_clip,
            noise_sigma=sigma, rng=rng)
        return params2, new_state, opt_state2, {
            "loss": loss, "acc": acc, "lr": lr, **m}

    return train_step


def make_vision_eval(model_apply: Callable, cfg: VisionModelConfig
                     ) -> Callable:
    @jax.jit
    def eval_batch(params, bn_state, x, y):
        logits, _ = model_apply(params, bn_state, cfg, x, training=False)
        return (logits.argmax(-1) == y).sum()

    def evaluate(params, bn_state, x, y, batch: int = 512) -> float:
        correct = 0
        for i in range(0, x.shape[0], batch):
            correct += int(eval_batch(params, bn_state,
                                      x[i:i + batch], y[i:i + batch]))
        return correct / x.shape[0]

    return evaluate


def _epoch_perm(shuffle_key: jax.Array, epoch: int, n: int) -> np.ndarray:
    """Deterministic per-epoch shuffle: a pure function of (key, epoch), so
    a run resumed at any (epoch, cursor) sees the same batch sequence as an
    uninterrupted one."""
    return np.asarray(
        jax.random.permutation(jax.random.fold_in(shuffle_key, epoch), n))


def _record_diffusion(step: int, total_steps: int, every: int) -> bool:
    if every > 0:
        return step % every == 0
    # auto cadence: dense early (the log-t regime), sparse after
    return step < 32 or step % max(1, total_steps // 64) == 0


def _save_run_state(checkpoint_dir: str, step: int, params, bn_state,
                    opt_state, *, epoch: int, cursor: int,
                    logger, tracker) -> None:
    from repro import checkpoint as ckpt
    extra: Dict[str, Any] = {"epoch": epoch, "cursor": cursor,
                             "metrics": logger.to_json()}
    if tracker is not None:
        extra["tracker"] = {"steps": list(tracker.steps),
                            "distances": list(tracker.distances)}
    # under a multi-process runtime each host writes only its addressable
    # shards (no gather); single-process keeps the consolidated layout
    ckpt.save(checkpoint_dir, step, params, opt_state, extra=extra,
              bn_state=bn_state, sharded=jax.process_count() > 1)


def _restore_run_state(checkpoint_dir, params, opt_state, bn_state, tracker):
    """Shared resume path: restore trees + (step, epoch, cursor, logger)
    from the latest checkpoint, or the fresh-run defaults when none exists.
    ``bn_state=None`` (the LM loop) skips the BN-state tree."""
    from repro import checkpoint as ckpt
    if not checkpoint_dir or ckpt.latest_step(checkpoint_dir) is None:
        return params, opt_state, bn_state, 0, 0, 0, MetricsLogger()
    params, _ = ckpt.restore(checkpoint_dir, params)
    opt_state, _ = ckpt.restore(checkpoint_dir, opt_state, kind="opt")
    if bn_state is not None:
        bn_state, _ = ckpt.restore(checkpoint_dir, bn_state, kind="state")
    meta = ckpt.load_meta(checkpoint_dir)
    logger = MetricsLogger.from_json(meta["metrics"])
    if tracker is not None and "tracker" in meta:
        tracker.load(meta["tracker"]["steps"], meta["tracker"]["distances"])
    return (params, opt_state, bn_state, meta["step"], meta["epoch"],
            meta["cursor"], logger)


def train_vision(model_fns, cfg: VisionModelConfig, data,
                 lb: LargeBatchConfig, regime: Regime, *, seed: int = 0,
                 eval_every: int = 0, track_diffusion: bool = True,
                 diffusion_every: int = 0,
                 log_fn: Optional[Callable[[str], None]] = None,
                 use_kernels: bool = False, mesh=None,
                 weight_decay: float = 5e-4,
                 batch_schedule=None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 resume: bool = True, obs=None) -> Dict[str, Any]:
    """Full training run; returns final/best accuracy + diffusion trace.

    With ``mesh`` (any mesh from :mod:`repro.launch.mesh` — the 1-D
    ``("data",)`` mesh or the 2-D ``(data, model)`` production shape) the
    step runs sharded data-parallel over the mesh's dp axes: each dp shard
    normalizes with its own ghost-batch statistics and only gradients cross
    devices.

    The PRNG is split three ways — init / per-step gradient noise / data
    shuffling — so no consumer reuses another's key. Shuffling is a pure
    function of (seed, epoch), which together with ``checkpoint_dir`` +
    ``checkpoint_every`` makes runs resumable: an interrupted run restarts
    from the last saved (params, bn_state, opt_state, epoch, cursor,
    metrics) and replays the identical batch sequence.

    ``batch_schedule`` (a :class:`repro.core.regime.BatchSchedule`) grows
    the batch size during training instead of decaying the LR (Smith et
    al. 2018); distinct batch sizes re-jit once each.

    ``metrics`` output: the returned dict carries a
    :class:`repro.core.metrics.MetricsLogger` under ``"metrics"``
    (the legacy ``"history"`` dict is derived from it).

    ``obs`` (a :class:`repro.obs.Observability`) wraps every step in a
    ``train.step`` span and emits the training telemetry set —
    ``train/step_time_s`` / ``train/grad_norm`` histograms, ``train/lr``
    and ``train/batch_size`` gauges, and the logger's series (eval
    accuracy, weight distance) mirrored under ``train/``. With ``obs``
    the loop blocks on each step's output to make the step time real;
    without it nothing is added to the dispatch path.
    """
    init_fn, apply_fn = model_fns
    init_key, noise_key, shuffle_key = jax.random.split(
        jax.random.PRNGKey(seed), 3)
    params, bn_state = init_fn(init_key, cfg)
    opt_state = sgd.init(params)
    tracker = DiffusionTracker(params) if track_diffusion else None
    params, opt_state, bn_state, step, epoch, cursor, logger = \
        _restore_run_state(checkpoint_dir if resume else None,
                           params, opt_state, bn_state, tracker)
    tracer = obs.tracer if obs is not None else NULL_TRACER
    reg = obs.registry if obs is not None else None
    if obs is not None:
        logger.attach_registry(obs.registry, prefix="train/")

    if mesh is not None:
        from repro.train.data_parallel import make_dp_vision_train_step
        step_fn = jax.jit(make_dp_vision_train_step(
            apply_fn, cfg, lb, regime, mesh, use_kernels=use_kernels,
            weight_decay=weight_decay))
    else:
        step_fn = jax.jit(make_vision_train_step(
            apply_fn, cfg, lb, regime, use_kernels=use_kernels,
            weight_decay=weight_decay))
    evaluate = make_vision_eval(apply_fn, cfg)

    x_tr, y_tr = data.x_train, data.y_train
    n = x_tr.shape[0]
    perm = _epoch_perm(shuffle_key, epoch, n)
    best = logger.max("val_acc")
    while step < regime.total_steps:
        b = (batch_schedule.batch_at(step) if batch_schedule is not None
             else lb.batch_size)
        if b > n:
            if mesh is not None:
                # capping would silently break the divisibility the mesh
                # gating validated against the CONFIGURED batch size
                raise ValueError(f"batch {b} > dataset {n} on a mesh run")
            b = n
        if cursor + b > n:
            epoch += 1
            cursor = 0
            perm = _epoch_perm(shuffle_key, epoch, n)
        idx = perm[cursor:cursor + b]
        cursor += b
        x = jnp.asarray(x_tr[idx])
        y = jnp.asarray(y_tr[idx])
        t0 = time.perf_counter()
        with tracer.span("train.step", step=step, batch=b):
            params, bn_state, opt_state, m = step_fn(
                params, bn_state, opt_state, x, y, jnp.int32(step),
                jax.random.fold_in(noise_key, step))
            if reg is not None:
                jax.block_until_ready(m["loss"])
        if reg is not None:
            _obs_step_metrics(reg, t0, m, b)
        if tracker is not None and _record_diffusion(
                step, regime.total_steps, diffusion_every):
            tracker.record(step + 1, params)
        if eval_every and step % eval_every == 0:
            with tracer.span("train.eval", step=step):
                acc = evaluate(params, bn_state, data.x_test, data.y_test)
            mh = jax.device_get(m)     # one sync for every logged metric
            logger.log(step, val_acc=acc, train_loss=float(mh["loss"]),
                       lr=float(mh["lr"]))
            best = max(best, acc)
            if log_fn:
                log_fn(f"step {step:5d} loss {float(mh['loss']):.4f} "
                       f"val_acc {acc:.4f} lr {float(mh['lr']):.4f}")
        step += 1
        if (checkpoint_dir and checkpoint_every
                and step % checkpoint_every == 0
                and step < regime.total_steps):
            _save_run_state(checkpoint_dir, step, params, bn_state,
                            opt_state, epoch=epoch, cursor=cursor,
                            logger=logger, tracker=tracker)
    final = evaluate(params, bn_state, data.x_test, data.y_test)
    train_acc = evaluate(params, bn_state, x_tr[:2048], y_tr[:2048])
    if tracker is not None:
        logger.set_series("distance", tracker.steps, tracker.distances)
    out = {"final_acc": final, "best_acc": max(best, final),
           "train_acc": train_acc, "history": logger.to_history(),
           "metrics": logger, "steps": step}
    if tracker is not None:
        out["log_fit"] = tracker.log_fit(burn_in=2)
        out["power_fit"] = tracker.power_fit(burn_in=2)
    return out


# ---------------------------------------------------------------------------
# LM training loop (the same recipe on the assigned architectures)
# ---------------------------------------------------------------------------


def train_lm(cfg: ModelConfig, lb: LargeBatchConfig, regime: Regime,
             rows: np.ndarray, *, seed: int = 0, eval_every: int = 0,
             holdout: int = 0, use_kernels: bool = False,
             weight_decay: float = 0.0, track_diffusion: bool = False,
             diffusion_every: int = 0,
             log_fn: Optional[Callable[[str], None]] = None,
             mesh=None,
             checkpoint_dir: Optional[str] = None,
             checkpoint_every: int = 0, resume: bool = True,
             obs=None) -> Dict[str, Any]:
    """LM twin of :func:`train_vision`: drives :func:`make_lm_train_step`
    over (N, seq_len) token rows with the same structured metrics,
    deterministic shuffling, and checkpoint/resume contract.

    ``holdout`` rows from the end are held out for CE evaluation.
    ``use_kernels=True`` (what the ``lm-smoke`` sweep runs) trains through
    the differentiable Pallas flash-attention and Mamba chunk-scan kernels.

    With ``mesh`` (mirroring :func:`train_vision`) the step runs through
    the unified 2-D layer (:mod:`repro.train.parallel`): batch over the dp
    axes, MoE expert weights over ``"model"``.

    ``obs`` mirrors :func:`train_vision`: ``train.step`` spans plus the
    ``train/*`` telemetry set in the registry.
    """
    init_key, noise_key, shuffle_key = jax.random.split(
        jax.random.PRNGKey(seed), 3)
    params = T.init_params(init_key, cfg)
    opt_state = sgd.init(params)
    tracker = DiffusionTracker(params) if track_diffusion else None
    params, opt_state, _, step, epoch, cursor, logger = \
        _restore_run_state(checkpoint_dir if resume else None,
                           params, opt_state, None, tracker)
    tracer = obs.tracer if obs is not None else NULL_TRACER
    reg = obs.registry if obs is not None else None
    if obs is not None:
        logger.attach_registry(obs.registry, prefix="train/")

    step_fn = jax.jit(make_lm_train_step(
        cfg, lb, regime, weight_decay=weight_decay,
        use_kernels=use_kernels, mesh=mesh,
        params=params if mesh is not None else None))
    eval_fn = jax.jit(make_lm_eval_step(cfg, use_kernels=use_kernels))

    train_rows = rows[: rows.shape[0] - holdout] if holdout else rows
    eval_rows = rows[rows.shape[0] - holdout:] if holdout else rows[:0]
    n = train_rows.shape[0]
    b = lb.batch_size
    if n < b:
        raise ValueError(f"{n} rows < batch_size {b}")

    def eval_ce() -> float:
        """Row-weighted mean CE over the WHOLE holdout: full batches of
        ``b`` plus the trailing remainder (one extra jit shape) — previously
        the tail rows were silently dropped whenever a full batch fit."""
        n_eval = eval_rows.shape[0]
        if n_eval == 0:
            return float("nan")
        total = 0.0
        for i in range(0, n_eval, b):
            chunk = eval_rows[i:i + b]
            ce = float(eval_fn(params, {"tokens": jnp.asarray(chunk)}))
            total += ce * chunk.shape[0]
        return total / n_eval

    perm = _epoch_perm(shuffle_key, epoch, n)
    while step < regime.total_steps:
        if cursor + b > n:
            epoch += 1
            cursor = 0
            perm = _epoch_perm(shuffle_key, epoch, n)
        idx = perm[cursor:cursor + b]
        cursor += b
        batch = {"tokens": jnp.asarray(train_rows[idx])}
        t0 = time.perf_counter()
        with tracer.span("train.step", step=step, batch=b):
            params, opt_state, m = step_fn(
                params, opt_state, batch, jnp.int32(step),
                jax.random.fold_in(noise_key, step))
            if reg is not None:
                jax.block_until_ready(m["loss"])
        if reg is not None:
            _obs_step_metrics(reg, t0, m, b)
        if tracker is not None and _record_diffusion(
                step, regime.total_steps, diffusion_every):
            tracker.record(step + 1, params)
        if eval_every and step % eval_every == 0:
            with tracer.span("train.eval", step=step):
                ce = eval_ce()
            mh = jax.device_get(m)     # one sync for every logged metric
            logger.log(step, eval_ce=ce, train_loss=float(mh["loss"]),
                       lr=float(mh["lr"]))
            if log_fn:
                log_fn(f"step {step:5d} loss {float(mh['loss']):.4f} "
                       f"eval_ce {ce:.4f}")
        step += 1
        if (checkpoint_dir and checkpoint_every
                and step % checkpoint_every == 0
                and step < regime.total_steps):
            _save_run_state(checkpoint_dir, step, params, None, opt_state,
                            epoch=epoch, cursor=cursor, logger=logger,
                            tracker=tracker)
    final_ce = eval_ce()
    if tracker is not None:
        logger.set_series("distance", tracker.steps, tracker.distances)
    out = {"final_ce": final_ce, "metrics": logger,
           "history": logger.to_history(), "steps": step}
    if tracker is not None:
        out["log_fit"] = tracker.log_fit(burn_in=2)
        out["power_fit"] = tracker.power_fit(burn_in=2)
    return out
