"""Training loops wiring the large-batch toolkit into both model classes.

- ``make_lm_train_step``: next-token LM training for the assigned
  architectures (momentum SGD + clipping + noise + regime LR). The returned
  step is pjit-compatible: (params, opt_state, batch, step, rng) ->
  (params, opt_state, metrics).
- ``make_vision_train_step`` / ``train_vision``: the paper's Table-1 style
  experiments — models with (ghost) BN running state, SB/LB/+LR/+GBN/+RA
  presets, weight-distance (diffusion) tracking.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.paper_models import VisionModelConfig
from repro.core.diffusion import DiffusionTracker
from repro.core.large_batch import LargeBatchConfig
from repro.core.regime import Regime
from repro.models import transformer as T
from repro.optim import sgd

Params = Any


# ---------------------------------------------------------------------------
# LM training (assigned architectures)
# ---------------------------------------------------------------------------


def make_lm_train_step(cfg: ModelConfig, lb: LargeBatchConfig,
                       regime: Regime, *, weight_decay: float = 0.0,
                       use_kernels: bool = False,
                       momentum_dtype: str = "float32",
                       remat: bool = False,
                       seq_parallel: bool = False,
                       ce_chunk: int = 0) -> Callable:
    """Build the jit-able LM train step implementing the paper's recipe."""
    sigma = lb.effective_noise_sigma()

    def train_step(params: Params, opt_state: sgd.SGDState,
                   batch: Dict[str, jax.Array], step: jax.Array,
                   rng: jax.Array):
        def loss_fn(p):
            return T.lm_loss(p, cfg, batch, use_kernels=use_kernels,
                             remat=remat, seq_parallel=seq_parallel,
                             ce_chunk=ce_chunk)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr = regime.lr_at(step)
        params2, opt_state2, opt_metrics = sgd.update(
            grads, opt_state, params,
            lr=lr, momentum=lb.momentum, nesterov=lb.nesterov,
            weight_decay=weight_decay, grad_clip=lb.grad_clip,
            noise_sigma=sigma, rng=rng, momentum_dtype=momentum_dtype)
        metrics = {"loss": loss, "lr": lr, **metrics, **opt_metrics}
        return params2, opt_state2, metrics

    return train_step


def make_lm_eval_step(cfg: ModelConfig, use_kernels: bool = False) -> Callable:
    def eval_step(params: Params, batch: Dict[str, jax.Array]):
        loss, metrics = T.lm_loss(params, cfg, batch,
                                  use_kernels=use_kernels)
        return metrics["ce"]

    return eval_step


# ---------------------------------------------------------------------------
# Vision training (the paper's own experiments)
# ---------------------------------------------------------------------------


def make_vision_loss_fn(model_apply: Callable, cfg: VisionModelConfig,
                        lb: LargeBatchConfig, *,
                        use_kernels: bool = False) -> Callable:
    """(params, bn_state, x, y) -> (nll, (new_bn_state, acc)).

    Shared by the single-device step below and the shard_map data-parallel
    step (:mod:`repro.train.data_parallel`) — in the sharded case it runs on
    each device's LOCAL batch, so the ghost-batch statistics inside
    ``model_apply`` are per-device by construction. Fully differentiable
    through the ``use_kernels=True`` GBN path (Pallas backward kernel via
    ``jax.custom_vjp``).
    """

    def loss_fn(p: Params, bn_state: Params, x: jax.Array, y: jax.Array):
        logits, new_state = model_apply(
            p, bn_state, cfg, x, training=True,
            ghost_batch_size=lb.ghost_batch_size,
            use_gbn=lb.use_gbn, use_kernels=use_kernels)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
        acc = (logits.argmax(-1) == y).mean()
        return nll, (new_state, acc)

    return loss_fn


def make_vision_train_step(model_apply: Callable, cfg: VisionModelConfig,
                           lb: LargeBatchConfig, regime: Regime,
                           *, weight_decay: float = 5e-4,
                           use_kernels: bool = False) -> Callable:
    """Vision train step with GBN state threading.

    ``lb.use_gbn`` selects ghost vs full-batch statistics;
    ``lb.ghost_batch_size`` is Alg. 1's |B_S|.
    """
    sigma = lb.effective_noise_sigma()
    loss_fn = make_vision_loss_fn(model_apply, cfg, lb,
                                  use_kernels=use_kernels)

    def train_step(params: Params, bn_state: Params, opt_state: sgd.SGDState,
                   x: jax.Array, y: jax.Array, step: jax.Array,
                   rng: jax.Array):
        (loss, (new_state, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, bn_state, x, y)
        lr = regime.lr_at(step)
        params2, opt_state2, m = sgd.update(
            grads, opt_state, params, lr=lr, momentum=lb.momentum,
            weight_decay=weight_decay, grad_clip=lb.grad_clip,
            noise_sigma=sigma, rng=rng)
        return params2, new_state, opt_state2, {
            "loss": loss, "acc": acc, "lr": lr, **m}

    return train_step


def make_vision_eval(model_apply: Callable, cfg: VisionModelConfig
                     ) -> Callable:
    @jax.jit
    def eval_batch(params, bn_state, x, y):
        logits, _ = model_apply(params, bn_state, cfg, x, training=False)
        return (logits.argmax(-1) == y).sum()

    def evaluate(params, bn_state, x, y, batch: int = 512) -> float:
        correct = 0
        for i in range(0, x.shape[0], batch):
            correct += int(eval_batch(params, bn_state,
                                      x[i:i + batch], y[i:i + batch]))
        return correct / x.shape[0]

    return evaluate


def train_vision(model_fns, cfg: VisionModelConfig, data,
                 lb: LargeBatchConfig, regime: Regime, *, seed: int = 0,
                 eval_every: int = 0, track_diffusion: bool = True,
                 log_fn: Optional[Callable[[str], None]] = None,
                 use_kernels: bool = False, mesh=None,
                 weight_decay: float = 5e-4) -> Dict[str, Any]:
    """Full training run; returns final/best accuracy + diffusion trace.

    With ``mesh`` (a 1-D ``("data",)`` mesh from
    :func:`repro.launch.mesh.make_data_mesh`) the step runs sharded
    data-parallel: each device normalizes with its own ghost-batch
    statistics and only gradients cross devices.
    """
    init_fn, apply_fn = model_fns
    rng = jax.random.PRNGKey(seed)
    params, bn_state = init_fn(rng, cfg)
    opt_state = sgd.init(params)
    if mesh is not None:
        from repro.train.data_parallel import make_dp_vision_train_step
        step_fn = jax.jit(make_dp_vision_train_step(
            apply_fn, cfg, lb, regime, mesh, use_kernels=use_kernels,
            weight_decay=weight_decay))
    else:
        step_fn = jax.jit(make_vision_train_step(
            apply_fn, cfg, lb, regime, use_kernels=use_kernels,
            weight_decay=weight_decay))
    evaluate = make_vision_eval(apply_fn, cfg)
    tracker = DiffusionTracker(params) if track_diffusion else None

    nprng = np.random.RandomState(seed + 1)
    x_tr, y_tr = data.x_train, data.y_train
    n = x_tr.shape[0]
    steps_per_epoch = max(1, n // lb.batch_size)
    history = {"val_acc": [], "train_loss": [], "steps": [],
               "distance": [], "dist_steps": []}
    best = 0.0
    step = 0
    while step < regime.total_steps:
        for idx in np.array_split(nprng.permutation(n),
                                  max(1, n // lb.batch_size)):
            if step >= regime.total_steps:
                break
            if idx.size < lb.batch_size:
                continue
            x = jnp.asarray(x_tr[idx])
            y = jnp.asarray(y_tr[idx])
            params, bn_state, opt_state, m = step_fn(
                params, bn_state, opt_state, x, y, jnp.int32(step),
                jax.random.fold_in(rng, step))
            if tracker is not None and (
                    step < 32 or step % max(1, regime.total_steps // 64) == 0):
                d = tracker.record(step + 1, params)
                history["distance"].append(d)
                history["dist_steps"].append(step + 1)
            if eval_every and step % eval_every == 0:
                acc = evaluate(params, bn_state, data.x_test, data.y_test)
                history["val_acc"].append(acc)
                history["steps"].append(step)
                history["train_loss"].append(float(m["loss"]))
                best = max(best, acc)
                if log_fn:
                    log_fn(f"step {step:5d} loss {float(m['loss']):.4f} "
                           f"val_acc {acc:.4f} lr {float(m['lr']):.4f}")
            step += 1
    final = evaluate(params, bn_state, data.x_test, data.y_test)
    train_acc = evaluate(params, bn_state, x_tr[:2048], y_tr[:2048])
    out = {"final_acc": final, "best_acc": max(best, final),
           "train_acc": train_acc, "history": history, "steps": step}
    if tracker is not None:
        out["log_fit"] = tracker.log_fit(burn_in=2)
        out["power_fit"] = tracker.power_fit(burn_in=2)
    return out
