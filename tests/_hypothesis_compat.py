"""Import hypothesis if available; otherwise a deterministic fallback.

The container this repo is developed in does not ship ``hypothesis`` and we
cannot add dependencies. The fallback keeps the property tests running as a
small fixed-sample sweep (cartesian product of a few boundary/midpoint values
per strategy) so the suite stays green — and becomes a real property-based
sweep wherever hypothesis IS installed.
"""
from __future__ import annotations

import itertools

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _St:
        @staticmethod
        def integers(lo, hi):
            mid = (lo + hi) // 2
            return _Strategy(sorted({lo, mid, hi}))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(sorted({lo, (lo + hi) / 2.0, hi}))

        @staticmethod
        def sampled_from(xs):
            return _Strategy(xs)

    st = _St()

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = list(strategies)
        grids = [strategies[n].samples for n in names]

        def deco(fn):
            def wrapper():
                combos = list(itertools.product(*grids))
                # cap the sweep so a wide product stays fast
                for combo in combos[:32]:
                    fn(**dict(zip(names, combo)))
            # keep the collected test name; do NOT functools.wraps — pytest
            # would then see the original signature and demand fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
