import dataclasses

import jax
import pytest

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests and benches
# must see the single real device; only launch/dryrun.py forces 512.


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced_cfg(arch: str, **overrides):
    """Float32 reduced config for CPU numerics."""
    from repro.configs.registry import get_config
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, dtype="float32", **overrides)
