"""BAD: collective axis names spelled as string literals."""
import jax


def combine(y):
    return jax.lax.psum(y, "model")


def grad_mean(g):
    return jax.lax.pmean(g, axis_name=("pod", "data"))


def local_rank():
    return jax.lax.axis_index("data")
