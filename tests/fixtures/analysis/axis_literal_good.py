"""GOOD: collective axis names come from the launch.mesh constants."""
import jax

from repro.launch.mesh import DATA_AXIS, MODEL_AXIS, POD_AXIS


def combine(y):
    return jax.lax.psum(y, MODEL_AXIS)


def grad_mean(g):
    return jax.lax.pmean(g, axis_name=(POD_AXIS, DATA_AXIS))


def local_rank():
    return jax.lax.axis_index(DATA_AXIS)
