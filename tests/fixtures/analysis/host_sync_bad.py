"""BAD: one device metrics pytree fanned out into per-metric host syncs
(rule host-sync) — each float() blocks the dispatch queue separately."""


def log_metrics(logger, m):
    logger.log(loss=float(m["loss"]), lr=float(m["lr"]))
    print(float(m["grad_norm"]))


def poll_scalar(x):
    return x.item()
