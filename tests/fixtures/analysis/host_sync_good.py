"""GOOD: fetch the metrics pytree ONCE with jax.device_get and read the
plain floats from the host copy — a single device sync per log point."""
import jax


def log_metrics(logger, m):
    mh = jax.device_get(m)
    logger.log(loss=float(mh["loss"]), lr=float(mh["lr"]))
    print(float(mh["grad_norm"]))
