"""BAD: obs= without a None default (forces every caller to build an
Observability), and span/metric names off the docs/observability.md
grammar (rule obs-contract)."""


def run_engine(cfg, obs):
    with obs.tracer.span("DecodeStep"):
        obs.registry.observe("decode latency", 1.0)
