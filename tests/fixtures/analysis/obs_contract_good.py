"""GOOD: obs defaults to None (zero-cost un-observed) and the names follow
the grammar: spans <subsystem>.<signal>, metrics <subsystem>/<signal>."""


def run_engine(cfg, obs=None):
    if obs is not None:
        with obs.tracer.span("serve.decode_step"):
            obs.registry.observe("serve/decode_latency_s", 1.0)
