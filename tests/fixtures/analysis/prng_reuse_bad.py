"""BAD: the same PRNG key consumed by two jax.random draws without an
intervening split — the two draws are silently correlated (rule
prng-reuse)."""
import jax


def draw(rng, shape):
    a = jax.random.normal(rng, shape)
    b = jax.random.uniform(rng, shape)
    return a + b
