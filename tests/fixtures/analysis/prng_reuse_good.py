"""GOOD: split once, one subkey per consumer."""
import jax


def draw(rng, shape):
    ka, kb = jax.random.split(rng)
    a = jax.random.normal(ka, shape)
    b = jax.random.uniform(kb, shape)
    return a + b
