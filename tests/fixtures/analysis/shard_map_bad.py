"""BAD: raw shard_map import straight from jax (rule shard-map-import).

Bypasses the version shim in core/compat.py, so the namespace/kwarg moves
across jax versions break this module silently.
"""
from jax.experimental.shard_map import shard_map  # noqa: F401


def run(fn, mesh, in_specs, out_specs):
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
