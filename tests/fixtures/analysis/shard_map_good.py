"""GOOD: shard_map through the core/compat.py version shim."""
from repro.core.compat import shard_map  # noqa: F401


def run(fn, mesh, in_specs, out_specs):
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
