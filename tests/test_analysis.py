"""repro.analysis: fixture pairs per lint rule, suppression semantics,
trace-auditor unit checks, bench-gate units (tier 0 — seconds, no model
code), plus the repo-wide gates (tier 1): lint + kernel contracts clean on
src/, and the trace auditor proving no-callback / no-f64 / donation
aliasing on the hot entry points.
"""
import json
from pathlib import Path

import pytest

from repro.analysis.findings import Finding, render, suppressions
from repro.analysis.lint import (DEFAULT_CONFIG, LintConfig, lint_source,
                                 run_repo_lint)

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"

# the fixture dir plays the hot path so host-sync fires on its snippets
FIXTURE_CFG = LintConfig(hot_paths=("fixtures/analysis/",))

RULE_STEMS = {
    "shard-map-import": "shard_map",
    "host-sync": "host_sync",
    "obs-contract": "obs_contract",
    "prng-reuse": "prng_reuse",
    "axis-name-literal": "axis_literal",
}


def _lint_fixture(name: str):
    path = FIXTURES / name
    rel = f"fixtures/analysis/{name}"
    return lint_source(path.read_text(), rel, FIXTURE_CFG)


# ---------------------------------------------------------------------------
# tier 0: every rule has a bad/good fixture pair — executable docs
# ---------------------------------------------------------------------------


@pytest.mark.tier0
@pytest.mark.parametrize("rule", sorted(RULE_STEMS))
def test_rule_fixture_pair(rule):
    stem = RULE_STEMS[rule]
    bad = _lint_fixture(f"{stem}_bad.py")
    good = _lint_fixture(f"{stem}_good.py")
    assert any(f.rule == rule for f in bad), \
        f"{stem}_bad.py should trip {rule}:\n{render(bad)}"
    assert all(f.rule != rule for f in good), \
        f"{stem}_good.py should pass {rule}:\n{render(good)}"
    # good fixtures are fully clean, not merely clean for their own rule
    assert not good, render(good)


@pytest.mark.tier0
def test_host_sync_fixture_details():
    bad = _lint_fixture("host_sync_bad.py")
    msgs = [f.message for f in bad if f.rule == "host-sync"]
    # 3 float(m[...]) sites -> findings on the 2nd and 3rd, + one .item()
    assert sum(".item()" in m for m in msgs) == 1
    assert sum("separate host syncs" in m for m in msgs) == 2


@pytest.mark.tier0
def test_suppression_silences_only_the_named_rule():
    src = (FIXTURES / "prng_reuse_bad.py").read_text()
    line = "    b = jax.random.uniform(rng, shape)"
    assert line in src
    ok = src.replace(line, line + "  # repro: ignore[prng-reuse]")
    assert lint_source(ok, "x.py") == []
    wrong = src.replace(line, line + "  # repro: ignore[host-sync]")
    assert any(f.rule == "prng-reuse" for f in lint_source(wrong, "x.py"))


@pytest.mark.tier0
def test_suppressions_parse_multiple_rules():
    sup = suppressions("x = 1  # repro: ignore[host-sync, prng-reuse]\n")
    assert sup == {1: {"host-sync", "prng-reuse"}}


@pytest.mark.tier0
def test_prng_reuse_loop_target_rebinds_each_iteration():
    # `for g, r in zip(...)` rebinds r every iteration — NOT reuse
    # (the core/noise.py ghost-noise pattern)
    src = (
        "import jax\n\n\n"
        "def noise(leaves, rngs):\n"
        "    out = []\n"
        "    for g, r in zip(leaves, rngs):\n"
        "        out.append(jax.random.normal(r, g.shape))\n"
        "    return out\n")
    assert lint_source(src, "x.py") == []
    # ...but a key from OUTSIDE the loop consumed each iteration IS reuse
    src2 = (
        "import jax\n\n\n"
        "def noise(leaves, rng):\n"
        "    out = []\n"
        "    for g in leaves:\n"
        "        out.append(jax.random.normal(rng, g.shape))\n"
        "    return out\n")
    assert any(f.rule == "prng-reuse" for f in lint_source(src2, "x.py"))


@pytest.mark.tier0
def test_obs_contract_branch_grammar():
    src = (
        "def f(reg):\n"
        "    reg.observe('serve/ttft_s', 1.0)\n"
        "    reg.inc('bad metric')\n")
    fs = lint_source(src, "x.py")
    assert [f.line for f in fs if f.rule == "obs-contract"] == [3]


# ---------------------------------------------------------------------------
# tier 0: trace auditor units
# ---------------------------------------------------------------------------


@pytest.mark.tier0
def test_audit_jaxpr_flags_callbacks():
    import jax
    import jax.numpy as jnp

    from repro.analysis.trace_audit import audit_jaxpr

    def bad(x):
        jax.debug.print("x = {x}", x=x)
        return x * 2.0

    fs = audit_jaxpr(bad, (jnp.ones((2,)),), name="bad", path="t.py")
    assert any(f.rule == "trace-callback" for f in fs), render(fs)

    def good(x):
        return x * 2.0

    assert audit_jaxpr(good, (jnp.ones((2,)),), name="g", path="t.py") == []


@pytest.mark.tier0
def test_audit_jaxpr_flags_f64():
    import jax
    import jax.numpy as jnp

    from repro.analysis.trace_audit import audit_jaxpr

    def widen(x):
        return x.astype(jnp.float64) * 2.0

    with jax.experimental.enable_x64():
        fs = audit_jaxpr(widen, (jnp.ones((2,), jnp.float32),),
                         name="widen", path="t.py")
    assert any(f.rule == "trace-f64" for f in fs), render(fs)


@pytest.mark.tier0
def test_audit_jaxpr_recurses_into_scan():
    import jax
    import jax.numpy as jnp

    from repro.analysis.trace_audit import audit_jaxpr

    def scanned(x):
        def body(c, _):
            jax.debug.print("c = {c}", c=c)
            return c + 1.0, None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    fs = audit_jaxpr(scanned, (jnp.float32(0.0),), name="s", path="t.py")
    assert any(f.rule == "trace-callback" for f in fs), render(fs)


@pytest.mark.tier0
def test_audit_donation_positive_and_negative():
    import jax.numpy as jnp

    from repro.analysis.trace_audit import audit_donation

    def f(a, b):
        return a + 1.0, b

    ok = audit_donation(f, (jnp.ones((4,)), jnp.ones((4,))), (0,),
                        name="f", path="t.py")
    assert ok == [], render(ok)

    def g(a, b):
        return b * 2.0          # 'a' has no same-shaped output to reuse

    bad = audit_donation(g, (jnp.ones((3,)), jnp.ones((4,))), (0,),
                         name="g", path="t.py")
    assert any(f_.rule == "trace-donation" for f_ in bad), render(bad)


@pytest.mark.tier0
def test_recompile_census_budget():
    from repro.analysis.trace_audit import Entry, audit_variants

    over = Entry("e", "p.py", build=None,
                 static_knobs={"a": 4, "b": 4}, variant_budget=8)
    assert [f.rule for f in audit_variants(over)] == ["recompile-hazard"]
    under = Entry("e", "p.py", build=None,
                  static_knobs={"a": 2, "b": 2}, variant_budget=8)
    assert audit_variants(under) == []


# ---------------------------------------------------------------------------
# tier 0: kernel contract checker units
# ---------------------------------------------------------------------------


@pytest.mark.tier0
def test_kernel_contracts_flag_missing_oracle(tmp_path):
    from repro.analysis.kernel_contracts import check_oracle_pairing

    kdir = tmp_path / "kernels"
    kdir.mkdir()
    (kdir / "foo.py").write_text("def foo_pallas(x):\n    return x\n")
    (kdir / "ref.py").write_text("def foo_ref(x):\n    return x\n")
    doc = tmp_path / "kernels.md"

    # undocumented kernel
    doc.write_text("# kernels\n")
    fs = check_oracle_pairing(kdir, doc)
    assert any(f.rule == "kernel-doc" for f in fs), render(fs)

    # documented but no oracle on its contract row
    doc.write_text("| op | kernel |\n|---|---|\n"
                   "| `foo` | `foo.foo_pallas` |\n")
    fs = check_oracle_pairing(kdir, doc)
    assert any(f.rule == "kernel-oracle" for f in fs), render(fs)

    # docs cite a deleted oracle
    doc.write_text("| op | kernel | oracle |\n|---|---|---|\n"
                   "| `foo` | `foo.foo_pallas` | `ref.gone_ref` |\n")
    fs = check_oracle_pairing(kdir, doc)
    assert any(f.rule == "kernel-oracle" and "gone_ref" in f.message
               for f in fs), render(fs)

    # paired: clean
    doc.write_text("| op | kernel | oracle |\n|---|---|---|\n"
                   "| `foo` | `foo.foo_pallas` | `ref.foo_ref` |\n")
    assert check_oracle_pairing(kdir, doc) == []


@pytest.mark.tier0
def test_tile_alignment_sweep_clean():
    from repro.analysis.kernel_contracts import check_tile_alignment
    fs = check_tile_alignment()
    assert fs == [], render(fs)


# ---------------------------------------------------------------------------
# tier 0: bench gate units
# ---------------------------------------------------------------------------


def _write_bench(path, name, values):
    rows = [{"ts": f"t{i}", "name": name, "us_per_call": v, "derived": ""}
            for i, v in enumerate(values)]
    path.write_text("\n".join(json.dumps(r) for r in rows) + "\n")


@pytest.mark.tier0
def test_bench_gate_flags_regression(tmp_path):
    from repro.analysis.bench_gate import check_bench_regressions

    _write_bench(tmp_path / "BENCH_a.json", "a", [100, 104, 98, 250])
    fs = check_bench_regressions(tmp_path)
    assert len(fs) == 1 and fs[0].rule == "bench-regression", render(fs)
    assert "+1" in fs[0].message and "a:" in fs[0].message


@pytest.mark.tier0
def test_bench_gate_tolerates_noise_and_short_history(tmp_path):
    from repro.analysis.bench_gate import check_bench_regressions

    # +30% < the 50% default tolerance
    _write_bench(tmp_path / "BENCH_a.json", "a", [100, 104, 98, 130])
    # regressed but only 1 prior row: not enough history to judge
    _write_bench(tmp_path / "BENCH_b.json", "b", [100, 300])
    assert check_bench_regressions(tmp_path) == []
    # the improvement direction never fires
    _write_bench(tmp_path / "BENCH_c.json", "c", [300, 310, 290, 100])
    assert check_bench_regressions(tmp_path) == []


# ---------------------------------------------------------------------------
# tier 1: the repo-wide gates
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_repo_lint_gate():
    fs = run_repo_lint()
    assert fs == [], "\n" + render(fs)


@pytest.mark.tier1
def test_repo_kernel_contract_gate():
    from repro.analysis.kernel_contracts import run_kernel_contracts
    fs = run_kernel_contracts()
    assert fs == [], "\n" + render(fs)


@pytest.mark.tier1
def test_trace_audit_gate():
    """Traces every registry entry and (for the donating entries:
    train steps, decode step, fused prefill) compiles and proves the
    input_output_alias header covers every donated leaf."""
    from repro.analysis.trace_audit import ENTRIES, run_trace_audit
    names = {e.name for e in ENTRIES}
    assert {"vision_train_step", "lm_train_step", "decode_step",
            "prefill_fused", "flash_decode_paged"} <= names
    fs = run_trace_audit()
    assert fs == [], "\n" + render(fs)
