"""Per-architecture smoke tests (assigned deliverable f): instantiate the
REDUCED variant of each family and run one forward + one train step on CPU,
asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config, list_archs
from repro.core import LargeBatchConfig, Regime
from repro.models import transformer as T
from repro.optim import sgd
from repro.train.trainer import make_lm_train_step

BATCH, SEQ = 2, 32


def _cfg(arch):
    return dataclasses.replace(get_config(arch).reduced(), dtype="float32")


def _batch(cfg, rng):
    b = {"tokens": jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab_size)}
    if cfg.encoder is not None:
        b["frames"] = 0.1 * jax.random.normal(
            rng, (BATCH, SEQ // cfg.encoder.frame_ratio, cfg.encoder.d_model))
    if cfg.vision is not None:
        b["image_embeds"] = 0.1 * jax.random.normal(
            rng, (BATCH, cfg.vision.n_image_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_no_nans(arch):
    cfg = _cfg(arch)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    memory = T.get_memory(params, cfg, batch)
    logits, aux = T.forward(params, cfg, batch["tokens"], memory=memory)
    assert logits.shape == (BATCH, SEQ, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    for v in aux.values():
        assert not jnp.isnan(v).any()


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch):
    cfg = _cfg(arch)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    opt = sgd.init(params)
    lb = LargeBatchConfig(batch_size=BATCH, base_batch_size=BATCH,
                          grad_clip=1.0)
    regime = Regime(base_lr=0.01, total_steps=10, drop_every=5)
    step = make_lm_train_step(cfg, lb, regime)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    params2, opt2, metrics = step(params, opt, batch, jnp.int32(0),
                                  jax.random.PRNGKey(2))
    assert not jnp.isnan(metrics["loss"])
    assert float(metrics["loss"]) > 0
    # parameters actually moved
    moved = jax.tree.leaves(
        jax.tree.map(lambda a, b: jnp.any(a != b), params, params2))
    assert any(bool(m) for m in moved)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "falcon-mamba-7b",
                                  "jamba-v0.1-52b", "gemma3-27b",
                                  "kimi-k2-1t-a32b"])
def test_loss_decreases_few_steps(arch):
    """A handful of steps on a repeated batch must reduce the loss."""
    cfg = _cfg(arch)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    opt = sgd.init(params)
    lb = LargeBatchConfig(batch_size=BATCH, base_batch_size=BATCH,
                          grad_clip=1.0)
    regime = Regime(base_lr=0.05, total_steps=100, drop_every=100)
    step = jax.jit(make_lm_train_step(cfg, lb, regime))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    losses = []
    for i in range(8):
        params, opt, m = step(params, opt, batch, jnp.int32(i),
                              jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
