"""Checkpoint save/restore roundtrip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.optim import sgd


def test_roundtrip(tmp_path):
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32", body_repeats=1)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    save(str(tmp_path), 7, params, opt, extra={"arch": cfg.name})
    assert latest_step(str(tmp_path)) == 7
    template = jax.tree.map(jnp.zeros_like, params)
    restored, step = restore(str(tmp_path), template)
    assert step == 7
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(restored)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ropt, _ = restore(str(tmp_path), jax.tree.map(jnp.zeros_like, opt),
                      kind="opt")
    np.testing.assert_array_equal(np.asarray(ropt.step), np.asarray(opt.step))


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), {"w": jnp.zeros(())})
