"""Checkpoint save/restore roundtrip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_meta, restore, save
from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.optim import sgd

pytestmark = pytest.mark.tier0


def test_roundtrip(tmp_path):
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32", body_repeats=1)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    save(str(tmp_path), 7, params, opt, extra={"arch": cfg.name})
    assert latest_step(str(tmp_path)) == 7
    template = jax.tree.map(jnp.zeros_like, params)
    restored, step = restore(str(tmp_path), template)
    assert step == 7
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(restored)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ropt, _ = restore(str(tmp_path), jax.tree.map(jnp.zeros_like, opt),
                      kind="opt")
    np.testing.assert_array_equal(np.asarray(ropt.step), np.asarray(opt.step))


def test_bn_state_and_meta_roundtrip(tmp_path):
    """The run-state checkpoint the sweep runner relies on: BN running
    statistics (incl. bool 'initialized' flags) and the JSON meta."""
    bn = {"stages": [{"mean": jnp.ones((4,)), "var": 2.0 * jnp.ones((4,)),
                      "initialized": jnp.ones((), jnp.bool_)}]}
    params = {"w": jnp.arange(3.0)}
    save(str(tmp_path), 11, params, bn_state=bn,
         extra={"epoch": 2, "cursor": 96})
    template = jax.tree.map(jnp.zeros_like, bn)
    restored, step = restore(str(tmp_path), template, kind="state")
    assert step == 11
    assert bool(restored["stages"][0]["initialized"])
    np.testing.assert_array_equal(
        np.asarray(restored["stages"][0]["var"]), 2.0 * np.ones((4,)))
    meta = load_meta(str(tmp_path))
    assert meta["step"] == 11 and meta["epoch"] == 2 and meta["cursor"] == 96


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), {"w": jnp.zeros(())})


def test_sharded_layout_roundtrip(tmp_path):
    """save(sharded=True): per-process shard files with the global index
    baked into each entry name; restore finds and reassembles them without
    being told the layout. Single-process this is the degenerate one-file
    case (the cross-geometry 4-device case lives in test_distributed.py)."""
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.int32(3),
            "flag": np.float64(1.5)}
    save(str(tmp_path), 3, tree, sharded=True)
    assert (tmp_path / "params_3.shard0.npz").exists()
    assert not (tmp_path / "params_3.npz").exists()
    meta = load_meta(str(tmp_path))
    assert meta["sharded"] is True and meta["num_processes"] == 1
    restored, step = restore(str(tmp_path),
                             jax.tree.map(jnp.zeros_like, tree))
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
