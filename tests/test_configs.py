"""Config/registry invariants: the 10 assigned architectures, layer counts,
parameter counts vs their public sizes, shape applicability rules."""
import pytest

from repro.configs.base import INPUT_SHAPES, shape_applicable
from repro.configs.registry import combos, get_config, list_archs

pytestmark = pytest.mark.tier0

EXPECTED_LAYERS = {
    "kimi-k2-1t-a32b": 61,
    "falcon-mamba-7b": 64,
    "gemma3-27b": 62,
    "jamba-v0.1-52b": 32,
    "seamless-m4t-large-v2": 24,
    "qwen2-moe-a2.7b": 24,
    "qwen3-1.7b": 28,
    "llama-3.2-vision-11b": 40,
    "phi3-medium-14b": 40,
    "h2o-danube-3-4b": 24,
}

# (total params, active params) in billions, with generous tolerance —
# these anchor the configs to the public model sizes.
EXPECTED_PARAMS_B = {
    "kimi-k2-1t-a32b": (1027, 34),
    "falcon-mamba-7b": (7.3, 7.3),
    "gemma3-27b": (28.4, 28.4),
    "jamba-v0.1-52b": (51.6, 12.1),
    "seamless-m4t-large-v2": (2.0, 2.0),
    "qwen2-moe-a2.7b": (14.3, 2.7),
    "qwen3-1.7b": (1.7, 1.7),
    "llama-3.2-vision-11b": (10.1, 10.1),
    "phi3-medium-14b": (14.7, 14.7),
    "h2o-danube-3-4b": (4.0, 4.0),
}


def test_ten_archs_registered():
    assert len(list_archs()) == 10
    assert set(list_archs()) == set(EXPECTED_LAYERS)


@pytest.mark.parametrize("arch", sorted(EXPECTED_LAYERS))
def test_layer_count(arch):
    assert get_config(arch).n_layers == EXPECTED_LAYERS[arch]


@pytest.mark.parametrize("arch", sorted(EXPECTED_PARAMS_B))
def test_param_counts(arch):
    cfg = get_config(arch)
    total, active = EXPECTED_PARAMS_B[arch]
    assert cfg.param_count() / 1e9 == pytest.approx(total, rel=0.12)
    assert cfg.active_param_count() / 1e9 == pytest.approx(active, rel=0.15)


@pytest.mark.parametrize("arch", sorted(EXPECTED_LAYERS))
def test_reduced_is_small(arch):
    r = get_config(arch).reduced()
    assert r.d_model <= 512
    assert len(r.layers) <= 16
    if r.moe is not None:
        assert r.moe.n_experts <= 4
    assert r.family == get_config(arch).family


def test_input_shapes():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288


def test_long_context_applicability():
    runs = {a for a, s, ok, _ in combos(include_inapplicable=True)
            if s == "long_500k" and ok}
    assert runs == {"falcon-mamba-7b", "jamba-v0.1-52b", "gemma3-27b",
                    "h2o-danube-3-4b"}
    n_total = len(list(combos(include_inapplicable=True)))
    assert n_total == 40


def test_padded_vocab_shards():
    for arch in list_archs():
        assert get_config(arch).padded_vocab % 16 == 0
