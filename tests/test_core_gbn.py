"""Ghost Batch Normalization (paper Algorithm 1) — unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.gbn import (_cascaded_ema, equal_weight_bn_apply, gbn_apply,
                            gbn_init)

pytestmark = [pytest.mark.tier1, pytest.mark.tier0]


def test_ghost_stats_match_small_batch_bn():
    """GBN over B=G*gbs must equal plain BN applied to each ghost slice."""
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (64, 8)) * 3.0 + 1.0
    params, state = gbn_init(8)
    y, _ = gbn_apply(params, state, x, ghost_batch_size=16)
    for g in range(4):
        sl = x[16 * g: 16 * (g + 1)]
        mu = sl.mean(0)
        var = sl.var(0)
        ref = (sl - mu) / jnp.sqrt(var + 1e-5)
        np.testing.assert_allclose(y[16 * g: 16 * (g + 1)], ref,
                                   rtol=1e-4, atol=1e-4)


def test_single_ghost_equals_plain_bn():
    rng = jax.random.PRNGKey(1)
    x = jax.random.normal(rng, (32, 4))
    params, state = gbn_init(4)
    y_g, _ = gbn_apply(params, state, x, ghost_batch_size=32)
    y_b, _ = equal_weight_bn_apply(params, state, x)
    np.testing.assert_allclose(y_g, y_b, rtol=1e-5, atol=1e-5)


def test_cascaded_ema_equals_sequential():
    """The closed form must equal folding ghosts in one at a time."""
    run = jnp.asarray([1.0, -2.0])
    ghosts = jnp.asarray([[0.5, 0.5], [2.0, -1.0], [3.0, 0.0]])
    eta = 0.1
    seq = run
    for g in ghosts:
        seq = (1 - eta) * seq + eta * g
    closed = _cascaded_ema(run, ghosts, eta)
    np.testing.assert_allclose(closed, seq, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(G=st.integers(1, 6), c=st.integers(1, 5), eta=st.floats(0.05, 0.5))
def test_cascaded_ema_equals_sequential_random(G, c, eta):
    """Closed form == explicit sequential fold for random stats/eta/G."""
    rng = jax.random.PRNGKey(G * 31 + c)
    run = jax.random.normal(rng, (c,)) * 3.0
    ghosts = jax.random.normal(jax.random.fold_in(rng, 1), (G, c)) * 2.0
    seq = run
    for g in ghosts:
        seq = (1 - eta) * seq + eta * g
    closed = _cascaded_ema(run, ghosts, eta)
    np.testing.assert_allclose(closed, seq, rtol=1e-5, atol=1e-6)


def test_first_batch_initializes_running_stats():
    """The very first training batch seeds the EMA with the batch moments
    (mean over ghosts, unbiased var) instead of decaying the zero/one init."""
    rng = jax.random.PRNGKey(7)
    x = jax.random.normal(rng, (64, 4)) * 2.0 + 3.0
    params, state = gbn_init(4)
    assert not bool(state["initialized"])
    _, s1 = gbn_apply(params, state, x, ghost_batch_size=16)
    assert bool(s1["initialized"])
    xg = np.asarray(x, np.float32).reshape(4, 16, 4)
    mu = xg.mean(axis=1)                              # (G, C)
    var_u = xg.var(axis=1) * (16 / 15)                # unbiased per ghost
    np.testing.assert_allclose(s1["mu_run"], mu.mean(0), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(s1["var_run"], var_u.mean(0), rtol=1e-5,
                               atol=1e-5)
    # the SECOND batch takes the cascaded-EMA branch
    x2 = jax.random.normal(jax.random.fold_in(rng, 1), (64, 4))
    _, s2 = gbn_apply(params, s1, x2, ghost_batch_size=16, momentum=0.1)
    xg2 = np.asarray(x2, np.float32).reshape(4, 16, 4)
    want = _cascaded_ema(s1["mu_run"], jnp.asarray(xg2.mean(axis=1)), 0.1)
    np.testing.assert_allclose(s2["mu_run"], want, rtol=1e-5, atol=1e-5)


def test_inference_uses_running_stats():
    rng = jax.random.PRNGKey(2)
    params, state = gbn_init(4)
    x = jax.random.normal(rng, (64, 4)) * 2.0 + 3.0
    for i in range(20):
        xi = jax.random.normal(jax.random.fold_in(rng, i), (64, 4)) * 2.0 + 3.0
        _, state = gbn_apply(params, state, xi, ghost_batch_size=16)
    y, state2 = gbn_apply(params, state, x, ghost_batch_size=16,
                          training=False)
    # running stats should have converged near the true moments
    np.testing.assert_allclose(state["mu_run"], 3.0, atol=0.5)
    np.testing.assert_allclose(jnp.sqrt(state["var_run"]), 2.0, atol=0.5)
    # inference must not update state
    assert state2 is state


def test_conv_layout_stats_over_spatial():
    """(B, H, W, C): statistics reduce over batch and spatial dims."""
    rng = jax.random.PRNGKey(3)
    x = jax.random.normal(rng, (16, 4, 4, 3))
    params, state = gbn_init(3)
    y, _ = gbn_apply(params, state, x, ghost_batch_size=8)
    first = x[:8].reshape(-1, 3)
    mu, var = first.mean(0), first.var(0)
    ref = (x[:8] - mu) / jnp.sqrt(var + 1e-5)
    np.testing.assert_allclose(y[:8], ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b_mult=st.integers(1, 4),
    gbs=st.sampled_from([4, 8, 16]),
    c=st.integers(1, 9),
    scale=st.floats(0.1, 10.0),
)
def test_property_normalized_moments(b_mult, gbs, c, scale):
    """Every ghost slice of the output has ~zero mean and ~unit variance."""
    B = gbs * b_mult
    x = scale * jax.random.normal(jax.random.PRNGKey(b_mult * 100 + c),
                                  (B, c)) + scale
    params, state = gbn_init(c)
    y, _ = gbn_apply(params, state, x, ghost_batch_size=gbs)
    yg = np.asarray(y).reshape(b_mult, gbs, c)
    np.testing.assert_allclose(yg.mean(axis=1), 0.0, atol=1e-3)
    np.testing.assert_allclose(yg.var(axis=1), 1.0, atol=2e-2)


@settings(max_examples=15, deadline=None)
@given(gbs=st.sampled_from([8, 16, 32]))
def test_property_invariant_to_affine_input(gbs):
    """GBN(a*x+b) == GBN(x) for per-batch affine maps (scale invariance)."""
    x = jax.random.normal(jax.random.PRNGKey(gbs), (32, 5))
    params, state = gbn_init(5)
    y1, _ = gbn_apply(params, state, x, ghost_batch_size=gbs)
    y2, _ = gbn_apply(params, state, 5.0 * x + 2.0, ghost_batch_size=gbs)
    np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-3)
