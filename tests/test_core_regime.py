"""LR scaling (paper eq. 7), Regime Adaptation (paper §5), noise matching
(paper §4) — unit + property tests."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.large_batch import LargeBatchConfig, presets
from repro.core.lr_scaling import noise_sigma, scale_lr
from repro.core.noise import ghost_noise_grads, multiplicative_noise_grads
from repro.core.regime import Regime, adapt_regime

pytestmark = pytest.mark.tier0


def test_sqrt_scaling():
    assert scale_lr(0.1, 4096, 128, "sqrt") == pytest.approx(
        0.1 * math.sqrt(32))
    assert scale_lr(0.1, 4096, 128, "linear") == pytest.approx(0.1 * 32)
    assert scale_lr(0.1, 4096, 128, "none") == 0.1


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 16))
def test_property_update_covariance_constant_under_sqrt(m):
    """cov(eta*ghat) is ~constant in M when eta ~ sqrt(M) (paper eq. 6-7).

    Simulated with per-sample gradients g_n ~ N(mu, I): ghat over a batch of
    size M has cov = cov_g / M; sqrt scaling multiplies by M -> constant."""
    rng = np.random.RandomState(m)
    N = 4096
    g = rng.randn(N, 3)
    M = 16 * m
    eta = scale_lr(1.0, M, 16, "sqrt")
    steps = np.array([eta * g[rng.randint(0, N, M)].mean(0)
                      for _ in range(400)])
    var = steps.var(axis=0).mean()
    # reference at M=16, eta=1
    steps0 = np.array([g[rng.randint(0, N, 16)].mean(0) for _ in range(400)])
    var0 = steps0.var(axis=0).mean()
    assert var == pytest.approx(var0, rel=0.35)


def test_regime_adaptation_step_budget():
    """RA keeps the step count; no-RA keeps the epoch budget."""
    small = Regime(base_lr=0.1, total_steps=1000, drop_every=300)
    ra = adapt_regime(small, batch_size=4096, base_batch_size=128,
                      regime_adaptation=True)
    assert ra.total_steps == 1000
    assert ra.base_lr == pytest.approx(0.1 * math.sqrt(32))
    no_ra = adapt_regime(small, batch_size=4096, base_batch_size=128,
                         regime_adaptation=False)
    assert no_ra.total_steps == pytest.approx(1000 / 32, abs=1)


def test_lr_at_decays():
    r = Regime(base_lr=1.0, total_steps=100, drop_every=10, drop_factor=0.5)
    assert float(r.lr_at(0)) == 1.0
    assert float(r.lr_at(10)) == 0.5
    assert float(r.lr_at(25)) == 0.25
    w = Regime(base_lr=1.0, total_steps=100, drop_every=50, warmup_steps=10)
    assert float(w.lr_at(0)) == pytest.approx(0.1)
    assert float(w.lr_at(9)) == pytest.approx(1.0)


def test_noise_sigma_scaling():
    # sigma^2 ∝ M - matching the covariance of the small-batch estimate
    assert noise_sigma(128, 128) == 0.0
    assert noise_sigma(512, 128, base_sigma=1.0) == pytest.approx(
        math.sqrt(3.0))


def test_presets_are_the_table1_columns():
    p = presets(4096, 128)
    assert set(p) == {"SB", "LB", "LB+LR", "LB+LR+GBN", "LB+LR+GBN+RA"}
    assert p["LB"].lr_rule == "none" and not p["LB"].use_gbn
    assert p["LB+LR"].lr_rule == "sqrt"
    assert p["LB+LR+GBN"].use_gbn
    assert p["LB+LR+GBN+RA"].regime_adaptation


def test_multiplicative_noise_unbiased_and_scaled():
    grads = {"w": jnp.ones((2000,)), "b": 2.0 * jnp.ones((500,))}
    sigma = 0.5
    noisy = multiplicative_noise_grads(jax.random.PRNGKey(0), grads, sigma)
    w = np.asarray(noisy["w"])
    assert w.mean() == pytest.approx(1.0, abs=0.05)
    assert w.std() == pytest.approx(sigma, rel=0.15)
    b = np.asarray(noisy["b"])
    assert b.std() == pytest.approx(2.0 * sigma, rel=0.2)


def test_ghost_noise_matches_covariance():
    """Per-section noise with var G*sigma^2 averaged over G sections gives a
    mean with variance sigma^2 (section-granular matching). The per-section
    z is shared across a section's elements, so the variance is measured
    across independent draws."""
    G = 8
    sec = jnp.ones((G, 4))
    sigma = 0.3
    draws = np.array([
        float(ghost_noise_grads(jax.random.PRNGKey(i), {"g": sec},
                                sigma)["g"][0])
        for i in range(400)
    ])
    assert draws.mean() == pytest.approx(1.0, abs=0.05)
    assert draws.std() == pytest.approx(sigma, rel=0.2)


def test_large_batch_config_wiring():
    lb = LargeBatchConfig(batch_size=2048, base_batch_size=128,
                          lr_rule="sqrt", ghost_noise=1.0)
    assert lb.batch_ratio == 16
    assert lb.effective_lr(0.1) == pytest.approx(0.4)
    assert lb.effective_noise_sigma() == pytest.approx(math.sqrt(15.0))
    small = Regime(base_lr=0.1, total_steps=100, drop_every=30)
    r = lb.build_regime(small)
    assert r.total_steps == 100 and r.base_lr == pytest.approx(0.4)
