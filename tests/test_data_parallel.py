"""shard_map data-parallel training: per-device ghost statistics, gradients
as the only collective.

The single-device-mesh tests run in-process; the multi-device tests run in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the
conftest forbids forcing the device count in-process — smoke tests must keep
seeing the single real device)."""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import F1_MNIST
from repro.core import LargeBatchConfig, Regime
from repro.launch.mesh import make_data_mesh
from repro.models.cnn import model_fns
from repro.optim import sgd
from repro.train.data_parallel import dp_gbn_forward, make_dp_vision_train_step
from repro.train.trainer import make_vision_train_step

pytestmark = pytest.mark.tier1

REPO = Path(__file__).resolve().parent.parent


def _setup(batch=64, ghost=16):
    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(32,), ghost_batch_size=ghost)
    lb = LargeBatchConfig(batch_size=batch, base_batch_size=batch,
                          ghost_batch_size=ghost)
    regime = Regime(base_lr=0.1, total_steps=10, drop_every=10)
    init_fn, apply_fn = model_fns(cfg)
    params, bn = init_fn(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (batch, 8, 8, 1))
    y = jax.random.randint(jax.random.PRNGKey(3), (batch,), 0, 10)
    return cfg, lb, regime, apply_fn, params, bn, x, y


def test_dp_step_single_device_mesh_matches_trainer():
    """On a 1-device mesh the shard_map step must reproduce the plain step
    exactly (same ghosts, one trivial psum)."""
    mesh = make_data_mesh(1)
    cfg, lb, regime, apply_fn, params, bn, x, y = _setup()
    opt = sgd.init(params)
    s1 = jax.jit(make_vision_train_step(apply_fn, cfg, lb, regime))
    sd = jax.jit(make_dp_vision_train_step(apply_fn, cfg, lb, regime, mesh))
    p1, b1, _, m1 = s1(params, bn, opt, x, y, jnp.int32(0),
                       jax.random.PRNGKey(4))
    pd, bd, _, md = sd(params, bn, opt, x, y, jnp.int32(0),
                       jax.random.PRNGKey(4))
    np.testing.assert_allclose(float(m1["loss"]), float(md["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pd)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(b1), jax.tree.leaves(bd)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_dp_gbn_forward_single_device_matches_core():
    mesh = make_data_mesh(1)
    from repro.core.gbn import gbn_apply, gbn_init
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 6)) * 2 + 1
    params, state = gbn_init(6)
    y, mu, var = dp_gbn_forward(x, params["gamma"], params["beta"], mesh,
                                ghost_batch_size=8)
    want, _ = gbn_apply(params, state, x, ghost_batch_size=8)
    np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)
    assert mu.shape == (4, 6)


def test_dp_gbn_forward_rejects_ragged_batch():
    mesh = make_data_mesh(1)
    x = jnp.zeros((30, 4))
    with pytest.raises(ValueError):
        dp_gbn_forward(x, jnp.ones((4,)), jnp.zeros((4,)), mesh,
                       ghost_batch_size=16)


MULTIDEV_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 4, jax.device_count()
    from repro.configs.paper_models import F1_MNIST
    from repro.core import LargeBatchConfig, Regime
    from repro.launch.mesh import make_data_mesh
    from repro.models.cnn import model_fns
    from repro.optim import sgd
    from repro.train.data_parallel import (dp_gbn_forward,
                                           make_dp_vision_train_step)
    from repro.train.trainer import make_vision_train_step

    mesh = make_data_mesh()

    # --- per-device ghost statistics: 4 devices x 2 local ghosts of 8 rows.
    # Each stats row must equal the plain mean/var of that device's slice —
    # i.e. the ghost partitioning IS the device partitioning.
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 8)) * 2 + 1
    y, mu, var = dp_gbn_forward(x, jnp.ones((8,)), jnp.zeros((8,)), mesh,
                                ghost_batch_size=8)
    assert mu.shape == (8, 8), mu.shape
    xs = np.asarray(x, np.float32)
    for g in range(8):
        sl = xs[8 * g: 8 * (g + 1)]
        np.testing.assert_allclose(np.asarray(mu[g]), sl.mean(0),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(var[g]), sl.var(0),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(y[8 * g: 8 * (g + 1)]),
            (sl - sl.mean(0)) / np.sqrt(sl.var(0) + 1e-5),
            rtol=1e-4, atol=1e-4)

    # --- kernel path inside shard_map: same stats
    yk, muk, vark = dp_gbn_forward(x, jnp.ones((8,)), jnp.zeros((8,)), mesh,
                                   ghost_batch_size=8, use_kernels=True)
    np.testing.assert_allclose(np.asarray(muk), np.asarray(mu),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(y),
                               rtol=1e-4, atol=1e-4)

    # --- the sharded step takes the same step as the single-device trainer
    # (identical ghost boundaries; grads pmean == global mean grad)
    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(32,), ghost_batch_size=8)
    lb = LargeBatchConfig(batch_size=64, base_batch_size=64,
                          ghost_batch_size=8)
    regime = Regime(base_lr=0.1, total_steps=10, drop_every=10)
    init_fn, apply_fn = model_fns(cfg)
    params, bn = init_fn(jax.random.PRNGKey(1), cfg)
    opt = sgd.init(params)
    xb = jax.random.normal(jax.random.PRNGKey(2), (64, 8, 8, 1))
    yb = jax.random.randint(jax.random.PRNGKey(3), (64,), 0, 10)
    s1 = jax.jit(make_vision_train_step(apply_fn, cfg, lb, regime))
    sd = jax.jit(make_dp_vision_train_step(apply_fn, cfg, lb, regime, mesh))
    p1, _, _, m1 = s1(params, bn, opt, xb, yb, jnp.int32(0),
                      jax.random.PRNGKey(4))
    pd, _, _, md = sd(params, bn, opt, xb, yb, jnp.int32(0),
                      jax.random.PRNGKey(4))
    np.testing.assert_allclose(float(m1["loss"]), float(md["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    print("MULTIDEV_OK")
""")


def _run_multidev(script: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          cwd=str(REPO), timeout=600)


def test_dp_multi_device_subprocess():
    """≥2 simulated devices: per-device ghost stats + step equivalence."""
    proc = _run_multidev(MULTIDEV_SCRIPT)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "MULTIDEV_OK" in proc.stdout


RUNNER_MESH_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax
    assert jax.device_count() == 4, jax.device_count()
    from repro.configs.paper_models import F1_MNIST
    from repro.core import LargeBatchConfig
    from repro.experiments.runner import _mesh_for, run_one
    from repro.experiments.spec import DataSpec, RunSpec

    model = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                                hidden_sizes=(32,), ghost_batch_size=16)
    spec = RunSpec(name="dp", method="LB", model=model,
                   data=DataSpec(seed=0, n_train=512, n_test=128,
                                 input_shape=(8, 8, 1)),
                   lb=LargeBatchConfig(batch_size=128, base_batch_size=128,
                                       ghost_batch_size=16),
                   base_lr=0.08, total_steps=6, drop_every=3, seed=3,
                   use_mesh=True, track_diffusion=False)
    mesh = _mesh_for(spec)
    assert mesh is not None and mesh.shape["data"] == 4, mesh
    rec = run_one(spec)
    assert 0.0 <= rec["final_acc"] <= 1.0
    # batch 72 does not split 4 ways into whole 16-row ghosts -> no mesh
    bad = dataclasses.replace(
        spec, lb=LargeBatchConfig(batch_size=72, base_batch_size=72,
                                  ghost_batch_size=16))
    assert _mesh_for(bad) is None
    print("RUNNER_MESH_OK")
""")


def test_sweep_runner_fans_over_mesh_subprocess():
    """experiments.runner picks up the ("data",) mesh for use_mesh specs
    whose batch geometry shards evenly, and falls back otherwise."""
    proc = _run_multidev(RUNNER_MESH_SCRIPT)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "RUNNER_MESH_OK" in proc.stdout
