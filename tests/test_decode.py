"""Decode/serving correctness: step-by-step decode must reproduce the full
forward logits (dropless MoE), ring caches must window correctly, and
generate() must be shape-stable.

The long-prompt portion of every case rides the FUSED prefill
(``T.prefill_forward`` — one full-sequence forward that scatters K/V into
the cache), so the python-level token loop only covers the trailing decode
steps; the stepwise-vs-fused prefill cross-check lives in
tests/test_serving.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.models import transformer as T
from repro.serving import generate

S = 20
TAIL = 4          # decode steps taken one-by-one after the fused prefill


def _cfg(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    if cfg.moe is not None:
        # dropless so prefill and decode route identically (see moe.py notes)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch):
    """Fused prefill of the first S-TAIL tokens, then token-at-a-time decode
    of the tail: every compared position must reproduce the full forward's
    logits (late positions attend a cache whose entries were written by the
    fused scatter — prefill/decode agreement is load-bearing here)."""
    cfg = _cfg(arch)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    mem = None
    if cfg.encoder is not None:
        batch["frames"] = 0.1 * jax.random.normal(
            rng, (2, S // cfg.encoder.frame_ratio, cfg.encoder.d_model))
        mem = T.get_memory(params, cfg, batch)
    if cfg.vision is not None:
        batch["image_embeds"] = 0.1 * jax.random.normal(
            rng, (2, cfg.vision.n_image_tokens, cfg.d_model))
        mem = T.get_memory(params, cfg, batch)
    full, _ = T.forward(params, cfg, toks, memory=mem)
    cache = T.init_cache(cfg, 2, S, memory_len=mem.shape[1] if mem is not None
                         else 0, dtype=jnp.float32)
    if mem is not None:
        cache = T.build_cross_cache(params, cfg, mem, cache)
    P = S - TAIL
    lg, cache = T.prefill_forward(params, cfg, toks[:, :P], cache)
    errs = [float(jnp.abs(lg[:, 0] - full[:, P - 1]).max())]
    step = jax.jit(lambda p_, tk, c, t: T.decode_step(p_, cfg, tk, c, t))
    for t in range(P, S):
        lg, cache = step(params, toks[:, t][:, None], cache, jnp.int32(t))
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-4, (arch, max(errs))


def test_swa_ring_cache_equals_full_mask():
    """h2o-danube (SWA): ring cache of window slots == full attention with a
    window mask, even past the wrap-around point. The fused prefill covers
    the pre-wrap fill AND the wrapped scatter (prompt 24 > ring 16); the
    stepwise tail crosses more wrap boundaries."""
    cfg = _cfg("h2o-danube-3-4b")          # reduced window = 16
    assert cfg.sliding_window == 16
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    n, P = 40, 24                           # P > window: prefill wraps
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, n), 0,
                              cfg.vocab_size)
    full, _ = T.forward(params, cfg, toks)
    cache = T.init_cache(cfg, 1, n, dtype=jnp.float32)
    lg, cache = T.prefill_forward(params, cfg, toks[:, :P], cache)
    assert float(jnp.abs(lg[:, 0] - full[:, P - 1]).max()) < 5e-4
    step = jax.jit(lambda p_, tk, c, t: T.decode_step(p_, cfg, tk, c, t))
    for t in range(P, n):
        lg, cache = step(params, toks[:, t][:, None], cache, jnp.int32(t))
        err = float(jnp.abs(lg[:, 0] - full[:, t]).max())
        assert err < 5e-4, (t, err)


def test_generate_rejects_shallow_cache():
    """max_len < prompt + max_new_tokens would silently write decode steps
    past the cache depth — it must raise instead of corrupting the cache
    (including the explicit max_len=0 that `max_len or ...` used to
    swallow)."""
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 cfg.vocab_size)
    with pytest.raises(ValueError, match="cache depth"):
        generate(params, cfg, prompts, max_new_tokens=8, max_len=10)
    with pytest.raises(ValueError, match="cache depth"):
        generate(params, cfg, prompts, max_new_tokens=8, max_len=0)
    # exactly-deep cache is fine
    out = generate(params, cfg, prompts, max_new_tokens=4, max_len=10)
    assert out.shape == (2, 10)


def test_generate_greedy_deterministic():
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                 cfg.vocab_size)
    out1 = generate(params, cfg, prompts, max_new_tokens=8)
    out2 = generate(params, cfg, prompts, max_new_tokens=8)
    assert out1.shape == (3, 14)
    np.testing.assert_array_equal(out1, out2)
    np.testing.assert_array_equal(out1[:, :6], prompts)
    assert (out1 < cfg.vocab_size).all()
