"""Ultra-slow diffusion instrumentation (paper §3 / Fig. 2 / Appendix B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.diffusion import (DiffusionTracker, fit_log_diffusion,
                                  fit_power_diffusion,
                                  random_potential_probe, weight_distance)

pytestmark = pytest.mark.tier0


def test_weight_distance():
    p0 = {"a": jnp.zeros((3,)), "b": jnp.zeros((4,))}
    p1 = {"a": jnp.asarray([3.0, 0.0, 0.0]), "b": jnp.full((4,), 2.0)}
    assert float(weight_distance(p1, p0)) == pytest.approx(5.0)


def test_log_fit_recovers_slope():
    t = np.arange(1, 200)
    d = 2.5 * np.log(t) + 0.3
    fit = fit_log_diffusion(t, d)
    assert fit["slope"] == pytest.approx(2.5, rel=1e-6)
    assert fit["r2"] == pytest.approx(1.0, abs=1e-9)


def test_log_vs_power_discrimination():
    """Log-growth data: log fit r2 ~ 1, power fit visibly worse, exponent
    far below 0.5 (the paper's ultra-slow vs standard diffusion contrast)."""
    t = np.arange(2, 500)
    d = np.log(t)
    log_fit = fit_log_diffusion(t, d)
    pow_fit = fit_power_diffusion(t, d)
    assert log_fit["r2"] > 0.999
    assert pow_fit["power"] < 0.45


def test_sqrt_data_prefers_power_law():
    t = np.arange(2, 500)
    d = np.sqrt(t)
    pow_fit = fit_power_diffusion(t, d)
    assert pow_fit["power"] == pytest.approx(0.5, abs=1e-6)


def test_burn_in_filters_early_points():
    """Points with t < burn_in are excluded: corrupt the early steps and the
    fit still recovers the exact law from the tail."""
    t = np.arange(1, 200)
    d = 2.5 * np.log(t) + 0.3
    d[:10] = 100.0                        # transient garbage before burn-in
    fit = fit_log_diffusion(t, d, burn_in=11)
    assert fit["slope"] == pytest.approx(2.5, rel=1e-6)
    assert fit["r2"] == pytest.approx(1.0, abs=1e-9)
    corrupted = fit_log_diffusion(t, d, burn_in=1)
    assert abs(corrupted["slope"] - 2.5) > 0.5


def test_too_few_points_is_nan():
    """< 3 surviving points -> NaN fits, not a crash (both laws)."""
    lf = fit_log_diffusion([1, 2], [0.1, 0.2])
    assert np.isnan(lf["slope"]) and np.isnan(lf["r2"])
    lf = fit_log_diffusion(np.arange(1, 100), np.ones(99), burn_in=98)
    assert np.isnan(lf["slope"])
    pf = fit_power_diffusion([5, 6], [0.1, 0.2])
    assert np.isnan(pf["power"]) and np.isnan(pf["r2"])
    # power fit also drops d <= 0 rows before the log
    pf = fit_power_diffusion([1, 2, 3, 4], [0.0, 0.0, 0.1, 0.2])
    assert np.isnan(pf["power"])


def test_random_potential_probe_smoke():
    """Tiny-sample probe returns aligned, finite (distance, loss_std) bins."""
    rng = jax.random.PRNGKey(2)
    w0 = {"w": jax.random.normal(rng, (20,))}
    out = random_potential_probe(lambda p: jnp.sum(p["w"] ** 2), w0, rng,
                                 n_samples=40, max_radius=4.0, n_bins=4)
    assert out["distance"].shape == out["loss_std"].shape
    assert len(out["distance"]) >= 1
    assert np.all(np.isfinite(out["loss_std"]))


def test_tracker_records():
    p0 = {"w": jnp.zeros((2,))}
    tr = DiffusionTracker(p0)
    for t in range(1, 6):
        tr.record(t, {"w": jnp.full((2,), float(t))})
    assert len(tr.steps) == 5
    assert tr.distances[-1] == pytest.approx(5 * np.sqrt(2), rel=1e-5)


def test_tracker_record_is_lazy_and_batches_sync():
    """record() keeps the distance on device; the host floats materialize
    in one batch when .distances is first read, and load() restores a
    checkpointed series."""
    tr = DiffusionTracker({"w": jnp.zeros((3,))})
    for t in range(1, 4):
        d = tr.record(t, {"w": jnp.full((3,), float(t))})
        assert isinstance(d, jax.Array)        # no float() per call
    assert len(tr._pending) == 3 and not tr._host
    dists = tr.distances
    assert not tr._pending and len(dists) == 3
    assert dists[1] == pytest.approx(2 * np.sqrt(3), rel=1e-6)
    tr2 = DiffusionTracker({"w": jnp.zeros((3,))})
    tr2.load(tr.steps, tr.distances)
    assert tr2.log_fit() == tr.log_fit()


def test_random_potential_probe_linear_for_quadratic_loss():
    """For L(w) = ||w||^2 the probe's loss-std grows ~ linearly in distance
    for radii >> ||w0|| — the alpha=2 signature the paper reports."""
    rng = jax.random.PRNGKey(0)
    w0 = {"w": 0.01 * jax.random.normal(rng, (50,))}

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    out = random_potential_probe(loss, w0, rng, n_samples=120,
                                 max_radius=8.0, n_bins=6)
    d, s = out["distance"], out["loss_std"]
    assert len(d) >= 4
    # monotone increasing and superlinear-ish in d (std ~ d^2 here exactly,
    # since L is deterministic quadratic: |L(w)-L(w0)| ~ z^2)
    assert np.all(np.diff(s) > 0)
