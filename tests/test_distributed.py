"""Multi-process execution: jax.distributed bring-up, the pod mesh, the
per-shard checkpoint layout, and sweep sharding across processes.

The CPU backend can build process-spanning meshes and create/checkpoint
global arrays on them, but cannot run a computation across processes
("Multiprocess computations aren't implemented on the CPU backend") — so
the 2-process test computes on each host's local mesh and uses the pod
mesh for global placement + sharded checkpointing, which is exactly the
split `launch.mesh` documents for CPU-backend multi-process runs.

Every subprocess here runs with JAX_PLATFORMS=cpu pinned and an explicit
wait timeout: a hung coordinator handshake fails the test loudly instead
of wedging the suite.
"""
import json
import os
import socket
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.tier1

REPO = Path(__file__).resolve().parent.parent

# generous for a cold jax import + 2-run sweep; a hung distributed init
# would otherwise block forever
SUBPROC_TIMEOUT_S = 600


def _env(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # pin the platform: without it each process burns ~minutes probing for
    # TPU metadata before falling back to CPU
    env["JAX_PLATFORMS"] = "cpu"
    return env


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


CROSS_GEOMETRY_SCRIPT = textwrap.dedent("""
    import glob, os, sys, tempfile
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 4, jax.device_count()
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import load_meta, restore, save
    from repro.launch.mesh import make_2d_mesh, make_data_mesh

    mesh = make_2d_mesh()
    assert dict(mesh.shape) == {"data": 2, "model": 2}, mesh
    w = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    tree = {
        "w": jax.device_put(w, NamedSharding(mesh, P("data", "model"))),
        "b": jax.device_put(jnp.arange(4.0), NamedSharding(mesh, P())),
        "step": jnp.int32(3),
    }
    d = sys.argv[1]
    save(d, 3, tree, sharded=True)
    assert glob.glob(os.path.join(d, "params_3.shard0.npz"))
    meta = load_meta(d)
    assert meta["sharded"] is True and meta["num_processes"] == 1

    # restore onto a DIFFERENT geometry: the 1-D (data=4,) mesh
    dmesh = make_data_mesh()
    assert dict(dmesh.shape) == {"data": 4}, dmesh
    tmpl = jax.tree.map(jnp.zeros_like, tree)
    sh = {"w": NamedSharding(dmesh, P("data", None)),
          "b": NamedSharding(dmesh, P()),
          "step": NamedSharding(dmesh, P())}
    restored, step = restore(d, tmpl, shardings=sh)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert restored["w"].sharding.spec == P("data", None)
    print("CKPT_GEO_OK")
""")


def test_ckpt_cross_geometry_subprocess(tmp_path):
    """A checkpoint saved sharded on a (2 data, 2 model) mesh restores
    bit-exact onto a (4,)-data mesh — the shard entries carry their global
    index, so restore needs no knowledge of the saving geometry."""
    proc = subprocess.run(
        [sys.executable, "-c", CROSS_GEOMETRY_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, env=_env(4), cwd=str(REPO),
        timeout=SUBPROC_TIMEOUT_S)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "CKPT_GEO_OK" in proc.stdout


DISTRIBUTED_SCRIPT = textwrap.dedent("""
    import dataclasses, sys
    import numpy as np
    coordinator, pid, workdir = sys.argv[1], int(sys.argv[2]), sys.argv[3]

    from repro.launch.mesh import (POD_AXIS, global_array, init_distributed,
                                   make_local_mesh, make_pod_mesh)
    init_distributed(coordinator_address=coordinator, num_processes=2,
                     process_id=pid)
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    assert jax.process_count() == 2, jax.process_count()
    assert jax.process_index() == pid
    assert jax.device_count() == 4 and len(jax.local_devices()) == 2

    # pod mesh spans both processes; shard a global array over the pod
    # axis and checkpoint it — each process writes ONLY its own rows
    pod = make_pod_mesh()
    assert dict(pod.shape) == {"pod": 2, "data": 2, "model": 1}, pod
    full = np.arange(4 * 3, dtype=np.float32).reshape(4, 3)
    g = global_array(pod, full, P(POD_AXIS, None))
    assert len(g.addressable_shards) == 2           # this host's rows only
    from repro.checkpoint import save
    save(workdir + "/ckpt", 1, {"w": g}, sharded=True)

    # compute happens on the per-process local mesh (CPU backend cannot
    # run cross-process computations): one LM train step end to end
    from repro.configs.registry import get_config
    from repro.core import LargeBatchConfig, Regime
    from repro.models import transformer as T
    from repro.optim import sgd
    from repro.train.trainer import make_lm_train_step
    local = make_local_mesh()
    assert dict(local.shape) == {"data": 2, "model": 1}, local
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32", vocab_size=128)
    lb = LargeBatchConfig(batch_size=4, base_batch_size=4, grad_clip=1.0)
    regime = Regime(base_lr=0.02, total_steps=4, drop_every=2)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_lm_train_step(cfg, lb, regime, mesh=local,
                                      params=params, fsdp=True))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    p, o, m = step(params, sgd.init(params), {"tokens": toks},
                   jnp.int32(0), jax.random.PRNGKey(2))
    assert float(m["loss"]) > 0

    # sweep sharding: shard auto-detects (process_index, process_count);
    # both shards append to the same shared store
    from repro.experiments.registry import get_sweep
    from repro.experiments.runner import run_sweep
    sweep = get_sweep("diffusion", steps=4, batches=(32, 128))
    recs = run_sweep(sweep, workdir + "/sweep",
                     log_fn=lambda s: print(f"[p{pid}] {s}"))
    print(f"P{pid}_RAN_{len(recs)}")
    print(f"P{pid}_OK")
""")


def test_two_process_train_ckpt_sweep(tmp_path):
    """2-process jax.distributed on CPU: pod mesh over processes, per-shard
    checkpoint written by each process, one FSDP train step on each host's
    local mesh, and a sweep sharded by run_id hash across the processes —
    the shared store ends up with the full union of runs."""
    port = _free_port()
    coord = f"127.0.0.1:{port}"
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", DISTRIBUTED_SCRIPT, coord, str(i),
             str(tmp_path)],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
            env=_env(2), cwd=str(REPO))
        for i in range(2)]
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=SUBPROC_TIMEOUT_S)
            outs.append(out)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    for i, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"process {i}:\n{out}"
        assert f"P{i}_OK" in out, out

    # both processes wrote their own checkpoint shard; assembly recovers
    # the full pod-sharded array
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import restore
    ckpt = tmp_path / "ckpt"
    assert (ckpt / "params_1.shard0.npz").exists()
    assert (ckpt / "params_1.shard1.npz").exists()
    full = np.arange(4 * 3, dtype=np.float32).reshape(4, 3)
    restored, step = restore(str(ckpt), {"w": jnp.zeros((4, 3))})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), full)

    # the two sweep shards cover the whole sweep exactly once
    from repro.experiments.registry import get_sweep
    all_ids = {s.run_id for s in
               get_sweep("diffusion", steps=4, batches=(32, 128)).expand()}
    records = [json.loads(line) for line in
               (tmp_path / "sweep" / "diffusion" / "records.jsonl")
               .read_text().splitlines()]
    got = [r["run_id"] for r in records]
    assert sorted(got) == sorted(all_ids), (got, all_ids)
