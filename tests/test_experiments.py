"""Experiment subsystem: spec expansion + stable IDs, metrics store and
aggregation, resumable runner (run-granular skip AND mid-run checkpoint
resume determinism), batch-size-increase schedule, mesh gating."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs.paper_models import F1_MNIST
from repro.core.large_batch import LargeBatchConfig
from repro.core.regime import BatchSchedule, Regime, batch_size_increase
from repro.experiments import MetricsLogger, ResultsStore
from repro.experiments import metrics as M
from repro.experiments.runner import run_one, run_sweep
from repro.experiments.spec import (DataSpec, RunSpec, SweepSpec,
                                    replace_path)

pytestmark = [pytest.mark.tier1, pytest.mark.tier0]


def _tiny_spec(**kw) -> RunSpec:
    model = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                                hidden_sizes=(32,), ghost_batch_size=16)
    base = dict(
        name="tiny", method="SB", model=model,
        data=DataSpec(seed=0, n_train=512, n_test=128,
                      input_shape=(8, 8, 1)),
        lb=LargeBatchConfig(batch_size=32, base_batch_size=32,
                            ghost_batch_size=16),
        base_lr=0.08, total_steps=30, drop_every=10, seed=3)
    base.update(kw)
    return RunSpec(**base)


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------


def test_run_id_stable_and_content_sensitive():
    a, b = _tiny_spec(), _tiny_spec()
    assert a.run_id == b.run_id
    assert a.run_id != _tiny_spec(seed=4).run_id
    assert a.run_id != replace_path(a, "lb.batch_size", 64).run_id


def test_spec_json_roundtrip():
    spec = _tiny_spec(batch_schedule=BatchSchedule(
        base_batch=32, max_batch=128, grow_every=10))
    again = RunSpec.from_json(spec.to_json())
    assert again == spec
    assert again.run_id == spec.run_id


def test_sweep_expansion_order_and_grid():
    sweep = SweepSpec(
        name="s", base=_tiny_spec(),
        methods={"SB": {}, "LB": {"lb.batch_size": 128}},
        grid={"base_lr": [0.05, 0.1]}, seeds=(0, 1))
    specs = sweep.expand()
    assert len(specs) == 2 * 2 * 2
    assert [s.method for s in specs[:4]] == ["SB"] * 4
    assert specs[4].lb.batch_size == 128
    assert {s.seed for s in specs} == {0, 1}
    assert len({s.run_id for s in specs}) == len(specs)
    # deterministic re-expansion
    assert [s.run_id for s in sweep.expand()] == [s.run_id for s in specs]


def test_regime_construction_matches_lb():
    spec = _tiny_spec(lb=LargeBatchConfig(batch_size=128,
                                          base_batch_size=32,
                                          regime_adaptation=False))
    # no RA: step budget shrinks by the batch ratio
    assert spec.regime().total_steps == pytest.approx(30 / 4, abs=1)
    sched_spec = _tiny_spec(batch_schedule=BatchSchedule(
        base_batch=32, max_batch=128, grow_every=10))
    r = sched_spec.regime()
    assert r.total_steps == 30
    assert float(r.lr_at(0)) == float(r.lr_at(29))     # constant LR


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metrics_logger_roundtrip_and_history():
    lg = MetricsLogger()
    lg.log(0, val_acc=0.1, train_loss=2.0)
    lg.log(10, val_acc=0.5, train_loss=1.0)
    lg.set_series("distance", [1, 5], [0.1, 0.4])
    again = MetricsLogger.from_json(lg.to_json())
    assert again.series("val_acc") == ([0, 10], [0.1, 0.5])
    assert again.max("val_acc") == 0.5
    h = again.to_history()
    assert h["steps"] == [0, 10] and h["dist_steps"] == [1, 5]
    assert h["distance"] == [0.1, 0.4]


def test_results_store_append_only(tmp_path):
    store = ResultsStore(str(tmp_path))
    store.append({"run_id": "a", "x": 1})
    store.append({"run_id": "b", "x": 2})
    assert [r["run_id"] for r in store.records()] == ["a", "b"]
    assert store.completed_run_ids() == {"a", "b"}
    assert ResultsStore(str(tmp_path / "empty")).records() == []


def test_table1_view_aggregates_seeds():
    recs = [
        {"method": "SB", "batch_size": 32, "seed": s, "steps": 100,
         "final_acc": 0.8 + 0.02 * s, "train_acc": 0.9} for s in (0, 1)
    ] + [{"method": "LB", "batch_size": 1024, "seed": 0, "steps": 3,
          "final_acc": 0.5, "train_acc": 0.6}]
    rows = M.table1_view(recs)
    assert [r["method"] for r in rows] == ["SB", "LB"]
    sb = rows[0]
    assert sb["n_seeds"] == 2
    assert sb["val_acc_mean"] == pytest.approx(0.81)
    assert sb["val_acc_std"] == pytest.approx(0.01)
    out = M.format_table1(rows)
    assert "vs SB" in out
    # records from a different-scale invocation stay in their own row
    # instead of being averaged in
    rows2 = M.table1_view(recs + [{"method": "SB", "batch_size": 32,
                                   "seed": 0, "steps": 2400,
                                   "final_acc": 0.9, "train_acc": 0.95}])
    sb_rows = [r for r in rows2 if r["method"] == "SB"]
    assert len(sb_rows) == 2
    assert {r["steps"] for r in sb_rows} == {100, 2400}


def test_diffusion_view_refits_stored_series():
    t = list(range(1, 64))
    d = [2.0 * np.log(x) + 0.5 for x in t]
    rec = {"method": "walk", "batch_size": 64, "seed": 0,
           "metrics": {"distance": [t, d]}}
    row = M.diffusion_view([rec], burn_in=2)[0]
    assert row["log_fit"]["slope"] == pytest.approx(2.0, rel=1e-6)
    assert row["log_fit"]["r2"] > 0.999


# ---------------------------------------------------------------------------
# batch-size-increase schedule
# ---------------------------------------------------------------------------


def test_batch_schedule_growth_and_rounding():
    sched = BatchSchedule(base_batch=32, max_batch=1024, grow_every=100,
                          grow_factor=5.0, round_to=16)
    assert sched.batch_at(0) == 32
    assert sched.batch_at(99) == 32
    assert sched.batch_at(100) == 160
    assert sched.batch_at(200) == 800
    assert sched.batch_at(300) == 1024          # capped
    assert all(b % 16 == 0 for b in sched.phases(400))
    assert sched.phases(400) == [32, 160, 800, 1024]


def test_batch_schedule_cap_rounds_down():
    """Regression: a max_batch that is NOT a round_to multiple used to win
    over rounding at the cap, returning an indivisible batch (e.g. 1000
    with round_to=16) that breaks ghost-batch splitting. The cap itself is
    rounded DOWN first."""
    sched = BatchSchedule(base_batch=32, max_batch=1000, grow_every=100,
                          grow_factor=5.0, round_to=16)
    assert sched.batch_at(300) == 992            # not 1000
    assert all(b % 16 == 0 for b in sched.phases(500))


def test_batch_schedule_validates_round_to():
    with pytest.raises(ValueError, match="round_to"):
        BatchSchedule(base_batch=32, max_batch=1024, grow_every=100,
                      round_to=0)
    with pytest.raises(ValueError, match="max_batch"):
        BatchSchedule(base_batch=32, max_batch=8, grow_every=100,
                      round_to=16)


def test_batch_size_increase_maps_decay_regime():
    small = Regime(base_lr=0.1, total_steps=300, drop_every=100,
                   drop_factor=0.2)
    const, sched = batch_size_increase(small, base_batch=32,
                                       max_batch=1024, round_to=16)
    assert float(const.lr_at(250)) == pytest.approx(0.1)
    assert sched.grow_every == 100
    assert sched.grow_factor == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


def test_sweep_runs_skip_on_resume(tmp_path):
    sweep = SweepSpec(
        name="tiny", base=_tiny_spec(),
        methods={"SB": {}, "LB": {"lb.batch_size": 128}})
    recs = run_sweep(sweep, str(tmp_path))
    assert len(recs) == 2
    assert {r["method"] for r in recs} == {"SB", "LB"}
    assert all(0.0 <= r["final_acc"] <= 1.0 for r in recs)
    # a checkpoint orphaned by a kill between record append and cleanup
    # is reaped on the next (skipping) pass
    orphan = os.path.join(str(tmp_path), "tiny", "ckpt", recs[0]["run_id"])
    os.makedirs(orphan)
    seen = []
    recs2 = run_sweep(sweep, str(tmp_path), log_fn=seen.append)
    assert [r["run_id"] for r in recs2] == [r["run_id"] for r in recs]
    assert all("skipping" in line for line in seen)
    assert not os.path.exists(orphan)
    # records.jsonl not double-appended
    store = ResultsStore(os.path.join(str(tmp_path), "tiny"))
    assert len(store.records()) == 2


def test_killed_run_resumes_identically(tmp_path):
    """The acceptance criterion: kill mid-run, restart, aggregate record
    matches the uninterrupted run exactly."""
    spec = _tiny_spec(total_steps=40, eval_every=10)
    ref = run_one(spec)

    ck = str(tmp_path / "ck")
    calls = []

    def killer(msg):
        calls.append(msg)
        if len(calls) == 2:                     # die after the step-10 eval
            raise KeyboardInterrupt
    with pytest.raises(KeyboardInterrupt):
        run_one(spec, checkpoint_dir=ck, checkpoint_every=8, log_fn=killer)
    assert os.path.exists(os.path.join(ck, "latest"))
    resumed = run_one(spec, checkpoint_dir=ck, checkpoint_every=8)
    for k in ("final_acc", "best_acc", "train_acc", "steps"):
        assert resumed[k] == ref[k], k
    assert resumed["metrics"] == ref["metrics"]
    assert resumed["log_fit"] == ref["log_fit"]


def test_killed_sweep_restarts_to_identical_records(tmp_path):
    """Sweep-level acceptance: a sweep killed mid-run (first run recorded,
    second run dead with a half-written checkpoint) restarts to the same
    aggregate records.jsonl as an uninterrupted sweep (modulo wall-clock)."""
    sweep = SweepSpec(
        name="killed", base=_tiny_spec(total_steps=24, eval_every=8),
        methods={"SB": {}, "LB": {"lb.batch_size": 128}})

    def strip(recs):
        return [{k: v for k, v in r.items() if k != "wall_s"}
                for r in recs]

    ref = strip(run_sweep(sweep, str(tmp_path / "ref"),
                          checkpoint_every=10))

    # simulate the kill: complete the SB run only, then die inside the LB
    # run after its step-10 checkpoint (same layout run_sweep would leave)
    boom_dir = str(tmp_path / "boom")
    sb, lb = sweep.expand()
    sb_only = dataclasses.replace(sweep, methods={"SB": {}})
    run_sweep(sb_only, boom_dir, checkpoint_every=10)

    def killer(msg):
        if msg.startswith("step    16"):
            raise KeyboardInterrupt
    lb_ck = os.path.join(boom_dir, sweep.name, "ckpt", lb.run_id)
    with pytest.raises(KeyboardInterrupt):
        run_one(lb, checkpoint_dir=lb_ck, checkpoint_every=10,
                log_fn=killer)
    assert os.path.exists(os.path.join(lb_ck, "latest"))

    resumed = strip(run_sweep(sweep, boom_dir, checkpoint_every=10))
    assert resumed == ref
    assert not os.path.exists(lb_ck)            # cleaned up after recording


def test_run_determinism_same_seed():
    spec = _tiny_spec()
    a, b = run_one(spec), run_one(spec)
    assert a["final_acc"] == b["final_acc"]
    assert a["metrics"] == b["metrics"]


def test_batch_schedule_run_executes_all_phases(tmp_path):
    spec = _tiny_spec(
        total_steps=20, drop_every=8,
        lb=LargeBatchConfig(batch_size=128, base_batch_size=32,
                            lr_rule="none", ghost_batch_size=16,
                            regime_adaptation=False),
        batch_schedule=BatchSchedule(base_batch=32, max_batch=128,
                                     grow_every=8, grow_factor=2.0,
                                     round_to=16))
    rec = run_one(spec)
    assert rec["steps"] == 20
    assert rec["batch_size"] == 32              # reported base batch
    assert 0.0 <= rec["final_acc"] <= 1.0


def test_mesh_compatible_gating():
    from repro.launch.mesh import make_data_mesh
    from repro.train.data_parallel import mesh_compatible
    mesh = make_data_mesh(1)
    lb = LargeBatchConfig(batch_size=64, base_batch_size=32,
                          ghost_batch_size=16)
    assert mesh_compatible(lb, mesh)
    assert mesh_compatible(lb, mesh, batch_size=48)
    assert not mesh_compatible(lb, mesh, batch_size=63)
    # no-GBN runs only need device divisibility
    nb = dataclasses.replace(lb, use_gbn=False)
    assert mesh_compatible(nb, mesh, batch_size=63)


def test_lm_runner_path(tmp_path):
    spec = _tiny_spec(
        lm_arch="qwen3-1.7b", lm_seq_len=16, lm_n_tokens=4096,
        lm_vocab_size=64, total_steps=4, drop_every=2, eval_every=2,
        track_diffusion=False, weight_decay=0.0,
        lb=LargeBatchConfig(batch_size=8, base_batch_size=8,
                            lr_rule="none", use_gbn=False))
    sweep = SweepSpec(name="lm", base=spec)
    recs = run_sweep(sweep, str(tmp_path), checkpoint_every=2)
    assert len(recs) == 1
    assert np.isfinite(recs[0]["final_ce"])
    assert recs[0]["steps"] == 4


def test_mesh_degradation_warns_once():
    """A topology request the host can't honor emits one RuntimeWarning
    naming both the requested and actual topology — once per (requested,
    actual) pair, not once per run."""
    from repro.experiments import runner
    spec = _tiny_spec(use_mesh="2d")
    runner._DEGRADE_WARNED.clear()
    try:
        with pytest.warns(RuntimeWarning, match="'2d'.*degrading"):
            runner._mesh_for(spec)          # 1 device in-process
        # second call for the same degradation is silent
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            runner._mesh_for(spec)
    finally:
        runner._DEGRADE_WARNED.clear()


def test_sweep_shard_partition():
    """_shard_owns partitions any run_id set exactly across shards, and is
    a pure function of the run_id (adding runs never reshuffles the rest)."""
    from repro.experiments.runner import _shard_owns
    ids = [_tiny_spec(seed=s).run_id for s in range(8)]
    for count in (2, 3):
        owners = [[rid for rid in ids if _shard_owns(rid, i, count)]
                  for i in range(count)]
        flat = [r for o in owners for r in o]
        assert sorted(flat) == sorted(ids)
    assert _shard_owns(ids[0], 0, 2) == _shard_owns(ids[0], 0, 2)


def test_run_sweep_shard_validation(tmp_path):
    sweep = SweepSpec(name="tiny", base=_tiny_spec(total_steps=2))
    with pytest.raises(ValueError, match="bad sweep shard"):
        run_sweep(sweep, str(tmp_path), shard=(2, 2))
