"""Fused row/attention kernels and the int8 paged KV cache.

Covers the PR 9 widening: fused rmsnorm+residual, fused SwiGLU and
RoPE-fused flash attention — forward AND grad against the jnp oracles in
f32 and bf16 (the Pallas pair driven explicitly with ``interpret=True``;
off-TPU the ops entries dispatch to the fused jnp lowering) — plus the
fused dkv+dq flash backward, the ``_fused_tile`` oracle fallback, and the
int8 page pool: per-slot quantize/dequant bounds, in-kernel dequant vs the
dequantizing oracle, trash-page no-op on quantized pages, and engine-level
greedy parity vs the full-precision pool."""
import dataclasses
import warnings as warnings_mod

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import (flash_attention_backward_pallas,
                                           flash_attention_pallas,
                                           flash_attention_rope_backward_pallas,
                                           flash_attention_rope_pallas)
from repro.kernels.flash_decode import (flash_decode_paged_blockwise,
                                        flash_decode_paged_pallas)
from repro.kernels.fused_norm import (rmsnorm_residual_backward_pallas,
                                      rmsnorm_residual_pallas)
from repro.kernels.swiglu import swiglu_backward_pallas, swiglu_pallas

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# fused rmsnorm + residual
# ---------------------------------------------------------------------------

NORM_SHAPES = [(17, 128), (64, 256), (5, 512)]


@pytest.mark.parametrize("shape", NORM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_residual_pallas_vs_ref(shape, dtype):
    N, d = shape
    rng = jax.random.PRNGKey(N + d)
    x = jax.random.normal(rng, shape, jnp.float32).astype(dtype)
    r = jax.random.normal(jax.random.fold_in(rng, 1), shape,
                          jnp.float32).astype(dtype)
    scale = jnp.linspace(0.5, 1.5, d)
    y, s = rmsnorm_residual_pallas(x, r, scale, interpret=True)
    yr, sr = ref.rmsnorm_residual_ref(x, r, scale)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s, np.float32),
                               np.asarray(sr, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_residual_backward_pallas_vs_oracle(dtype):
    """Backward kernel from the saved (s, scale) == oracle VJP (which also
    certifies dr == dx: the residual add fans the cotangent out equally)."""
    N, d = 33, 256
    rng = jax.random.PRNGKey(7)
    x = jax.random.normal(rng, (N, d), jnp.float32).astype(dtype)
    r = jax.random.normal(jax.random.fold_in(rng, 1), (N, d),
                          jnp.float32).astype(dtype)
    scale = jnp.linspace(0.5, 1.5, d)
    dy = jax.random.normal(jax.random.fold_in(rng, 2), (N, d),
                           jnp.float32).astype(dtype)
    ds = jax.random.normal(jax.random.fold_in(rng, 3), (N, d),
                           jnp.float32).astype(dtype)
    s = x + r
    dx, dscale = rmsnorm_residual_backward_pallas(s, scale, dy, ds,
                                                  interpret=True)
    dxr, drr, dscr = ref.rmsnorm_residual_vjp_ref(x, r, scale, (dy, ds))
    tol = 1e-5 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(dxr, np.float32),
                               np.asarray(drr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(dx, np.float32),
                               np.asarray(dxr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(dscale), np.asarray(dscr, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_residual_grad_vs_oracle(dtype):
    """jax.grad through ops.rmsnorm_residual == jax.grad through the oracle
    with live cotangents on BOTH outputs (y and the new residual stream)."""
    N, d = 20, 256
    rng = jax.random.PRNGKey(11)
    x = jax.random.normal(rng, (N, d), jnp.float32).astype(dtype)
    r = jax.random.normal(jax.random.fold_in(rng, 1), (N, d),
                          jnp.float32).astype(dtype)
    scale = jnp.linspace(0.5, 1.5, d)
    wy = jax.random.normal(jax.random.fold_in(rng, 2), (N, d))
    ws = jax.random.normal(jax.random.fold_in(rng, 3), (N, d))

    def make_loss(f):
        def loss(a, b, c):
            y, s = f(a, b, c)
            return ((y.astype(jnp.float32) * wy).sum()
                    + (s.astype(jnp.float32) * ws).sum())
        return loss

    gk = jax.grad(make_loss(ops.rmsnorm_residual), argnums=(0, 1, 2))(
        x, r, scale)
    gr = jax.grad(make_loss(ref.rmsnorm_residual_ref), argnums=(0, 1, 2))(
        x, r, scale)
    tol = 1e-5 if dtype == jnp.float32 else 1e-1
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# fused SwiGLU
# ---------------------------------------------------------------------------

SWIGLU_SHAPES = [(9, 128, 256), (33, 256, 384)]


@pytest.mark.parametrize("shape", SWIGLU_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu_pallas_vs_ref(shape, dtype):
    N, d, F = shape
    rng = jax.random.PRNGKey(sum(shape))
    x = jax.random.normal(rng, (N, d), jnp.float32).astype(dtype)
    wg = (jax.random.normal(jax.random.fold_in(rng, 1), (d, F))
          / d ** 0.5).astype(dtype)
    wu = (jax.random.normal(jax.random.fold_in(rng, 2), (d, F))
          / d ** 0.5).astype(dtype)
    h, g = swiglu_pallas(x, wg, wu, interpret=True)
    hr, gr = ref.swiglu_ref(x, wg, wu)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(g, np.float32),
                               np.asarray(gr, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu_backward_pallas_vs_oracle(dtype):
    """Activation-side backward kernel (dx from the saved gate g, dg/du for
    the outside weight GEMMs) == oracle VJP."""
    N, d, F = 17, 128, 256
    rng = jax.random.PRNGKey(13)
    x = jax.random.normal(rng, (N, d), jnp.float32).astype(dtype)
    wg = (jax.random.normal(jax.random.fold_in(rng, 1), (d, F))
          / d ** 0.5).astype(dtype)
    wu = (jax.random.normal(jax.random.fold_in(rng, 2), (d, F))
          / d ** 0.5).astype(dtype)
    dh = jax.random.normal(jax.random.fold_in(rng, 3), (N, F),
                           jnp.float32).astype(dtype)
    _, g = ref.swiglu_ref(x, wg, wu)
    dx, dg, du = swiglu_backward_pallas(x, wg, wu, g, dh, interpret=True)
    dwg = jnp.dot(x.T.astype(jnp.float32), dg.astype(jnp.float32))
    dwu = jnp.dot(x.T.astype(jnp.float32), du.astype(jnp.float32))
    dxr, dwgr, dwur = ref.swiglu_vjp_ref(x, wg, wu, dh)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    for got, want in ((dx, dxr), (dwg, dwgr), (dwu, dwur)):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu_grad_vs_oracle(dtype):
    """jax.grad through ops.swiglu == jax.grad through the oracle for all
    three inputs (x, wg, wu)."""
    N, d, F = 12, 128, 256
    rng = jax.random.PRNGKey(17)
    x = jax.random.normal(rng, (N, d), jnp.float32).astype(dtype)
    wg = (jax.random.normal(jax.random.fold_in(rng, 1), (d, F))
          / d ** 0.5).astype(dtype)
    wu = (jax.random.normal(jax.random.fold_in(rng, 2), (d, F))
          / d ** 0.5).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(rng, 3), (N, F))

    def make_loss(f):
        return lambda a, b, c: (f(a, b, c).astype(jnp.float32) * w).sum()

    gk = jax.grad(make_loss(ops.swiglu), argnums=(0, 1, 2))(x, wg, wu)
    gr = jax.grad(make_loss(lambda a, b, c: ref.swiglu_ref(a, b, c)[0]),
                  argnums=(0, 1, 2))(x, wg, wu)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# RoPE-fused flash attention
# ---------------------------------------------------------------------------

ROPE_SHAPES = [
    # (B, H, KV, T, hd) — self-attention: S == T
    (1, 2, 2, 17, 32),
    (2, 4, 2, 64, 64),
]


def _rope_inputs(shape, dtype, salt=0):
    B, H, KV, T, hd = shape
    rng = jax.random.PRNGKey((sum(shape) + salt) % 2 ** 31)
    q = jax.random.normal(rng, (B, H, T, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, KV, T, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, KV, T, hd),
                          jnp.float32).astype(dtype)
    # staggered per-row positions (continuation offsets, not just 0..T-1)
    pos = (jnp.arange(T)[None, :] + 3 * jnp.arange(B)[:, None]).astype(
        jnp.float32)
    return q, k, v, pos


@pytest.mark.parametrize("shape", ROPE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 13])
def test_flash_attention_rope_vs_ref(shape, dtype, window):
    """In-kernel q/k rotation == rope-then-attend oracle composition."""
    q, k, v, pos = _rope_inputs(shape, dtype)
    out = flash_attention_rope_pallas(q, k, v, pos, theta=1e4, causal=True,
                                      window=window, block_q=32, block_k=32,
                                      interpret=True)
    want = ref.attention_rope_ref(q, k, v, pos, theta=1e4, causal=True,
                                  window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_rope_backward_vs_oracle(dtype):
    """Rope backward (un-rotate dq/dk around the shared non-rope kernels)
    fed the forward kernel's own residuals == oracle VJP."""
    q, k, v, pos = _rope_inputs((2, 4, 2, 33, 32), dtype, salt=5)
    do = jax.random.normal(jax.random.PRNGKey(6), q.shape,
                           jnp.float32).astype(dtype)
    o, lse = flash_attention_rope_pallas(q, k, v, pos, theta=1e4,
                                         causal=True, block_q=32, block_k=32,
                                         return_residuals=True,
                                         interpret=True)
    dq, dk, dv = flash_attention_rope_backward_pallas(
        q, k, v, pos, o, lse, do, theta=1e4, causal=True, block_q=32,
        block_k=32, interpret=True)
    want = ref.attention_rope_vjp_ref(q, k, v, pos, do, theta=1e4,
                                      causal=True)
    tol = 5e-4 if dtype == jnp.float32 else 2e-1
    for got, wnt in zip((dq, dk, dv), want):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(wnt, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_rope_grad_vs_oracle(dtype):
    """jax.grad through the ops.flash_attention_rope custom_vjp (model
    layout, unrotated q/k in) == jax.grad through the oracle composition."""
    B, H, KV, T, hd = 2, 4, 2, 20, 32
    rng = jax.random.PRNGKey(23)
    q = jax.random.normal(rng, (B, T, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, KV, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, T, KV, hd),
                          jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(rng, 3), (B, T, H, hd))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def make_loss(f):
        return lambda a, b, c: (f(a, b, c).astype(jnp.float32) * w).sum()

    gk = jax.grad(make_loss(lambda a, b, c: ops.flash_attention_rope(
        a, b, c, pos, theta=1e4)), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(make_loss(lambda a, b, c: ref.attention_rope_ref(
        a.swapaxes(1, 2), b.swapaxes(1, 2), c.swapaxes(1, 2), pos,
        theta=1e4).swapaxes(1, 2)), argnums=(0, 1, 2))(q, k, v)
    tol = 5e-4 if dtype == jnp.float32 else 2e-1
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# fused dkv + dq flash backward (one recompute feeds both)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal,window", [(True, None), (True, 13),
                                           (False, None)])
def test_flash_backward_fused_vs_split_vs_oracle(causal, window):
    """fuse_dq=True (single kernel, shared p blocks) == fuse_dq=False (two
    kernels, two recomputes) == the hand oracle VJP."""
    B, H, KV, T, hd = 2, 4, 2, 33, 32
    rng = jax.random.PRNGKey(29)
    q = jax.random.normal(rng, (B, H, T, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, KV, T, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, KV, T, hd))
    do = jax.random.normal(jax.random.fold_in(rng, 3), (B, H, T, hd))
    o, lse = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                    block_q=32, block_k=32,
                                    return_residuals=True, interpret=True)
    outs = {}
    for fuse in (True, False):
        outs[fuse] = flash_attention_backward_pallas(
            q, k, v, o, lse, do, causal=causal, window=window, block_q=32,
            block_k=32, fuse_dq=fuse, interpret=True)
    want = ref.attention_vjp_ref(q, k, v, do, causal=causal, window=window)
    for fuse in (True, False):
        for got, wnt in zip(outs[fuse], want):
            np.testing.assert_allclose(got, wnt, rtol=5e-4, atol=5e-4,
                                       err_msg=f"fuse_dq={fuse}")


def test_flash_backward_bf16_accumulators_bounded():
    """acc_dtype=bf16 on the fused path stays within bf16 resolution of the
    f32-accumulated grads (the docs/kernels.md accumulation study's bound)."""
    B, H, KV, T, hd = 2, 4, 2, 64, 32
    rng = jax.random.PRNGKey(31)
    q = jax.random.normal(rng, (B, H, T, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, KV, T, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, KV, T, hd))
    do = jax.random.normal(jax.random.fold_in(rng, 3), (B, H, T, hd))
    o, lse = flash_attention_pallas(q, k, v, causal=True, block_q=32,
                                    block_k=32, return_residuals=True,
                                    interpret=True)
    f32 = flash_attention_backward_pallas(
        q, k, v, o, lse, do, causal=True, block_q=32, block_k=32,
        fuse_dq=True, interpret=True)
    b16 = flash_attention_backward_pallas(
        q, k, v, o, lse, do, causal=True, block_q=32, block_k=32,
        fuse_dq=True, acc_dtype=jnp.bfloat16, interpret=True)
    for got, want, name in zip(b16, f32, ("dq", "dk", "dv")):
        scale = float(jnp.abs(want).max())
        err = float(jnp.abs(got.astype(jnp.float32) - want).max())
        # bf16 has ~8 mantissa bits; the accumulated sums lose a few more
        assert err <= 0.15 * scale, (name, err, scale)


# ---------------------------------------------------------------------------
# _fused_tile oracle fallback (never a silent mis-tile)
# ---------------------------------------------------------------------------


def test_fused_tile_gate():
    assert ops._fused_tile(256, "t") == 256
    assert ops._fused_tile(ops._MAX_FUSED_LANE, "t") == ops._MAX_FUSED_LANE
    with warnings_mod.catch_warnings():
        warnings_mod.simplefilter("ignore")
        assert ops._fused_tile(100, "t") is None
        assert ops._fused_tile(ops._MAX_FUSED_LANE + 128, "t") is None


def test_rmsnorm_residual_unaligned_fallback_warns_once():
    """d=100 (not a 128-multiple) falls back to the oracle — same numbers,
    ONE warning per shape, never a mis-tiled kernel."""
    N, d = 8, 100
    rng = jax.random.PRNGKey(37)
    x = jax.random.normal(rng, (N, d))
    r = jax.random.normal(jax.random.fold_in(rng, 1), (N, d))
    scale = jnp.linspace(0.5, 1.5, d)
    ops._TILE_WARNED.clear()
    with warnings_mod.catch_warnings(record=True) as rec:
        warnings_mod.simplefilter("always")
        y, s = ops.rmsnorm_residual(x, r, scale)
        yr, sr = ref.rmsnorm_residual_ref(x, r, scale)
        np.testing.assert_allclose(y, yr, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(s, sr, rtol=1e-6, atol=1e-6)
        gk = jax.grad(lambda *a: ops.rmsnorm_residual(*a)[0].sum(),
                      argnums=(0, 1, 2))(x, r, scale)
        gr = jax.grad(lambda *a: ref.rmsnorm_residual_ref(*a)[0].sum(),
                      argnums=(0, 1, 2))(x, r, scale)
        for g, w in zip(gk, gr):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
    hits = [w for w in rec if "rmsnorm_residual" in str(w.message)
            and "128-multiple" in str(w.message)]
    assert len(hits) == 1, [str(w.message) for w in rec]


def test_swiglu_unaligned_fallback_warns():
    """A non-128-multiple hidden dim falls back to the oracle (fwd + grad
    agree) with a warning."""
    N, d, F = 8, 128, 100
    rng = jax.random.PRNGKey(41)
    x = jax.random.normal(rng, (N, d))
    wg = jax.random.normal(jax.random.fold_in(rng, 1), (d, F)) / d ** 0.5
    wu = jax.random.normal(jax.random.fold_in(rng, 2), (d, F)) / d ** 0.5
    ops._TILE_WARNED.clear()
    with warnings_mod.catch_warnings(record=True) as rec:
        warnings_mod.simplefilter("always")
        h = ops.swiglu(x, wg, wu)
        hr, _ = ref.swiglu_ref(x, wg, wu)
        np.testing.assert_allclose(h, hr, rtol=1e-6, atol=1e-6)
        gk = jax.grad(lambda *a: ops.swiglu(*a).sum(),
                      argnums=(0, 1, 2))(x, wg, wu)
        gr = jax.grad(lambda *a: ref.swiglu_ref(*a)[0].sum(),
                      argnums=(0, 1, 2))(x, wg, wu)
        for g, w in zip(gk, gr):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
    assert any("swiglu" in str(w.message) and "128-multiple" in str(w.message)
               for w in rec)


# ---------------------------------------------------------------------------
# int8 paged KV cache
# ---------------------------------------------------------------------------


def _quantize_pool(kp):
    """Per-slot symmetric int8 quantization, as the engine/decode writes."""
    sc = jnp.maximum(jnp.abs(kp).max(axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(kp / sc[..., None]), -127, 127).astype(jnp.int8)
    return q, sc.astype(jnp.float32)


def test_int8_roundtrip_error_bound():
    """quantize -> dequantize error is elementwise <= scale/2 (round), i.e.
    <= max|slot|/254; all-zero slots survive the clamped scale."""
    rng = jax.random.PRNGKey(43)
    kp = jax.random.normal(rng, (6, 2, 16, 64)) * \
        jnp.exp(jax.random.normal(jax.random.fold_in(rng, 1), (6, 1, 1, 1)))
    kp = kp.at[0].set(0.0)
    q, sc = _quantize_pool(kp)
    deq = q.astype(jnp.float32) * sc[..., None]
    err = jnp.abs(deq - kp)
    assert float((err - sc[..., None] / 2).max()) <= 1e-6
    np.testing.assert_array_equal(np.asarray(deq[0]), 0.0)
    # codes actually span the int8 range (the scale isn't degenerate)
    assert int(jnp.abs(q[1:]).max()) == 127


def _paged_from_contiguous(k, v, ps, seed=0):
    B, KV, S, hd = k.shape
    NB = S // ps
    perm = np.random.RandomState(seed).permutation(
        np.arange(1, 1 + B * NB)).astype(np.int32)
    pt = jnp.asarray(perm.reshape(B, NB))

    def pool(x):
        blocks = x.reshape(B, KV, NB, ps, hd).transpose(0, 2, 1, 3, 4)
        p = jnp.zeros((1 + B * NB, KV, ps, hd), x.dtype)
        return p.at[pt.reshape(-1)].set(blocks.reshape(B * NB, KV, ps, hd))
    return pool(k), pool(v), pt


def test_flash_decode_paged_int8_vs_oracle():
    """In-kernel dequant (pallas-interpret AND blockwise) == the oracle
    that materialises the dequantized pool up front, at per-row positions
    with window and fused-rope variants."""
    B, H, KV, NB, ps, hd = 2, 4, 2, 4, 16, 64
    S = NB * ps
    ks = jax.random.split(jax.random.PRNGKey(47), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    pos = jnp.asarray([S - 1, S // 2 + 3], jnp.int32)
    kp, vp, pt = _paged_from_contiguous(k, v, ps)
    kq, ksc = _quantize_pool(kp)
    vq, vsc = _quantize_pool(vp)
    for window, theta in ((None, None), (24, None), (None, 1e4)):
        want = ref.flash_decode_paged_ref(q, kq, vq, pt, pos, window=window,
                                          k_scale=ksc, v_scale=vsc)
        if theta is not None:
            want = ref.flash_decode_paged_ref(
                ref.rope_ref(q[:, :, None], pos[:, None],
                             theta)[:, :, 0],
                kq, vq, pt, pos, window=window, k_scale=ksc, v_scale=vsc)
        for name, fn in (
            ("pallas", lambda *a, **kw: flash_decode_paged_pallas(
                *a, interpret=True, **kw)),
            ("blockwise", flash_decode_paged_blockwise),
        ):
            got = fn(q, kq, vq, pt, pos, window=window, k_scale=ksc,
                     v_scale=vsc, rope_theta=theta)
            np.testing.assert_allclose(got, want, atol=3e-6, rtol=1e-5,
                                       err_msg=f"{name} window={window} "
                                               f"theta={theta}")


def test_flash_decode_paged_int8_trash_page_noop():
    """Block-table entries past pos may point at trash page 0: with a
    quantized pool (page 0 codes AND scales are zeros) they must stay an
    exact no-op, and an all-trash row stays finite."""
    B, H, KV, NB, ps, hd = 2, 4, 2, 4, 16, 64
    S = NB * ps
    ks = jax.random.split(jax.random.PRNGKey(53), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    pos = jnp.asarray([ps + 3, 2 * ps - 1], jnp.int32)   # rows use 2 blocks
    kp, vp, pt = _paged_from_contiguous(k, v, ps)
    kq, ksc = _quantize_pool(kp)
    vq, vsc = _quantize_pool(vp)
    full = flash_decode_paged_pallas(q, kq, vq, pt, pos, k_scale=ksc,
                                     v_scale=vsc, interpret=True)
    trashed = pt.at[:, 2:].set(0)
    for fn in (lambda *a, **kw: flash_decode_paged_pallas(
                   *a, interpret=True, **kw),
               flash_decode_paged_blockwise):
        got = fn(q, kq, vq, trashed, pos, k_scale=ksc, v_scale=vsc)
        np.testing.assert_allclose(got, full, atol=3e-6, rtol=1e-5)
        dead = fn(q, kq, vq, jnp.zeros_like(pt), pos, k_scale=ksc,
                  v_scale=vsc)
        assert np.isfinite(np.asarray(dead)).all()


# ---------------------------------------------------------------------------
# int8 cache through the model / engine
# ---------------------------------------------------------------------------


def _cfg(arch="qwen3-1.7b"):
    from repro.configs.registry import get_config
    return dataclasses.replace(get_config(arch).reduced(), dtype="float32")


@pytest.mark.parametrize("use_kernels", [False, True])
def test_decode_step_int8_bounded_logit_drift(use_kernels):
    """decode_step over an int8 paged cache tracks the full-precision paged
    cache within quantization noise (~1/254 relative on K/V) at every step
    — for both the kernel and the gather-dequant einsum paths."""
    from repro.models import transformer as T
    from repro.serving.engine import _write_pt
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S, ps = 2, 16, 8
    NB = S // ps
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 10), 0,
                              cfg.vocab_size)
    out = {}
    for cd in (None, "int8"):
        cache = T.init_cache(cfg, B, S, dtype=jnp.float32, layout="paged",
                             page_size=ps, total_pages=1 + B * NB,
                             cache_dtype=cd)
        cache = _write_pt(cache, jnp.asarray(
            1 + np.arange(B * NB).reshape(B, NB), jnp.int32))
        seq = []
        for t in range(10):
            lg, cache = T.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                      jnp.full((B,), t, jnp.int32),
                                      use_kernels=use_kernels)
            seq.append(lg[:, 0])
        out[cd] = jnp.stack(seq)
    drift = float(jnp.abs(out[None] - out["int8"]).max())
    scale = float(jnp.abs(out[None]).max())
    assert drift <= 0.06 * max(scale, 1.0), (drift, scale)
    assert drift > 0.0          # the quantized path actually ran


@pytest.mark.parametrize("use_kernels", [False, True])
def test_engine_int8_matches_full_precision_greedy(use_kernels):
    """ContinuousEngine(cache_dtype='int8') produces the SAME greedy tokens
    as the full-precision paged engine on the test trace (identical argmax
    per step), through admission quantization, slot reuse and retirement."""
    from repro.models import transformer as T
    from repro.serving import ContinuousEngine, Request
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    r = np.random.RandomState(0)
    reqs = []
    for i in range(5):
        L = int(r.choice([4, 8]))
        prompt = r.randint(0, cfg.vocab_size, size=(L,)).astype("int32")
        reqs.append(Request(id=i, prompt=prompt, max_new_tokens=6,
                            arrival=0.9 * i))
    outs = {}
    for cd in (None, "int8"):
        eng = ContinuousEngine(params, cfg, num_slots=2, max_len=16,
                               layout="paged", page_size=8,
                               use_kernels=use_kernels, cache_dtype=cd)
        comps = eng.run(reqs)
        assert sorted(comps) == [q.id for q in reqs]
        outs[cd] = {i: c.tokens for i, c in comps.items()}
    assert outs[None] == outs["int8"]


def test_init_cache_int8_shapes():
    """The int8 paged cache carries int8 kp/vp plus f32 (pages, kv, ps)
    scale planes, and rejects non-paged layouts."""
    from repro.models import transformer as T
    cfg = _cfg()
    cache = T.init_cache(cfg, 2, 16, dtype=jnp.float32, layout="paged",
                         page_size=8, cache_dtype="int8")
    leaves = {}
    jax.tree_util.tree_map_with_path(
        lambda p, x: leaves.setdefault(
            "/".join(str(getattr(q, "key", "")) for q in p), x), cache)
    kp = next(v for k, v in leaves.items() if k.endswith("/kp"))
    ks = next(v for k, v in leaves.items() if k.endswith("/ks"))
    assert kp.dtype == jnp.int8
    assert ks.dtype == jnp.float32
    assert ks.shape == kp.shape[:-1]
    with pytest.raises(ValueError, match="cache_dtype"):
        T.init_cache(cfg, 2, 16, dtype=jnp.float32, layout="seq",
                     cache_dtype="int8")
