"""Loop-aware HLO analysis: trip-count recovery and FLOP counting validated
against a known program (scan of matmuls)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_matmul_flops_counted_with_trips():
    """8-step scan of a (64x64)@(64x64) matmul: 8 * 2*64^3 FLOPs."""
    N, STEPS = 64, 8

    def fn(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=STEPS)
        return y

    compiled = _compile(fn, jnp.ones((N, N)), jnp.ones((N, N)))
    stats = H.analyze(compiled.as_text())
    want = STEPS * 2 * N ** 3
    assert stats.flops == pytest.approx(want, rel=0.05)
    assert STEPS in stats.trip_counts


def test_single_matmul_flops():
    M, K, Nn = 32, 48, 80

    def fn(a, b):
        return a @ b

    compiled = _compile(fn, jnp.ones((M, K)), jnp.ones((K, Nn)))
    stats = H.analyze(compiled.as_text())
    assert stats.flops == pytest.approx(2 * M * K * Nn, rel=0.01)


def test_shape_bytes():
    assert H._shape_bytes("bf16[16,4096,448]{2,1,0}") == 16 * 4096 * 448 * 2
    assert H._shape_bytes("f32[8]") == 32
    assert H._shape_bytes("(f32[2,2]{1,0}, s32[4])") == 16 + 16
    assert H._shape_bytes("pred[]") == 1


def test_roofline_terms_and_dominance():
    terms = H.roofline_terms(197e12, 819e9, 0.0)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(1.0)
    assert H.dominant_term({"compute_s": 2.0, "memory_s": 1.0,
                            "collective_s": 0.5}) == "compute_s"


def test_model_flops():
    assert H.model_flops(1_000_000, 10, train=True) == 6e7
    assert H.model_flops(1_000_000, 10, train=False) == 2e7


def test_collectives_counted_under_mesh():
    """psum inside shard_map on a 1-device mesh still emits an all-reduce."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def fn(a):
        return shard_map(lambda t: jax.lax.psum(t, "x"), mesh=mesh,
                         in_specs=P("x"), out_specs=P())(a)

    with mesh:
        compiled = jax.jit(fn).lower(jnp.ones((8,))).compile()
    stats = H.analyze(compiled.as_text())
    # single-device all-reduce may be optimised away; just assert parsing ran
    assert stats.flops >= 0.0
