"""Loop-aware HLO analysis: trip-count recovery and FLOP counting validated
against a known program (scan of matmuls)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_matmul_flops_counted_with_trips():
    """8-step scan of a (64x64)@(64x64) matmul: 8 * 2*64^3 FLOPs."""
    N, STEPS = 64, 8

    def fn(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=STEPS)
        return y

    compiled = _compile(fn, jnp.ones((N, N)), jnp.ones((N, N)))
    stats = H.analyze(compiled.as_text())
    want = STEPS * 2 * N ** 3
    assert stats.flops == pytest.approx(want, rel=0.05)
    assert STEPS in stats.trip_counts


def test_single_matmul_flops():
    M, K, Nn = 32, 48, 80

    def fn(a, b):
        return a @ b

    compiled = _compile(fn, jnp.ones((M, K)), jnp.ones((K, Nn)))
    stats = H.analyze(compiled.as_text())
    assert stats.flops == pytest.approx(2 * M * K * Nn, rel=0.01)


def test_shape_bytes():
    assert H._shape_bytes("bf16[16,4096,448]{2,1,0}") == 16 * 4096 * 448 * 2
    assert H._shape_bytes("f32[8]") == 32
    assert H._shape_bytes("(f32[2,2]{1,0}, s32[4])") == 16 + 16
    assert H._shape_bytes("pred[]") == 1


def test_roofline_terms_and_dominance():
    terms = H.roofline_terms(197e12, 819e9, 0.0)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(1.0)
    assert H.dominant_term({"compute_s": 2.0, "memory_s": 1.0,
                            "collective_s": 0.5}) == "compute_s"


def test_model_flops():
    assert H.model_flops(1_000_000, 10, train=True) == 6e7
    assert H.model_flops(1_000_000, 10, train=False) == 2e7


def test_while_loop_trip_count():
    """An explicit lax.while_loop with a counter < N condition."""
    N, TRIPS = 32, 11

    def fn(x, w):
        def cond(c):
            return c[0] < TRIPS

        def body(c):
            i, y = c
            return i + 1, y @ w

        _, y = jax.lax.while_loop(cond, body, (jnp.int32(0), x))
        return y

    compiled = _compile(fn, jnp.ones((N, N)), jnp.ones((N, N)))
    stats = H.analyze(compiled.as_text())
    assert stats.n_whiles >= 1
    assert TRIPS in stats.trip_counts
    assert stats.flops == pytest.approx(TRIPS * 2 * N ** 3, rel=0.05)


def test_nested_scan_trip_counts_multiply():
    """Outer scan(3) of inner scan(5) of a matmul: 15x the matmul FLOPs."""
    N, OUTER, INNER = 32, 3, 5

    def fn(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=INNER)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=OUTER)
        return y

    compiled = _compile(fn, jnp.ones((N, N)), jnp.ones((N, N)))
    stats = H.analyze(compiled.as_text())
    assert stats.flops == pytest.approx(OUTER * INNER * 2 * N ** 3,
                                        rel=0.05)
    assert {OUTER, INNER} <= set(stats.trip_counts)


def test_batched_dot_general_flops():
    """einsum bmk,bkn->bmn = 2*B*M*N*K: batch dims are result dims, not
    contracting dims, so _dot_flops must count them exactly once."""
    B, M, K, Nn = 4, 16, 24, 40

    def fn(a, b):
        return jnp.einsum("bmk,bkn->bmn", a, b)

    compiled = _compile(fn, jnp.ones((B, M, K)), jnp.ones((B, K, Nn)))
    stats = H.analyze(compiled.as_text())
    assert stats.flops == pytest.approx(2 * B * M * K * Nn, rel=0.01)


def test_donation_aliasing_positive_and_negative():
    """parse_input_output_aliases: donated buffers show up as
    input_output_alias header entries; without donation the header is
    absent (the trace auditor builds its trace-donation rule on this)."""

    def fn(a, b, c):
        return a + 1.0, b * 2.0, c.sum()

    args = (jnp.ones((8,)), jnp.ones((8,)), jnp.ones((8,)))
    donated = jax.jit(fn, donate_argnums=(0, 1)).lower(*args).compile()
    aliases = H.parse_input_output_aliases(donated.as_text())
    assert len(aliases) == 2
    assert {a.param_number for a in aliases} == {0, 1}
    assert all(a.kind in ("may-alias", "must-alias") for a in aliases)
    # each aliased output is a distinct tuple position
    assert len({a.output_index for a in aliases}) == 2

    plain = jax.jit(fn).lower(*args).compile()
    assert H.parse_input_output_aliases(plain.as_text()) == []


def test_donation_unusable_buffer_not_aliased():
    """A donated argument with no same-shaped output cannot alias — the
    header holds fewer entries than donated leaves (what trace-donation
    flags)."""

    def fn(a, b):
        return b * 2.0

    compiled = jax.jit(fn, donate_argnums=(0,)).lower(
        jnp.ones((3,)), jnp.ones((4,))).compile()
    assert len(H.parse_input_output_aliases(compiled.as_text())) == 0


def test_collectives_counted_under_mesh():
    """psum inside shard_map on a 1-device mesh still emits an all-reduce."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def fn(a):
        return shard_map(lambda t: jax.lax.psum(t, "x"), mesh=mesh,
                         in_specs=P("x"), out_specs=P())(a)

    with mesh:
        compiled = jax.jit(fn).lower(jnp.ones((8,))).compile()
    stats = H.analyze(compiled.as_text())
    # single-device all-reduce may be optimised away; just assert parsing ran
    assert stats.flops >= 0.0
