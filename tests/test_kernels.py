"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracles in kernels/ref.py (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # (B, H, KV, T, S, hd)
    (1, 2, 2, 17, 17, 32),
    (2, 4, 2, 64, 64, 64),
    (1, 8, 1, 128, 128, 64),     # MQA
    (2, 4, 4, 100, 100, 128),    # MHA, ragged T
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 13),
                                           (False, None)])
def test_flash_attention_vs_ref(shape, dtype, causal, window):
    B, H, KV, T, S, hd = shape
    rng = jax.random.PRNGKey(hash((shape, causal, window or 0)) % 2**31)
    q = jax.random.normal(rng, (B, H, T, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, KV, S, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, KV, S, hd),
                          jnp.float32).astype(dtype)
    out = ops.flash_attention_hm(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_model_layout():
    """(B, T, H, hd) adapter used by the model code."""
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 32, 4, 64))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 2, 64))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 32, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ghost batch norm kernel
# ---------------------------------------------------------------------------

GBN_SHAPES = [(1, 16, 8), (4, 300, 96), (2, 1024, 128), (3, 77, 200)]


@pytest.mark.parametrize("shape", GBN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gbn_kernel_vs_ref(shape, dtype):
    G, R, C = shape
    rng = jax.random.PRNGKey(G * 1000 + R)
    xg = (2.0 * jax.random.normal(rng, shape, jnp.float32) + 0.5).astype(dtype)
    gamma = jnp.linspace(0.5, 1.5, C)
    beta = jnp.linspace(-1.0, 1.0, C)
    y, mu, var = ops.gbn_forward(xg, gamma, beta)
    yr, mur, varr = ref.gbn_ref(xg, gamma, beta)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mur),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(var), np.asarray(varr),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=10 * tol, atol=10 * tol)


def test_gbn_kernel_inside_module():
    """core.gbn_apply(use_kernels=True) matches the jnp path."""
    from repro.core.gbn import gbn_apply, gbn_init
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 24)) * 2 + 1
    params, state = gbn_init(24)
    y0, s0 = gbn_apply(params, state, x, ghost_batch_size=16)
    y1, s1 = gbn_apply(params, state, x, ghost_batch_size=16,
                       use_kernels=True)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s0["mu_run"], s1["mu_run"], rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# mamba chunk scan kernel
# ---------------------------------------------------------------------------

MAMBA_SHAPES = [
    # (B, c, di, ds)
    (1, 8, 128, 8),
    (2, 16, 256, 16),
    (2, 32, 512, 16),
]


@pytest.mark.parametrize("shape", MAMBA_SHAPES)
def test_mamba_chunk_vs_ref(shape):
    B, c, di, ds = shape
    rng = jax.random.PRNGKey(sum(shape))
    xc = jax.random.normal(rng, (B, c, di))
    dt = 0.1 * jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(rng, 1), (B, c, di)))
    Bm = jax.random.normal(jax.random.fold_in(rng, 2), (B, c, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 3), (B, c, ds))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 4), (di, ds)))
    h0 = jax.random.normal(jax.random.fold_in(rng, 5), (B, di, ds))
    y, h = ops.mamba_chunk(xc, dt, Bm, Cm, A, h0)
    yr, hr = ref.mamba_chunk_ref(xc, dt, Bm, Cm, A, h0)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, hr, rtol=1e-4, atol=1e-4)


def test_mamba_chunk_chains_across_chunks():
    """Carrying h across two chunks == one long reference scan."""
    B, c, di, ds = 1, 8, 128, 8
    rng = jax.random.PRNGKey(9)
    xc = jax.random.normal(rng, (B, 2 * c, di))
    dt = 0.1 * jnp.ones((B, 2 * c, di))
    Bm = jax.random.normal(jax.random.fold_in(rng, 1), (B, 2 * c, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 2), (B, 2 * c, ds))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (di, ds)))
    h0 = jnp.zeros((B, di, ds))
    y1, h1 = ops.mamba_chunk(xc[:, :c], dt[:, :c], Bm[:, :c], Cm[:, :c], A, h0)
    y2, h2 = ops.mamba_chunk(xc[:, c:], dt[:, c:], Bm[:, c:], Cm[:, c:], A, h1)
    yr, hr = ref.mamba_chunk_ref(xc, dt, Bm, Cm, A, h0)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), yr,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, hr, rtol=1e-4, atol=1e-4)
