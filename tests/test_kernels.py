"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracles in kernels/ref.py (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.tier1

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # (B, H, KV, T, S, hd)
    (1, 2, 2, 17, 17, 32),
    (2, 4, 2, 64, 64, 64),
    (1, 8, 1, 128, 128, 64),     # MQA
    (2, 4, 4, 100, 100, 128),    # MHA, ragged T
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 13),
                                           (False, None)])
def test_flash_attention_vs_ref(shape, dtype, causal, window):
    B, H, KV, T, S, hd = shape
    rng = jax.random.PRNGKey(hash((shape, causal, window or 0)) % 2**31)
    q = jax.random.normal(rng, (B, H, T, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, KV, S, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, KV, S, hd),
                          jnp.float32).astype(dtype)
    out = ops.flash_attention_hm(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_unaligned_default_blocks():
    """T/S not a multiple of 8 with the DEFAULT block sizes: the picked
    blocks must be sublane-aligned (T=100 -> bq=104, not 100) and the
    padded result must still match the oracle."""
    from repro.kernels.flash_attention import _block_sizes
    bq, bk = _block_sizes(100, 100, 128, 128, jnp.float32)
    assert bq % 8 == 0 and bk % 8 == 0, (bq, bk)
    bq16, bk16 = _block_sizes(100, 100, 128, 128, jnp.bfloat16)
    assert bq16 % 16 == 0 and bk16 % 16 == 0, (bq16, bk16)
    rng = jax.random.PRNGKey(2)
    q = jax.random.normal(rng, (1, 4, 100, 32))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 2, 100, 32))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, 2, 100, 32))
    out = ops.flash_attention_hm(q, k, v, causal=True)   # default blocks
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_model_layout():
    """(B, T, H, hd) adapter used by the model code."""
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 32, 4, 64))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 2, 64))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 32, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention — gradients (Pallas backward via custom_vjp)
# ---------------------------------------------------------------------------

ATTN_GRAD_SHAPES = [
    # (B, H, KV, T, S, hd)
    (1, 2, 2, 17, 17, 32),       # ragged (non-multiple-of-8 T/S)
    (2, 4, 2, 64, 64, 32),       # GQA
    (1, 4, 1, 64, 64, 32),       # MQA
]


def _attn_inputs(shape, dtype, salt=0):
    B, H, KV, T, S, hd = shape
    rng = jax.random.PRNGKey((sum(shape) + salt) % 2**31)
    q = jax.random.normal(rng, (B, H, T, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, KV, S, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, KV, S, hd),
                          jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(rng, 3), (B, H, T, hd))
    return q, k, v, w


@pytest.mark.parametrize("shape", ATTN_GRAD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 13),
                                           (False, None)])
def test_flash_attention_grad_vs_ref(shape, dtype, causal, window):
    """jax.grad through the kernel custom_vjp == jax.grad through the
    oracle, across causal/window/GQA/ragged shapes in f32 and bf16."""
    q, k, v, w = _attn_inputs(shape, dtype)

    def make_loss(f):
        return lambda a, b, c: (
            f(a, b, c).astype(jnp.float32) * w).sum()

    gk = jax.grad(make_loss(lambda a, b, c: ops.flash_attention_hm(
        a, b, c, causal=causal, window=window, block_q=32, block_k=32)),
        argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(make_loss(lambda a, b, c: ref.attention_ref(
        a, b, c, causal=causal, window=window)), argnums=(0, 1, 2))(q, k, v)
    tol = 5e-4 if dtype == jnp.float32 else 1e-1
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.tier0
def test_flash_attention_grad_smoke():
    """Seconds-scale quick-gate case: causal f32 grad vs oracle."""
    q, k, v, w = _attn_inputs((1, 2, 1, 16, 16, 16), jnp.float32)

    def make_loss(f):
        return lambda a, b, c: (f(a, b, c) * w).sum()

    gk = jax.grad(make_loss(lambda a, b, c: ops.flash_attention_hm(
        a, b, c, block_q=16, block_k=16)), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(make_loss(ref.attention_ref), argnums=(0, 1, 2))(q, k, v)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 13),
                                           (False, None)])
def test_attention_vjp_ref_matches_autodiff(causal, window):
    """The hand-derived oracle VJP == jax.vjp of the jnp oracle
    (GQA + ragged shape)."""
    q, k, v, do = _attn_inputs((2, 4, 2, 37, 37, 16), jnp.float32, salt=3)
    _, vjp = jax.vjp(lambda *a: ref.attention_ref(
        *a, causal=causal, window=window), q, k, v)
    want = vjp(do)
    got = ref.attention_vjp_ref(q, k, v, do, causal=causal, window=window)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", ATTN_GRAD_SHAPES)
@pytest.mark.parametrize("causal,window", [(True, None), (True, 13),
                                           (False, None)])
def test_flash_backward_kernel_vs_hand_vjp(shape, causal, window):
    """flash_attention_backward_pallas directly against the hand oracle,
    fed the forward kernel's own (o, lse) residuals."""
    from repro.kernels.flash_attention import (
        flash_attention_backward_pallas, flash_attention_pallas)
    q, k, v, do = _attn_inputs(shape, jnp.float32, salt=7)
    o, lse = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                    block_q=32, block_k=32,
                                    return_residuals=True, interpret=True)
    dq, dk, dv = flash_attention_backward_pallas(
        q, k, v, o, lse, do, causal=causal, window=window, block_q=32,
        block_k=32, interpret=True)
    dqr, dkr, dvr = ref.attention_vjp_ref(q, k, v, do, causal=causal,
                                          window=window)
    np.testing.assert_allclose(dq, dqr, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(dk, dkr, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(dv, dvr, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# ghost batch norm kernel
# ---------------------------------------------------------------------------

GBN_SHAPES = [(1, 16, 8), (4, 300, 96), (2, 1024, 128), (3, 77, 200)]


@pytest.mark.parametrize("shape", GBN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gbn_kernel_vs_ref(shape, dtype):
    G, R, C = shape
    rng = jax.random.PRNGKey(G * 1000 + R)
    xg = (2.0 * jax.random.normal(rng, shape, jnp.float32) + 0.5).astype(dtype)
    gamma = jnp.linspace(0.5, 1.5, C)
    beta = jnp.linspace(-1.0, 1.0, C)
    y, mu, var = ops.gbn_forward(xg, gamma, beta)
    yr, mur, varr = ref.gbn_ref(xg, gamma, beta)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mur),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(var), np.asarray(varr),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=10 * tol, atol=10 * tol)


def test_gbn_kernel_inside_module():
    """core.gbn_apply(use_kernels=True) matches the jnp path."""
    from repro.core.gbn import gbn_apply, gbn_init
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 24)) * 2 + 1
    params, state = gbn_init(24)
    y0, s0 = gbn_apply(params, state, x, ghost_batch_size=16)
    y1, s1 = gbn_apply(params, state, x, ghost_batch_size=16,
                       use_kernels=True)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s0["mu_run"], s1["mu_run"], rtol=1e-4,
                               atol=1e-4)


def test_gbn_kernel_leftover_rows():
    """B not divisible by the ghost size: the tail is normalized with the
    last ghost's stats; kernel and jnp paths must agree (fwd AND grad)."""
    from repro.core.gbn import gbn_apply, gbn_init
    x = jax.random.normal(jax.random.PRNGKey(3), (70, 24)) * 2 + 1
    params, state = gbn_init(24)
    y0, s0 = gbn_apply(params, state, x, ghost_batch_size=16)
    y1, s1 = gbn_apply(params, state, x, ghost_batch_size=16,
                       use_kernels=True)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s0["mu_run"], s1["mu_run"], rtol=1e-4,
                               atol=1e-4)
    # the tail path makes the mu/var outputs of the kernel gradient-carrying
    w = jax.random.normal(jax.random.PRNGKey(4), (70, 24))

    def loss(p, uk):
        y, _ = gbn_apply(p, state, x, ghost_batch_size=16, use_kernels=uk)
        return (y * w).sum()

    g0 = jax.grad(lambda p: loss(p, False))(params)
    g1 = jax.grad(lambda p: loss(p, True))(params)
    for k in g0:
        np.testing.assert_allclose(g0[k], g1[k], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ghost batch norm kernel — gradients (Pallas backward via custom_vjp)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", GBN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gbn_grad_vs_ref(shape, dtype):
    """jax.grad through the kernel custom_vjp == jax.grad through the
    oracle, with live cotangents on ALL THREE outputs (y, mu, var)."""
    G, R, C = shape
    rng = jax.random.PRNGKey(G * 777 + R)
    xg = (2.0 * jax.random.normal(rng, shape, jnp.float32) + 0.5).astype(dtype)
    gamma = jnp.linspace(0.5, 1.5, C)
    beta = jnp.linspace(-1.0, 1.0, C)
    wy = jax.random.normal(jax.random.fold_in(rng, 1), shape)
    wm = jax.random.normal(jax.random.fold_in(rng, 2), (G, C))
    wv = jax.random.normal(jax.random.fold_in(rng, 3), (G, C))

    def make_loss(f):
        def loss(x, g, b):
            y, mu, var = f(x, g, b)
            return ((y.astype(jnp.float32) * wy).sum()
                    + (mu * wm).sum() + (var * wv).sum())
        return loss

    gk = jax.grad(make_loss(ops.gbn_forward), argnums=(0, 1, 2))(
        xg, gamma, beta)
    gr = jax.grad(make_loss(ref.gbn_ref), argnums=(0, 1, 2))(xg, gamma, beta)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", GBN_SHAPES)
def test_gbn_backward_kernel_vs_hand_vjp(shape):
    """gbn_backward_pallas directly against the hand-derived oracle VJP."""
    from repro.kernels.gbn import gbn_backward_pallas
    G, R, C = shape
    rng = jax.random.PRNGKey(G + R + C)
    xg = 2.0 * jax.random.normal(rng, shape) + 0.5
    gamma = jnp.linspace(0.5, 1.5, C)
    beta = jnp.zeros((C,))
    dy = jax.random.normal(jax.random.fold_in(rng, 1), shape)
    dmu = jax.random.normal(jax.random.fold_in(rng, 2), (G, C))
    dvar = jax.random.normal(jax.random.fold_in(rng, 3), (G, C))
    _, mu, var = ref.gbn_ref(xg, gamma, beta)
    dx, dgamma, dbeta = gbn_backward_pallas(xg, gamma, mu, var, dy, dmu,
                                            dvar, interpret=True)
    dxr, dgr, dbr = ref.gbn_vjp_ref(xg, gamma, beta, (dy, dmu, dvar))
    np.testing.assert_allclose(dx, dxr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dgamma, dgr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dbeta, dbr, rtol=1e-4, atol=1e-4)


def test_gbn_vjp_ref_matches_autodiff():
    """The hand-derived oracle VJP == jax.vjp of the jnp oracle."""
    G, R, C = 3, 50, 17
    rng = jax.random.PRNGKey(5)
    xg = jax.random.normal(rng, (G, R, C)) * 3 - 1
    gamma = jnp.linspace(0.2, 2.0, C)
    beta = jnp.linspace(-0.5, 0.5, C)
    cts = (jax.random.normal(jax.random.fold_in(rng, 1), (G, R, C)),
           jax.random.normal(jax.random.fold_in(rng, 2), (G, C)),
           jax.random.normal(jax.random.fold_in(rng, 3), (G, C)))
    _, vjp = jax.vjp(lambda *a: ref.gbn_ref(*a), xg, gamma, beta)
    want = vjp(cts)
    got = ref.gbn_vjp_ref(xg, gamma, beta, cts)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_vision_train_step_kernel_path_matches():
    """A full make_vision_train_step(use_kernels=True) step runs under grad
    and matches the non-kernel step's loss and updated params."""
    import dataclasses
    from repro.configs.paper_models import F1_MNIST
    from repro.core import LargeBatchConfig, Regime
    from repro.models.cnn import model_fns
    from repro.optim import sgd
    from repro.train.trainer import make_vision_train_step
    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(32,), ghost_batch_size=16)
    lb = LargeBatchConfig(batch_size=64, base_batch_size=64,
                          ghost_batch_size=16)
    regime = Regime(base_lr=0.1, total_steps=10, drop_every=10)
    init_fn, apply_fn = model_fns(cfg)
    params, bn = init_fn(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8, 8, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (64,), 0, 10)
    outs = {}
    for uk in (False, True):
        step = jax.jit(make_vision_train_step(apply_fn, cfg, lb, regime,
                                              use_kernels=uk))
        outs[uk] = step(params, bn, opt, x, y, jnp.int32(0),
                        jax.random.PRNGKey(3))
    p0, _, _, m0 = outs[False]
    p1, _, _, m1 = outs[True]
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mamba chunk scan kernel
# ---------------------------------------------------------------------------

MAMBA_SHAPES = [
    # (B, c, di, ds)
    (1, 8, 128, 8),
    (2, 16, 256, 16),
    (2, 32, 512, 16),
]


@pytest.mark.parametrize("shape", MAMBA_SHAPES)
def test_mamba_chunk_vs_ref(shape):
    B, c, di, ds = shape
    rng = jax.random.PRNGKey(sum(shape))
    xc = jax.random.normal(rng, (B, c, di))
    dt = 0.1 * jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(rng, 1), (B, c, di)))
    Bm = jax.random.normal(jax.random.fold_in(rng, 2), (B, c, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 3), (B, c, ds))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 4), (di, ds)))
    h0 = jax.random.normal(jax.random.fold_in(rng, 5), (B, di, ds))
    y, h = ops.mamba_chunk(xc, dt, Bm, Cm, A, h0)
    yr, hr = ref.mamba_chunk_ref(xc, dt, Bm, Cm, A, h0)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, hr, rtol=1e-4, atol=1e-4)


def test_mamba_chunk_chains_across_chunks():
    """Carrying h across two chunks == one long reference scan."""
    B, c, di, ds = 1, 8, 128, 8
    rng = jax.random.PRNGKey(9)
    xc = jax.random.normal(rng, (B, 2 * c, di))
    dt = 0.1 * jnp.ones((B, 2 * c, di))
    Bm = jax.random.normal(jax.random.fold_in(rng, 1), (B, 2 * c, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 2), (B, 2 * c, ds))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (di, ds)))
    h0 = jnp.zeros((B, di, ds))
    y1, h1 = ops.mamba_chunk(xc[:, :c], dt[:, :c], Bm[:, :c], Cm[:, :c], A, h0)
    y2, h2 = ops.mamba_chunk(xc[:, c:], dt[:, c:], Bm[:, c:], Cm[:, c:], A, h1)
    yr, hr = ref.mamba_chunk_ref(xc, dt, Bm, Cm, A, h0)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), yr,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, hr, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mamba chunk scan kernel — gradients (Pallas backward via custom_vjp)
# ---------------------------------------------------------------------------


def _mamba_inputs(B, c, di, ds, key=0, dtype=jnp.float32):
    rng = jax.random.PRNGKey(key)
    xc = jax.random.normal(rng, (B, c, di)).astype(dtype)
    dt = (0.1 * jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(rng, 1), (B, c, di)))).astype(dtype)
    Bm = jax.random.normal(jax.random.fold_in(rng, 2), (B, c, ds)).astype(dtype)
    Cm = jax.random.normal(jax.random.fold_in(rng, 3), (B, c, ds)).astype(dtype)
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 4), (di, ds)))
    h0 = jax.random.normal(jax.random.fold_in(rng, 5), (B, di, ds))
    return xc, dt, Bm, Cm, A, h0


@pytest.mark.parametrize("shape", MAMBA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_grad_vs_ref(shape, dtype):
    """jax.grad through the kernel custom_vjp == jax.grad through the
    oracle, with live cotangents on BOTH outputs (y and h_last) and a
    nonzero h0."""
    B, c, di, ds = shape
    args = _mamba_inputs(B, c, di, ds, key=sum(shape), dtype=dtype)
    rng = jax.random.PRNGKey(sum(shape) + 1)
    wy = jax.random.normal(rng, (B, c, di))
    wh = jax.random.normal(jax.random.fold_in(rng, 1), (B, di, ds))

    def make_loss(f):
        def loss(*a):
            y, h_last = f(*a)
            return (y * wy).sum() + (h_last * wh).sum()
        return loss

    gk = jax.grad(make_loss(ops.mamba_chunk),
                  argnums=tuple(range(6)))(*args)
    gr = jax.grad(make_loss(ref.mamba_chunk_ref),
                  argnums=tuple(range(6)))(*args)
    tol = 2e-4 if dtype == jnp.float32 else 1e-1
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.tier0
def test_mamba_grad_multichunk_smoke():
    """Quick-gate case: grads through TWO chained kernel chunks (nonzero
    carried h) == grads through one long oracle scan."""
    B, c, di, ds = 1, 8, 128, 8
    xc, dt, Bm, Cm, A, h0 = _mamba_inputs(B, 2 * c, di, ds, key=11)
    wy = jax.random.normal(jax.random.PRNGKey(12), (B, 2 * c, di))

    def two_chunk(xc, dt, Bm, Cm, A, h0):
        y1, h1 = ops.mamba_chunk(xc[:, :c], dt[:, :c], Bm[:, :c],
                                 Cm[:, :c], A, h0)
        y2, _ = ops.mamba_chunk(xc[:, c:], dt[:, c:], Bm[:, c:],
                                Cm[:, c:], A, h1)
        return jnp.concatenate([y1, y2], axis=1)

    gk = jax.grad(lambda *a: (two_chunk(*a) * wy).sum(),
                  argnums=tuple(range(6)))(xc, dt, Bm, Cm, A, h0)
    gr = jax.grad(lambda *a: (ref.mamba_chunk_ref(*a)[0] * wy).sum(),
                  argnums=tuple(range(6)))(xc, dt, Bm, Cm, A, h0)
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", MAMBA_SHAPES)
def test_mamba_backward_kernel_vs_oracle_vjp(shape):
    """mamba_chunk_backward_pallas directly against the oracle VJP."""
    from repro.kernels.mamba_scan import mamba_chunk_backward_pallas
    B, c, di, ds = shape
    args = _mamba_inputs(B, c, di, ds, key=sum(shape) + 5)
    rng = jax.random.PRNGKey(sum(shape) + 6)
    dy = jax.random.normal(rng, (B, c, di))
    dhl = jax.random.normal(jax.random.fold_in(rng, 1), (B, di, ds))
    got = mamba_chunk_backward_pallas(*args, dy, dhl, di_tile=128,
                                      interpret=True)
    want = ref.mamba_chunk_vjp_ref(*args, (dy, dhl))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=2e-4)


def test_mamba_backward_no_oracle_replay(monkeypatch):
    """The custom-VJP backward must not re-run the oracle forward: poison
    the oracle and check jax.grad through the kernel path still works."""
    def boom(*a, **kw):
        raise AssertionError("oracle forward replayed in backward")

    monkeypatch.setattr(ref, "mamba_chunk_ref", boom)
    monkeypatch.setattr(ops.ref, "mamba_chunk_ref", boom)
    args = _mamba_inputs(1, 8, 128, 8, key=21)
    g = jax.grad(lambda *a: ops.mamba_chunk(*a)[0].sum(),
                 argnums=(0,))(*args)
    assert np.all(np.isfinite(np.asarray(g[0])))


def test_mamba_unaligned_tile_fallback():
    """d_inner without a 128-multiple divisor runs as one untiled
    whole-axis block (with a one-time warning) instead of silently dropping
    to the oracle; past the VMEM bound it still gets the oracle, loudly.
    Both stay correct (fwd and grad)."""
    import warnings as warnings_mod
    assert ops._mamba_tile(100) == 100            # untiled whole axis
    assert ops._mamba_tile(192) == 192
    assert ops._mamba_tile(640) == 128            # 128-multiple: strict tile
    assert ops._mamba_tile(1100) is None          # past the VMEM bound

    ops._TILE_WARNED.clear()
    with warnings_mod.catch_warnings(record=True) as rec:
        warnings_mod.simplefilter("always")
        args = _mamba_inputs(1, 8, 100, 8, key=31)
        y, h = ops.mamba_chunk(*args)
        yr, hr = ref.mamba_chunk_ref(*args)
        np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(h, hr, rtol=1e-4, atol=1e-4)
        gk = jax.grad(lambda *a: ops.mamba_chunk(*a)[0].sum(),
                      argnums=(0, 4))(*args)
        gr = jax.grad(lambda *a: ref.mamba_chunk_ref(*a)[0].sum(),
                      argnums=(0, 4))(*args)
        for g, w in zip(gk, gr):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)
    assert any("no 128-multiple divisor" in str(w.message) for w in rec)

    ops._TILE_WARNED.clear()
    with warnings_mod.catch_warnings(record=True) as rec:
        warnings_mod.simplefilter("always")
        args = _mamba_inputs(1, 8, 1100, 8, key=32)   # oracle fallback
        y, h = ops.mamba_chunk(*args)
        yr, hr = ref.mamba_chunk_ref(*args)
        np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
        # the oracle-fallback custom_vjp branch must also differentiate
        gk = jax.grad(lambda *a: ops.mamba_chunk(*a)[0].sum(),
                      argnums=(0, 4))(*args)
        gr = jax.grad(lambda *a: ref.mamba_chunk_ref(*a)[0].sum(),
                      argnums=(0, 4))(*args)
        for g, w in zip(gk, gr):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)
    assert any("un-tiled jnp oracle" in str(w.message) for w in rec)


# ---------------------------------------------------------------------------
# LM train step through both kernel mixers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "falcon-mamba-7b"])
def test_lm_train_step_kernel_path_matches(arch):
    """A full make_lm_train_step(use_kernels=True) step runs under grad
    through the Pallas attention / Mamba custom-VJPs and matches the
    non-kernel step's loss and updated params."""
    import dataclasses
    from repro.configs.registry import get_config
    from repro.core import LargeBatchConfig, Regime
    from repro.models import transformer as T
    from repro.optim import sgd
    from repro.train.trainer import make_lm_train_step
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    B, S = 2, 32
    lb = LargeBatchConfig(batch_size=B, base_batch_size=B, grad_clip=1.0)
    regime = Regime(base_lr=0.01, total_steps=10, drop_every=10)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size)}
    outs = {}
    for uk in (False, True):
        step = jax.jit(make_lm_train_step(cfg, lb, regime, use_kernels=uk))
        outs[uk] = step(params, opt, batch, jnp.int32(0),
                        jax.random.PRNGKey(2))
    np.testing.assert_allclose(float(outs[False][2]["loss"]),
                               float(outs[True][2]["loss"]),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[False][0]),
                    jax.tree.leaves(outs[True][0])):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
