"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracles in kernels/ref.py (kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.tier1

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # (B, H, KV, T, S, hd)
    (1, 2, 2, 17, 17, 32),
    (2, 4, 2, 64, 64, 64),
    (1, 8, 1, 128, 128, 64),     # MQA
    (2, 4, 4, 100, 100, 128),    # MHA, ragged T
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 13),
                                           (False, None)])
def test_flash_attention_vs_ref(shape, dtype, causal, window):
    B, H, KV, T, S, hd = shape
    rng = jax.random.PRNGKey(hash((shape, causal, window or 0)) % 2**31)
    q = jax.random.normal(rng, (B, H, T, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, KV, S, hd),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, KV, S, hd),
                          jnp.float32).astype(dtype)
    out = ops.flash_attention_hm(q, k, v, causal=causal, window=window,
                                 block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_model_layout():
    """(B, T, H, hd) adapter used by the model code."""
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 32, 4, 64))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, 2, 64))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 32, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ghost batch norm kernel
# ---------------------------------------------------------------------------

GBN_SHAPES = [(1, 16, 8), (4, 300, 96), (2, 1024, 128), (3, 77, 200)]


@pytest.mark.parametrize("shape", GBN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gbn_kernel_vs_ref(shape, dtype):
    G, R, C = shape
    rng = jax.random.PRNGKey(G * 1000 + R)
    xg = (2.0 * jax.random.normal(rng, shape, jnp.float32) + 0.5).astype(dtype)
    gamma = jnp.linspace(0.5, 1.5, C)
    beta = jnp.linspace(-1.0, 1.0, C)
    y, mu, var = ops.gbn_forward(xg, gamma, beta)
    yr, mur, varr = ref.gbn_ref(xg, gamma, beta)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mur),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(var), np.asarray(varr),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=10 * tol, atol=10 * tol)


def test_gbn_kernel_inside_module():
    """core.gbn_apply(use_kernels=True) matches the jnp path."""
    from repro.core.gbn import gbn_apply, gbn_init
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 24)) * 2 + 1
    params, state = gbn_init(24)
    y0, s0 = gbn_apply(params, state, x, ghost_batch_size=16)
    y1, s1 = gbn_apply(params, state, x, ghost_batch_size=16,
                       use_kernels=True)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s0["mu_run"], s1["mu_run"], rtol=1e-4,
                               atol=1e-4)


def test_gbn_kernel_leftover_rows():
    """B not divisible by the ghost size: the tail is normalized with the
    last ghost's stats; kernel and jnp paths must agree (fwd AND grad)."""
    from repro.core.gbn import gbn_apply, gbn_init
    x = jax.random.normal(jax.random.PRNGKey(3), (70, 24)) * 2 + 1
    params, state = gbn_init(24)
    y0, s0 = gbn_apply(params, state, x, ghost_batch_size=16)
    y1, s1 = gbn_apply(params, state, x, ghost_batch_size=16,
                       use_kernels=True)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s0["mu_run"], s1["mu_run"], rtol=1e-4,
                               atol=1e-4)
    # the tail path makes the mu/var outputs of the kernel gradient-carrying
    w = jax.random.normal(jax.random.PRNGKey(4), (70, 24))

    def loss(p, uk):
        y, _ = gbn_apply(p, state, x, ghost_batch_size=16, use_kernels=uk)
        return (y * w).sum()

    g0 = jax.grad(lambda p: loss(p, False))(params)
    g1 = jax.grad(lambda p: loss(p, True))(params)
    for k in g0:
        np.testing.assert_allclose(g0[k], g1[k], rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ghost batch norm kernel — gradients (Pallas backward via custom_vjp)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", GBN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gbn_grad_vs_ref(shape, dtype):
    """jax.grad through the kernel custom_vjp == jax.grad through the
    oracle, with live cotangents on ALL THREE outputs (y, mu, var)."""
    G, R, C = shape
    rng = jax.random.PRNGKey(G * 777 + R)
    xg = (2.0 * jax.random.normal(rng, shape, jnp.float32) + 0.5).astype(dtype)
    gamma = jnp.linspace(0.5, 1.5, C)
    beta = jnp.linspace(-1.0, 1.0, C)
    wy = jax.random.normal(jax.random.fold_in(rng, 1), shape)
    wm = jax.random.normal(jax.random.fold_in(rng, 2), (G, C))
    wv = jax.random.normal(jax.random.fold_in(rng, 3), (G, C))

    def make_loss(f):
        def loss(x, g, b):
            y, mu, var = f(x, g, b)
            return ((y.astype(jnp.float32) * wy).sum()
                    + (mu * wm).sum() + (var * wv).sum())
        return loss

    gk = jax.grad(make_loss(ops.gbn_forward), argnums=(0, 1, 2))(
        xg, gamma, beta)
    gr = jax.grad(make_loss(ref.gbn_ref), argnums=(0, 1, 2))(xg, gamma, beta)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    for got, want in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", GBN_SHAPES)
def test_gbn_backward_kernel_vs_hand_vjp(shape):
    """gbn_backward_pallas directly against the hand-derived oracle VJP."""
    from repro.kernels.gbn import gbn_backward_pallas
    G, R, C = shape
    rng = jax.random.PRNGKey(G + R + C)
    xg = 2.0 * jax.random.normal(rng, shape) + 0.5
    gamma = jnp.linspace(0.5, 1.5, C)
    beta = jnp.zeros((C,))
    dy = jax.random.normal(jax.random.fold_in(rng, 1), shape)
    dmu = jax.random.normal(jax.random.fold_in(rng, 2), (G, C))
    dvar = jax.random.normal(jax.random.fold_in(rng, 3), (G, C))
    _, mu, var = ref.gbn_ref(xg, gamma, beta)
    dx, dgamma, dbeta = gbn_backward_pallas(xg, gamma, mu, var, dy, dmu,
                                            dvar, interpret=True)
    dxr, dgr, dbr = ref.gbn_vjp_ref(xg, gamma, beta, (dy, dmu, dvar))
    np.testing.assert_allclose(dx, dxr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dgamma, dgr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dbeta, dbr, rtol=1e-4, atol=1e-4)


def test_gbn_vjp_ref_matches_autodiff():
    """The hand-derived oracle VJP == jax.vjp of the jnp oracle."""
    G, R, C = 3, 50, 17
    rng = jax.random.PRNGKey(5)
    xg = jax.random.normal(rng, (G, R, C)) * 3 - 1
    gamma = jnp.linspace(0.2, 2.0, C)
    beta = jnp.linspace(-0.5, 0.5, C)
    cts = (jax.random.normal(jax.random.fold_in(rng, 1), (G, R, C)),
           jax.random.normal(jax.random.fold_in(rng, 2), (G, C)),
           jax.random.normal(jax.random.fold_in(rng, 3), (G, C)))
    _, vjp = jax.vjp(lambda *a: ref.gbn_ref(*a), xg, gamma, beta)
    want = vjp(cts)
    got = ref.gbn_vjp_ref(xg, gamma, beta, cts)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_vision_train_step_kernel_path_matches():
    """A full make_vision_train_step(use_kernels=True) step runs under grad
    and matches the non-kernel step's loss and updated params."""
    import dataclasses
    from repro.configs.paper_models import F1_MNIST
    from repro.core import LargeBatchConfig, Regime
    from repro.models.cnn import model_fns
    from repro.optim import sgd
    from repro.train.trainer import make_vision_train_step
    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(32,), ghost_batch_size=16)
    lb = LargeBatchConfig(batch_size=64, base_batch_size=64,
                          ghost_batch_size=16)
    regime = Regime(base_lr=0.1, total_steps=10, drop_every=10)
    init_fn, apply_fn = model_fns(cfg)
    params, bn = init_fn(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8, 8, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (64,), 0, 10)
    outs = {}
    for uk in (False, True):
        step = jax.jit(make_vision_train_step(apply_fn, cfg, lb, regime,
                                              use_kernels=uk))
        outs[uk] = step(params, bn, opt, x, y, jnp.int32(0),
                        jax.random.PRNGKey(3))
    p0, _, _, m0 = outs[False]
    p1, _, _, m1 = outs[True]
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mamba chunk scan kernel
# ---------------------------------------------------------------------------

MAMBA_SHAPES = [
    # (B, c, di, ds)
    (1, 8, 128, 8),
    (2, 16, 256, 16),
    (2, 32, 512, 16),
]


@pytest.mark.parametrize("shape", MAMBA_SHAPES)
def test_mamba_chunk_vs_ref(shape):
    B, c, di, ds = shape
    rng = jax.random.PRNGKey(sum(shape))
    xc = jax.random.normal(rng, (B, c, di))
    dt = 0.1 * jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(rng, 1), (B, c, di)))
    Bm = jax.random.normal(jax.random.fold_in(rng, 2), (B, c, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 3), (B, c, ds))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 4), (di, ds)))
    h0 = jax.random.normal(jax.random.fold_in(rng, 5), (B, di, ds))
    y, h = ops.mamba_chunk(xc, dt, Bm, Cm, A, h0)
    yr, hr = ref.mamba_chunk_ref(xc, dt, Bm, Cm, A, h0)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h, hr, rtol=1e-4, atol=1e-4)


def test_mamba_chunk_chains_across_chunks():
    """Carrying h across two chunks == one long reference scan."""
    B, c, di, ds = 1, 8, 128, 8
    rng = jax.random.PRNGKey(9)
    xc = jax.random.normal(rng, (B, 2 * c, di))
    dt = 0.1 * jnp.ones((B, 2 * c, di))
    Bm = jax.random.normal(jax.random.fold_in(rng, 1), (B, 2 * c, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 2), (B, 2 * c, ds))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 3), (di, ds)))
    h0 = jnp.zeros((B, di, ds))
    y1, h1 = ops.mamba_chunk(xc[:, :c], dt[:, :c], Bm[:, :c], Cm[:, :c], A, h0)
    y2, h2 = ops.mamba_chunk(xc[:, c:], dt[:, c:], Bm[:, c:], Cm[:, c:], A, h1)
    yr, hr = ref.mamba_chunk_ref(xc, dt, Bm, Cm, A, h0)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), yr,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, hr, rtol=1e-4, atol=1e-4)
