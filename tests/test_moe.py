"""MoE routing invariants (property-based) + EP shard_map equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.configs.registry import get_config
from repro.launch.mesh import make_host_mesh


def _mk_cfg(E=4, k=2, cf=1.25, shared=0):
    return ModelConfig(
        name="t", family="moe", d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, vocab_size=64,
        body_pattern=(LayerSpec(mixer="attn", ff="moe"),), body_repeats=1,
        moe=MoEConfig(n_experts=E, top_k=k, d_expert=16,
                      capacity_factor=cf, n_shared_experts=shared,
                      d_shared=16 if shared else 0),
        dtype="float32")


@settings(max_examples=20, deadline=None)
@given(E=st.sampled_from([4, 8]), k=st.integers(1, 3),
       seed=st.integers(0, 100))
def test_property_capacity_never_exceeded(E, k, seed):
    """No expert ever receives more than C tokens (per sequence)."""
    cfg = _mk_cfg(E=E, k=k, cf=1.0)
    m = cfg.moe
    rng = jax.random.PRNGKey(seed)
    params = MOE.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 24, cfg.d_model))
    y, aux = MOE.moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert not jnp.isnan(y).any()
    assert float(aux["moe_aux"]) >= 0.99   # E*sum f*P >= 1 by Cauchy-Schwarz


def test_dropless_outputs_match_manual():
    """With huge capacity, the MoE output equals the dense per-token sum of
    top-k expert MLPs."""
    cfg = _mk_cfg(E=4, k=2, cf=100.0)
    m = cfg.moe
    rng = jax.random.PRNGKey(0)
    params = MOE.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (1, 8, cfg.d_model))
    y, _ = MOE.moe_apply(params, cfg, x)

    # manual dense computation
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, 2)
    topw = topw / topw.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for t in range(8):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(2):
            e = int(topi[0, t, j])
            g = jax.nn.silu(x[0, t] @ params["w_gate"][e])
            u = x[0, t] @ params["w_up"][e]
            acc += float(topw[0, t, j]) * ((g * u) @ params["w_down"][e])
        want = want.at[0, t].set(acc)
    np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)


def test_drops_occur_at_low_capacity():
    """With capacity factor << 1 some assignments must drop (output is the
    shared/残 partial sum only for dropped tokens)."""
    cfg = _mk_cfg(E=4, k=1, cf=0.3)
    rng = jax.random.PRNGKey(0)
    params = MOE.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (1, 32, cfg.d_model))
    y_low, _ = MOE.moe_apply(params, cfg, x)
    cfg_hi = _mk_cfg(E=4, k=1, cf=100.0)
    y_hi, _ = MOE.moe_apply(params, cfg_hi, x)
    # some tokens differ (dropped), but not all
    diff = jnp.abs(y_low - y_hi).max(axis=-1)[0]
    assert (diff > 1e-6).any()
    assert (diff < 1e-6).any()


def test_shared_expert_always_on():
    cfg = _mk_cfg(E=4, k=1, cf=0.01, shared=1)   # drop ~everything routed
    rng = jax.random.PRNGKey(0)
    params = MOE.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (1, 16, cfg.d_model))
    y, _ = MOE.moe_apply(params, cfg, x)
    # shared expert output present even for dropped tokens
    from repro.models.layers import mlp_apply
    shared = mlp_apply(params["shared"], x)
    resid = jnp.abs(y - shared).max(axis=-1)[0]
    assert float(resid.min()) < 1e-5


def test_ep_shard_map_equals_fallback():
    """kimi reduced config: EP path under a 1x1 mesh == no-mesh fallback."""
    cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").reduced(),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    l0, _ = T.forward(params, cfg, toks)
    with make_host_mesh():
        l1, _ = jax.jit(lambda p, t: T.forward(p, cfg, t))(params, toks)
    np.testing.assert_allclose(l0, l1, rtol=2e-4, atol=2e-4)


def test_router_weights_normalized():
    cfg = _mk_cfg(E=8, k=3)
    params = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    topi, topw, aux = MOE._route(params["router"], x, cfg.moe)
    np.testing.assert_allclose(topw.sum(-1), 1.0, rtol=1e-5)
    assert topi.shape == (2, 8, 3)
    # top-k indices are distinct per token
    for b in range(2):
        for t in range(8):
            assert len(set(np.asarray(topi[b, t]).tolist())) == 3
