"""Observability layer: span tracer (nesting, Chrome export, zero-cost
disabled path), streaming histograms vs numpy quantiles, the metrics
registry (JSONL export, summary table, kind safety), the MetricsLogger
dedup shims, and the engine/trainer SLO wiring."""
import dataclasses
import json
import tracemalloc

import numpy as np
import pytest

from repro.obs import Observability
from repro.obs.metrics import Histogram, MetricsLogger, Registry
from repro.obs.trace import NULL_SPAN, NULL_TRACER, Tracer


# ---------------------------------------------------------------------------
# tracer (tier0 — pure python, runs in --quick)
# ---------------------------------------------------------------------------


@pytest.mark.tier0
def test_span_nesting_and_ordering():
    tr = Tracer()
    with tr.span("outer", k=1):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b"):
            pass
    # "X" events append on exit: children close before the parent
    names = [e["name"] for e in tr.events]
    assert names == ["inner_a", "inner_b", "outer"]
    by = {e["name"]: e for e in tr.events}
    out, a, b = by["outer"], by["inner_a"], by["inner_b"]
    # containment on one pid/tid track is what Perfetto nests by
    assert out["ts"] <= a["ts"] and out["ts"] <= b["ts"]
    assert a["ts"] + a["dur"] <= out["ts"] + out["dur"] + 1e-6
    assert b["ts"] >= a["ts"] + a["dur"] - 1e-6       # siblings ordered
    assert out["args"] == {"k": 1}
    assert out["tid"] == a["tid"] == b["tid"]


@pytest.mark.tier0
def test_chrome_trace_json_valid(tmp_path):
    tr = Tracer()
    with tr.span("root"):
        with tr.span("child", i=3):
            pass
    tr.instant("marker")
    path = tmp_path / "trace.json"
    tr.write_chrome(str(path))
    events = json.loads(path.read_text())
    assert isinstance(events, list) and len(events) == 3
    for ev in events:
        assert {"name", "ph", "pid", "tid", "ts"} <= set(ev)
        assert ev["ph"] in ("X", "i")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0


@pytest.mark.tier0
def test_disabled_tracer_zero_cost():
    tr = Tracer(enabled=False)
    # the disabled path returns ONE shared singleton: no per-span object
    s1 = tr.span("a", k=1)
    s2 = tr.span("b")
    assert s1 is s2 is NULL_SPAN is NULL_TRACER.span("c")
    tracemalloc.start()
    for i in range(100):
        with tr.span("hot", step=i):
            pass
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 4096                     # no event/span allocations
    assert tr.events == [] and NULL_TRACER.events == []


@pytest.mark.tier0
def test_tracer_clear():
    tr = Tracer()
    with tr.span("a"):
        pass
    tr.clear()
    assert tr.events == []


# ---------------------------------------------------------------------------
# histograms / registry (tier0)
# ---------------------------------------------------------------------------


@pytest.mark.tier0
@pytest.mark.parametrize("dist", ["uniform", "lognormal", "negative"])
def test_histogram_quantiles_vs_numpy(dist):
    rng = np.random.RandomState(0)
    x = {"uniform": rng.uniform(0.5, 20.0, 20_000),
         "lognormal": rng.lognormal(0.0, 1.0, 20_000),
         "negative": -rng.lognormal(0.0, 0.5, 20_000)}[dist]
    h = Histogram()
    for v in x:
        h.observe(float(v))
    for q in (0.5, 0.95, 0.99):
        got, want = h.quantile(q), float(np.quantile(x, q))
        assert got == pytest.approx(want, rel=0.03), (q, got, want)
    assert h.count == len(x)
    assert h.quantile(0.0) == pytest.approx(x.min())
    assert h.quantile(1.0) == pytest.approx(x.max())


@pytest.mark.tier0
def test_histogram_exact_fields():
    h = Histogram()
    for v in (1.0, 2.0, 0.0, -3.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4
    assert s["mean"] == pytest.approx(0.0)
    assert s["min"] == -3.0 and s["max"] == 2.0 and s["last"] == -3.0


@pytest.mark.tier0
def test_registry_jsonl_and_summary(tmp_path):
    reg = Registry()
    reg.inc("req", 3)
    reg.set("depth", 7.0)
    for v in (0.1, 0.2, 0.3):
        reg.observe("lat_s", v)
    path = tmp_path / "metrics.jsonl"
    reg.write_jsonl(str(path))
    reg.write_jsonl(str(path))             # append mode: 2 runs accumulate
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(rows) == 6
    by = {r["name"]: r for r in rows[:3]}
    assert by["req"]["kind"] == "counter" and by["req"]["value"] == 3
    assert by["depth"]["kind"] == "gauge" and by["depth"]["value"] == 7.0
    lat = by["lat_s"]
    assert lat["kind"] == "histogram" and lat["count"] == 3
    assert {"p50", "p95", "p99", "mean"} <= set(lat)
    assert all("ts" in r for r in rows)
    table = reg.summary_table()
    for name in ("req", "depth", "lat_s"):
        assert name in table


@pytest.mark.tier0
def test_registry_kind_mismatch_raises():
    reg = Registry()
    reg.inc("n")
    with pytest.raises(TypeError):
        reg.observe("n", 1.0)


@pytest.mark.tier0
def test_observability_bundle(tmp_path):
    obs = Observability()
    with obs.span("work"):
        obs.registry.observe("x", 1.0)
    obs.write(str(tmp_path / "t.json"), str(tmp_path / "m.jsonl"))
    assert json.loads((tmp_path / "t.json").read_text())
    assert (tmp_path / "m.jsonl").read_text().strip()
    obs.clear()
    assert obs.tracer.events == [] and obs.registry.names() == []


# ---------------------------------------------------------------------------
# MetricsLogger dedup: one implementation, both legacy import paths
# ---------------------------------------------------------------------------


@pytest.mark.tier0
def test_metrics_logger_single_implementation():
    from repro.core.metrics import MetricsLogger as core_ML
    from repro.experiments.metrics import MetricsLogger as exp_ML
    assert core_ML is exp_ML is MetricsLogger
    assert core_ML.__module__ == "repro.obs.metrics"


@pytest.mark.tier0
def test_metrics_logger_attach_registry():
    reg = Registry()
    ml = MetricsLogger()
    ml.attach_registry(reg, prefix="train/")
    ml.log(0, loss=2.0)
    ml.log(1, loss=1.5)
    ml.set_series("distance", [0, 1], [0.1, 0.2])
    assert ml.series("loss") == ([0, 1], [2.0, 1.5])  # logger unchanged
    assert reg.histogram("train/loss").count == 2
    assert reg.histogram("train/distance").last == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# wiring: engine SLOs + trainer telemetry (tier1 — compiles tiny models)
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_engine_slo_metrics_under_poisson_trace():
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.serving import ContinuousEngine, poisson_trace
    import jax
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = poisson_trace(cfg, 5, rate=0.7, seed=0,
                         prompt_len_choices=(4, 8),
                         new_token_choices=(4, 8))
    obs = Observability()
    eng = ContinuousEngine(params, cfg, num_slots=2, max_len=32,
                           layout="paged", page_size=8, total_pages=9,
                           obs=obs)
    comps = eng.run(reqs)
    useful = sum(len(c.tokens) for c in comps.values())
    reg = obs.registry
    # SLO set: per-request latencies observed once per completion
    assert reg.histogram("serve/ttft_s").count == len(comps)
    assert reg.histogram("serve/e2e_s").count == len(comps)
    assert reg.histogram("serve/itl_s").count >= useful - len(comps)
    # per-tick scheduler gauges sampled once per decode step
    for name in ("serve/queue_depth", "serve/slot_occupancy",
                 "serve/page_pool_util"):
        assert reg.histogram(name).count == eng.steps
    assert 0.0 <= reg.histogram("serve/page_pool_util").vmax <= 1.0
    # useful vs raw accounting: raw counts every decoded lane-token
    st = eng.stats()
    assert st["useful_tokens"] == useful
    assert st["raw_tokens"] >= st["useful_tokens"]
    assert st["dropped_tokens"] == st["raw_tokens"] - useful
    assert reg.gauge("serve/useful_tokens").value == useful
    # spans from every hot path made it into the trace
    names = {e["name"] for e in obs.tracer.events}
    assert {"serve.admit", "serve.decode_step", "serve.run"} <= names


@pytest.mark.tier1
def test_trainer_emits_obs(tmp_path):
    from repro.configs.paper_models import F1_MNIST
    from repro.core import LargeBatchConfig, Regime
    from repro.data.synthetic import teacher_classification
    from repro.models.cnn import model_fns
    from repro.train.trainer import train_vision
    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(16,), ghost_batch_size=16)
    data = teacher_classification(0, n_train=128, n_test=64,
                                  input_shape=(8, 8, 1), n_classes=10)
    lb = LargeBatchConfig(batch_size=32, base_batch_size=32,
                          ghost_batch_size=16)
    regime = Regime(base_lr=0.05, total_steps=4, drop_every=4)
    obs = Observability()
    train_vision(model_fns(cfg), cfg, data, lb, regime, obs=obs)
    reg = obs.registry
    assert reg.histogram("train/step_time_s").count == 4
    assert reg.counter("train/steps").value == 4
    assert reg.gauge("train/batch_size").value == 32
    assert reg.gauge("train/lr").value > 0
    assert reg.histogram("train/grad_norm").count == 4
    # logger series mirror into the registry under train/
    assert reg.histogram("train/distance").count >= 1
    spans = [e["name"] for e in obs.tracer.events]
    assert spans.count("train.step") == 4
