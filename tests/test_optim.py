"""Optimizer unit tests: momentum SGD (the paper's optimizer), clipping,
multiplicative noise wiring, int8 momentum, Adam baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clipping import clip_by_global_norm, global_norm
from repro.optim import adam, sgd

pytestmark = pytest.mark.tier0


def _quad_loss(params):
    return 0.5 * jnp.sum(params["w"] ** 2)


def test_sgd_momentum_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = sgd.init(params)
    for i in range(300):
        grads = jax.grad(_quad_loss)(params)
        params, state, _ = sgd.update(grads, state, params, lr=0.1,
                                      momentum=0.9)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sgd_matches_manual_recurrence():
    params = {"w": jnp.asarray([1.0])}
    state = sgd.init(params)
    g = {"w": jnp.asarray([2.0])}
    p, s, _ = sgd.update(g, state, params, lr=0.1, momentum=0.5)
    # m = 0.5*0 + 2 = 2 ; w = 1 - 0.1*2 = 0.8
    assert float(p["w"][0]) == pytest.approx(0.8)
    p, s, _ = sgd.update(g, s, p, lr=0.1, momentum=0.5)
    # m = 0.5*2 + 2 = 3 ; w = 0.8 - 0.3 = 0.5
    assert float(p["w"][0]) == pytest.approx(0.5)


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below the threshold: untouched
    clipped2, _ = clip_by_global_norm(grads, 10.0)
    np.testing.assert_allclose(clipped2["a"], grads["a"])


def test_sgd_grad_clip_and_noise_wiring():
    params = {"w": jnp.ones((4,))}
    state = sgd.init(params)
    g = {"w": 100.0 * jnp.ones((4,))}
    p, _, m = sgd.update(g, state, params, lr=0.1, momentum=0.0,
                         grad_clip=1.0, noise_sigma=0.0)
    assert "grad_norm" in m and float(m["grad_norm"]) == pytest.approx(200.0)
    # clipped to norm 1 -> step 0.1 * 0.5 per element
    np.testing.assert_allclose(p["w"], 1.0 - 0.05, rtol=1e-5)
    # noise requires rng
    with pytest.raises(AssertionError):
        sgd.update(g, state, params, lr=0.1, noise_sigma=0.5)


def test_int8_momentum_roundtrip():
    params = {"w": jnp.linspace(-1, 1, 1000)}
    state = sgd.init(params, momentum_dtype="int8")
    g = {"w": jnp.sin(jnp.arange(1000.0))}
    p8, s8, _ = sgd.update(g, state, params, lr=0.1, momentum=0.9,
                           momentum_dtype="int8")
    pf, sf, _ = sgd.update(g, sgd.init(params), params, lr=0.1, momentum=0.9)
    # int8 quantized momentum step close to fp32 step (blockwise scales)
    np.testing.assert_allclose(p8["w"], pf["w"], atol=2e-3)
    assert s8.momentum["w"]["q"].dtype == jnp.int8


def test_weight_decay():
    params = {"w": jnp.asarray([1.0])}
    state = sgd.init(params)
    g = {"w": jnp.asarray([0.0])}
    p, _, _ = sgd.update(g, state, params, lr=0.1, momentum=0.0,
                         weight_decay=0.1)
    assert float(p["w"][0]) == pytest.approx(1.0 - 0.01)


def test_adam_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adam.init(params)
    for i in range(200):
        grads = jax.grad(_quad_loss)(params)
        params, state, _ = adam.update(grads, state, params, lr=0.1)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
