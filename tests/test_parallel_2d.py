"""Unified 2-D parallelism layer (train/parallel.py): the LM/MoE train step
sharded data x model matches the single-device step, geometry gating, and
the experiments runner's topology ladder.

In-process tests use the degenerate 1x1 host mesh or a shape-only mesh stub
(tier0 quick gate); the real multi-device tests run in a subprocess with 4
simulated devices as a (2 data, 2 model) mesh (the conftest forbids forcing
the device count in-process)."""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import LargeBatchConfig, Regime
from repro.launch.mesh import dp_axes, dp_size, make_host_mesh
from repro.models import transformer as T
from repro.optim import sgd
from repro.train.parallel import mesh_compatible, mesh_param_specs
from repro.train.trainer import make_lm_train_step

pytestmark = pytest.mark.tier1

REPO = Path(__file__).resolve().parent.parent


def _mesh_stub(**axes):
    """Shape-only mesh: enough for spec/geometry functions (no devices)."""
    return SimpleNamespace(shape=dict(axes), axis_names=tuple(axes))


def _reduced(arch: str):
    return dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                               vocab_size=128)


# ---------------------------------------------------------------------------
# tier0: degenerate host mesh + geometry gating (no simulated devices)
# ---------------------------------------------------------------------------


@pytest.mark.tier0
def test_host_mesh_lm_step_matches_plain():
    """On the degenerate (1, 1) host mesh the unified step must reproduce
    the plain LM step exactly (size-1 psums, grad-clip norm included)."""
    cfg = _reduced("kimi-k2-1t-a32b")
    lb = LargeBatchConfig(batch_size=4, base_batch_size=4, grad_clip=1.0)
    regime = Regime(base_lr=0.02, total_steps=10, drop_every=5)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, cfg.vocab_size)}
    s1 = jax.jit(make_lm_train_step(cfg, lb, regime))
    s2 = jax.jit(make_lm_train_step(cfg, lb, regime, mesh=make_host_mesh(),
                                    params=params))
    p1, _, m1 = s1(params, opt, batch, jnp.int32(0), jax.random.PRNGKey(2))
    p2, _, m2 = s2(params, opt, batch, jnp.int32(0), jax.random.PRNGKey(2))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.tier0
def test_data_mesh_lm_step_matches_plain():
    """The unified LM step on a mesh WITHOUT a 'model' axis (the legacy 1-D
    ("data",) mesh _mesh_for's ladder can fall back to): everything
    replicates except the batch, and the pjit spec rules — which assume a
    'model' axis — must not be consulted."""
    from repro.launch.mesh import make_data_mesh
    cfg = _reduced("qwen3-1.7b")
    lb = LargeBatchConfig(batch_size=4, base_batch_size=4, grad_clip=1.0)
    regime = Regime(base_lr=0.02, total_steps=10, drop_every=5)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, cfg.vocab_size)}
    s1 = jax.jit(make_lm_train_step(cfg, lb, regime))
    s2 = jax.jit(make_lm_train_step(cfg, lb, regime, mesh=make_data_mesh(1),
                                    params=params))
    p1, _, m1 = s1(params, opt, batch, jnp.int32(0), jax.random.PRNGKey(2))
    p2, _, m2 = s2(params, opt, batch, jnp.int32(0), jax.random.PRNGKey(2))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


@pytest.mark.tier0
def test_run_id_topology_canonicalization():
    """use_mesh=True and use_mesh="data" are the same request and must hash
    to the same run_id (True is the legacy encoding recorded in existing
    sweep stores); "2d" is distinct."""
    from repro.experiments.registry import get_sweep
    base = get_sweep("lm-smoke", steps=2).expand()[0]
    s_true = dataclasses.replace(base, use_mesh=True)
    s_data = dataclasses.replace(base, use_mesh="data")
    s_2d = dataclasses.replace(base, use_mesh="2d")
    s_off = dataclasses.replace(base, use_mesh="")
    assert s_true.run_id == s_data.run_id
    assert s_true.to_json()["use_mesh"] is True
    assert s_2d.run_id != s_true.run_id
    assert s_off.run_id == base.run_id


@pytest.mark.tier0
def test_mesh_lm_step_requires_params():
    cfg = _reduced("qwen3-1.7b")
    lb = LargeBatchConfig(batch_size=4, base_batch_size=4)
    with pytest.raises(ValueError):
        make_lm_train_step(cfg, lb, Regime(base_lr=0.1, total_steps=1,
                                           drop_every=1),
                           mesh=make_host_mesh())


@pytest.mark.tier0
def test_mesh_compatible_2d_geometry():
    """batch % dp size, whole ghosts per dp shard, experts % model size."""
    mesh = _mesh_stub(data=2, model=2)
    lb = LargeBatchConfig(batch_size=64, base_batch_size=64,
                          ghost_batch_size=16)
    assert mesh_compatible(lb, mesh)                       # 32 per dp shard
    assert not mesh_compatible(lb, mesh, batch_size=6)     # 6 % 2 != 0
    # 36/2 = 18 rows per dp shard: not whole 16-row ghosts
    assert not mesh_compatible(lb, mesh, batch_size=36)
    nogbn = dataclasses.replace(lb, use_gbn=False)
    assert mesh_compatible(nogbn, mesh, batch_size=36)
    # MoE expert geometry over the model axis
    kimi = _reduced("kimi-k2-1t-a32b")                     # 4 experts
    assert mesh_compatible(nogbn, mesh, batch_size=8, cfg=kimi)
    odd = dataclasses.replace(
        kimi, moe=dataclasses.replace(kimi.moe, n_experts=3, d_expert=129))
    assert not mesh_compatible(nogbn, mesh, batch_size=8, cfg=odd)
    # ffn fallback: experts don't divide but each expert's hidden does
    ffn = dataclasses.replace(
        kimi, moe=dataclasses.replace(kimi.moe, n_experts=3, d_expert=128))
    assert mesh_compatible(nogbn, mesh, batch_size=8, cfg=ffn)
    # dense cfg: the model axis just replicates — always compatible
    assert mesh_compatible(nogbn, mesh, batch_size=8,
                           cfg=_reduced("qwen3-1.7b"))
    # pod axis folds into the dp ways
    pod = _mesh_stub(pod=2, data=2, model=2)
    assert dp_size(pod) == 4 and dp_axes(pod) == ("pod", "data")
    assert mesh_compatible(nogbn, pod, batch_size=8)
    assert not mesh_compatible(nogbn, pod, batch_size=6)


@pytest.mark.tier0
def test_mesh_param_specs_expert_only():
    """Expert tensors keep 'model' (expert axis when it divides, hidden dim
    otherwise); attention/dense/shared-expert weights are replicated even
    though the pjit rules Megatron-shard them."""
    mesh = _mesh_stub(data=2, model=2)
    specs = mesh_param_specs(T.init_params(jax.random.PRNGKey(0),
                                           _reduced("kimi-k2-1t-a32b")),
                             mesh)
    body_ff = specs["stack"]["body"][0]["ff"]
    assert tuple(body_ff["w_gate"]) == (None, "model", None, None)
    assert tuple(body_ff["w_down"]) == (None, "model", None, None)
    assert all(e is None for e in body_ff["router"])
    for leaf in jax.tree.leaves(specs["stack"]["body"][0]["mixer"]):
        assert all(e is None for e in leaf), leaf
    for leaf in jax.tree.leaves(body_ff["shared"]):
        assert all(e is None for e in leaf), leaf
    assert all(e is None for e in specs["embed"])


# ---------------------------------------------------------------------------
# multi-device subprocess: (2 data, 2 model)
# ---------------------------------------------------------------------------


def _run_multidev(script: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("JAX_PLATFORMS", None)
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          cwd=str(REPO), timeout=900)


LM_2D_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 4, jax.device_count()
    from repro.configs.base import LayerSpec, ModelConfig, MoEConfig
    from repro.configs.registry import get_config
    from repro.core import LargeBatchConfig, Regime
    from repro.launch.mesh import make_2d_mesh
    from repro.models import transformer as T
    from repro.optim import sgd
    from repro.train.trainer import make_lm_train_step

    mesh = make_2d_mesh()
    assert dict(mesh.shape) == {"data": 2, "model": 2}, mesh

    lb = LargeBatchConfig(batch_size=8, base_batch_size=8, grad_clip=1.0)
    regime = Regime(base_lr=0.02, total_steps=10, drop_every=5)

    def run(cfg, steps=3, use_kernels=False):
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        s1 = jax.jit(make_lm_train_step(cfg, lb, regime,
                                        use_kernels=use_kernels))
        s2 = jax.jit(make_lm_train_step(cfg, lb, regime, mesh=mesh,
                                        params=params,
                                        use_kernels=use_kernels))
        p1 = p2 = params
        o1 = o2 = sgd.init(params)
        for k in range(steps):
            toks = jax.random.randint(
                jax.random.fold_in(jax.random.PRNGKey(1), k), (8, 16),
                0, cfg.vocab_size)
            b = {"tokens": toks}
            p1, o1, m1 = s1(p1, o1, b, jnp.int32(k),
                            jax.random.PRNGKey(2 + k))
            p2, o2, m2 = s2(p2, o2, b, jnp.int32(k),
                            jax.random.PRNGKey(2 + k))
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(m1["grad_norm"]),
                                   float(m2["grad_norm"]), rtol=1e-4)
        for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=2e-5, atol=1e-6)
        return p2

    def reduced(arch):
        return dataclasses.replace(get_config(arch).reduced(),
                                   dtype="float32", vocab_size=128)

    # dense: model axis replicates, dp axes shard the batch
    run(reduced("qwen3-1.7b"), steps=2)

    # kimi (4 experts % 2 == 0): expert weights sharded over 'model'
    kimi = reduced("kimi-k2-1t-a32b")
    p2 = run(kimi)
    spec = p2["stack"]["body"][0]["ff"]["w_gate"].sharding.spec
    assert tuple(spec)[:2] == (None, "model"), spec

    # qwen2-moe through the Pallas kernels (flash attention fwd+bwd
    # inside the shard_map region), 1 step for time
    run(reduced("qwen2-moe-a2.7b"), steps=1, use_kernels=True)

    # 3 experts don't divide model=2 -> ffn sharding of d_expert
    ffn = ModelConfig(
        name="ffn3", family="moe", d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=128, vocab_size=128,
        body_pattern=(LayerSpec(mixer="attn", ff="moe"),), body_repeats=2,
        moe=MoEConfig(n_experts=3, top_k=2, d_expert=64,
                      capacity_factor=1.5),
        dtype="float32")
    p2 = run(ffn)
    spec = p2["stack"]["body"][0]["ff"]["w_gate"].sharding.spec
    assert tuple(spec) == (None, None, None, "model"), spec
    print("LM_2D_OK")
""")


def test_lm_2d_matches_single_device_subprocess():
    """(2 data, 2 model): sharded LM step == unsharded step after multiple
    steps — dense, expert-sharded MoE (kimi), ffn-sharded MoE, and the
    Pallas-kernel path; expert weights actually land sharded over 'model'
    and gradients pmean over dp only (equality would break otherwise)."""
    proc = _run_multidev(LM_2D_SCRIPT)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "LM_2D_OK" in proc.stdout


VISION_2D_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 4, jax.device_count()
    from repro.configs.paper_models import F1_MNIST
    from repro.core import LargeBatchConfig, Regime
    from repro.launch.mesh import make_2d_mesh
    from repro.models.cnn import model_fns
    from repro.optim import sgd
    from repro.train.data_parallel import make_dp_vision_train_step
    from repro.train.trainer import make_vision_train_step

    mesh = make_2d_mesh()
    # 2 dp shards x 2 model shards: 32 rows per dp shard, 4 ghosts of 8
    cfg = dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                              hidden_sizes=(32,), ghost_batch_size=8)
    lb = LargeBatchConfig(batch_size=64, base_batch_size=64,
                          ghost_batch_size=8)
    regime = Regime(base_lr=0.1, total_steps=10, drop_every=10)
    init_fn, apply_fn = model_fns(cfg)
    params, bn = init_fn(jax.random.PRNGKey(1), cfg)
    opt = sgd.init(params)
    xb = jax.random.normal(jax.random.PRNGKey(2), (64, 8, 8, 1))
    yb = jax.random.randint(jax.random.PRNGKey(3), (64,), 0, 10)
    s1 = jax.jit(make_vision_train_step(apply_fn, cfg, lb, regime))
    sd = jax.jit(make_dp_vision_train_step(apply_fn, cfg, lb, regime, mesh))
    p1, b1, _, m1 = s1(params, bn, opt, xb, yb, jnp.int32(0),
                       jax.random.PRNGKey(4))
    pd, bd, _, md = sd(params, bn, opt, xb, yb, jnp.int32(0),
                       jax.random.PRNGKey(4))
    np.testing.assert_allclose(float(m1["loss"]), float(md["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(b1), jax.tree.leaves(bd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    print("VISION_2D_OK")
""")


def test_vision_2d_matches_single_device_subprocess():
    """The generalized vision DP step on a (2, 2) mesh: batch shards over
    the 2 dp ways (the model axis replicates), ghost stats stay local, and
    the step matches the single-device trainer."""
    proc = _run_multidev(VISION_2D_SCRIPT)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "VISION_2D_OK" in proc.stdout


RUNNER_2D_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax
    assert jax.device_count() == 4, jax.device_count()
    from repro.experiments.registry import get_sweep
    from repro.experiments.runner import _mesh_for, run_one

    # lm-smoke over the 2-D mesh on an MoE arch: geometry fits (batch 8
    # over 2 dp ways, 4 experts over 2 model ways)
    sweep = get_sweep("lm-smoke", steps=4, arch="kimi-k2-1t-a32b",
                      use_mesh="2d")
    spec = sweep.expand()[0]
    mesh = _mesh_for(spec)
    assert mesh is not None and dict(mesh.shape) == {"data": 2, "model": 2}
    # kernels-off for the end-to-end run: interpret-mode Pallas backward
    # dominates the wall clock and the kernel path's 2-D equivalence is
    # covered by test_lm_2d_matches_single_device_subprocess
    rec = run_one(dataclasses.replace(spec, use_kernels=False))
    assert rec["final_ce"] > 0
    # geometry that fits no mesh (batch 6: 6 % 2 dp ways is fine, but a
    # batch of 7 splits neither 2-D nor 1-D) -> clean fallback to None
    bad = dataclasses.replace(
        spec, lb=dataclasses.replace(spec.lb, batch_size=7))
    assert _mesh_for(bad) is None
    # 2-D incompatible but 1-D compatible (odd experts, odd hidden):
    # ladder degrades to the ("data",) mesh
    from repro.configs.registry import get_config
    from repro.experiments.runner import _lm_config
    from repro.train.parallel import mesh_compatible
    from repro.launch.mesh import make_data_mesh
    cfg = _lm_config(spec)
    odd = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=3, d_expert=129))
    assert not mesh_compatible(spec.lb, _mesh_for(spec), cfg=odd)
    assert mesh_compatible(spec.lb, make_data_mesh(), cfg=odd)
    # use_mesh=True keeps meaning the 1-D data mesh
    legacy = dataclasses.replace(spec, use_mesh=True)
    m1d = _mesh_for(legacy)
    assert m1d is not None and tuple(m1d.axis_names) == ("data",)
    # a dense arch has nothing to shard over 'model': a "2d" request takes
    # the full-width data mesh instead of wasting half the devices on
    # replication
    dense = dataclasses.replace(spec, lm_arch="qwen3-1.7b")
    md = _mesh_for(dense)
    assert md is not None and tuple(md.axis_names) == ("data",), md
    assert md.shape["data"] == 4
    print("RUNNER_2D_OK")
""")


def test_runner_fans_lm_over_2d_mesh_subprocess():
    """experiments.runner: use_mesh="2d" fans an lm-smoke MoE run over the
    (2 data, 2 model) mesh when the geometry allows, degrades down the
    topology ladder when it doesn't, and use_mesh=True still selects the
    1-D data mesh."""
    proc = _run_multidev(RUNNER_2D_SCRIPT)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "RUNNER_2D_OK" in proc.stdout


# ---------------------------------------------------------------------------
# tier0: Megatron-TP + FSDP spec derivation and state-memory math
# ---------------------------------------------------------------------------


@pytest.mark.tier0
def test_mesh_param_specs_tp_and_fsdp():
    """tp=True head-splits attention projections and column/row-splits the
    dense MLP over 'model'; fsdp=True shards every remaining large tensor
    over the dp axes; embed/head stay model-replicated (vocab parallelism
    is not built)."""
    mesh = _mesh_stub(data=2, model=2)
    cfg = _reduced("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    specs = mesh_param_specs(params, mesh, cfg=cfg, tp=True, fsdp=True)
    mixer = specs["stack"]["body"][0]["mixer"]
    # column-parallel qkv: head dim over 'model', fsdp over 'data'
    assert tuple(mixer["wq"]) == (None, "data", "model")
    assert tuple(mixer["wk"]) == (None, "data", "model")
    # row-parallel o: input (head) dim over 'model'
    assert tuple(mixer["wo"]) == (None, "model", "data")
    ff = specs["stack"]["body"][0]["ff"]
    assert tuple(ff["w_gate"]) == (None, "data", "model")
    assert tuple(ff["w_down"]) == (None, "model", "data")
    # embed takes fsdp but never the model axis (no vocab parallelism)
    assert "model" not in tuple(specs["embed"])
    assert "data" in tuple(specs["embed"])
    # tp alone leaves the fsdp dims unsharded
    tp_only = mesh_param_specs(params, mesh, cfg=cfg, tp=True)
    assert tuple(tp_only["stack"]["body"][0]["mixer"]["wq"]) == \
        (None, None, "model")


@pytest.mark.tier0
def test_mesh_param_specs_tp_requires_cfg_and_divisibility():
    mesh = _mesh_stub(data=2, model=2)
    cfg = _reduced("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="cfg"):
        mesh_param_specs(params, mesh, tp=True)
    # heads not divisible by model size -> attention stays replicated
    odd = dataclasses.replace(cfg, n_heads=3, n_kv_heads=3)
    p3 = T.init_params(jax.random.PRNGKey(0), odd)
    specs = mesh_param_specs(p3, mesh, cfg=odd, tp=True)
    mixer = specs["stack"]["body"][0]["mixer"]
    for name in ("wq", "wk", "wv", "wo"):
        assert "model" not in tuple(mixer[name]), (name, mixer[name])


@pytest.mark.tier0
def test_mesh_param_specs_fsdp_without_model_axis():
    """FSDP works on a pure data mesh (no 'model' axis at all)."""
    mesh = _mesh_stub(data=4)
    cfg = _reduced("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    specs = mesh_param_specs(params, mesh, cfg=cfg, fsdp=True)
    w_gate = specs["stack"]["body"][0]["ff"]["w_gate"]
    assert tuple(w_gate) == (None, "data", None), w_gate
    assert "model" not in {e for leaf in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        for e in tuple(leaf) if e is not None}


@pytest.mark.tier0
def test_fsdp_optimizer_state_bytes_shrink_by_dp_size():
    """The acceptance check for FSDP memory: per-device Adam moment bytes
    drop ~dp_size for an LM config (ratio == dp up to replicated scalars)."""
    from jax.sharding import PartitionSpec as P

    from repro.optim import adam
    from repro.train.parallel import state_bytes_per_device

    cfg = _reduced("qwen3-1.7b")
    mesh = _mesh_stub(data=2, model=2)
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    ost = jax.eval_shape(adam.init, shapes)
    pspecs = mesh_param_specs(shapes, mesh, cfg=cfg, fsdp=True)
    ospecs = adam.AdamState(mu=pspecs, nu=pspecs, step=P())
    full = state_bytes_per_device(ost, jax.tree.map(lambda _: P(), ost),
                                  mesh)
    sharded = state_bytes_per_device(ost, ospecs, mesh)
    ratio = full / sharded
    assert 1.9 < ratio <= 2.0, ratio


# ---------------------------------------------------------------------------
# multi-device subprocess: Megatron-TP and FSDP vs the unsharded step
# ---------------------------------------------------------------------------


TP_FSDP_2D_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 4, jax.device_count()
    from jax.sharding import PartitionSpec as P
    from repro.configs.registry import get_config
    from repro.core import LargeBatchConfig, Regime
    from repro.launch.mesh import make_2d_mesh
    from repro.models import transformer as T
    from repro.optim import adam, sgd
    from repro.train import parallel as PAR
    from repro.train.trainer import make_lm_train_step

    mesh = make_2d_mesh()
    assert dict(mesh.shape) == {"data": 2, "model": 2}, mesh
    lb = LargeBatchConfig(batch_size=8, base_batch_size=8, grad_clip=1.0)
    regime = Regime(base_lr=0.02, total_steps=10, drop_every=5)

    def reduced(arch):
        return dataclasses.replace(get_config(arch).reduced(),
                                   dtype="float32", vocab_size=128)

    def run(cfg, steps=3, use_kernels=False, tp=False, fsdp=False,
            optimizer="sgd"):
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        s1 = jax.jit(make_lm_train_step(cfg, lb, regime,
                                        use_kernels=use_kernels,
                                        optimizer=optimizer))
        s2 = jax.jit(make_lm_train_step(cfg, lb, regime, mesh=mesh,
                                        params=params, tp=tp, fsdp=fsdp,
                                        use_kernels=use_kernels,
                                        optimizer=optimizer))
        p1 = p2 = params
        o1 = o2 = (adam.init(params) if optimizer == "adam"
                   else sgd.init(params))
        for k in range(steps):
            toks = jax.random.randint(
                jax.random.fold_in(jax.random.PRNGKey(1), k), (8, 16),
                0, cfg.vocab_size)
            b = {"tokens": toks}
            p1, o1, m1 = s1(p1, o1, b, jnp.int32(k),
                            jax.random.PRNGKey(2 + k))
            p2, o2, m2 = s2(p2, o2, b, jnp.int32(k),
                            jax.random.PRNGKey(2 + k))
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(m1["grad_norm"]),
                                   float(m2["grad_norm"]), rtol=1e-4)
        for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=2e-5, atol=1e-6)
        return p2, o1, o2

    qwen = reduced("qwen3-1.7b")

    # Megatron TP alone: attention heads + dense MLP split over 'model'
    p2, _, _ = run(qwen, tp=True)
    spec = p2["stack"]["body"][0]["mixer"]["wq"].sharding.spec
    assert tuple(spec) == (None, None, "model"), spec
    print("TP_OK")

    # FSDP alone: params + optimizer state sharded over dp
    p2, _, o2 = run(qwen, fsdp=True)
    spec = p2["stack"]["body"][0]["ff"]["w_gate"].sharding.spec
    assert "data" in tuple(spec), spec
    mspec = o2.momentum["stack"]["body"][0]["ff"]["w_gate"].sharding.spec
    assert "data" in tuple(mspec), mspec
    print("FSDP_OK")

    # the full stack: MoE expert sharding + TP attention + FSDP, 3 steps
    run(reduced("kimi-k2-1t-a32b"), tp=True, fsdp=True)
    print("TP_FSDP_MOE_OK")

    # Pallas kernel path under TP+FSDP (1 step for time)
    run(qwen, steps=1, use_kernels=True, tp=True, fsdp=True)
    print("TP_FSDP_KERNELS_OK")

    # adam: shard-local update from dp-scattered grads. Multi-step params
    # are NOT compared — mu_hat/(sqrt(nu_hat)+eps) amplifies fp32
    # reassociation noise into O(lr) drift — but the first moment after one
    # step is linear in the gradients and must match exactly.
    params = T.init_params(jax.random.PRNGKey(0), qwen)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                              qwen.vocab_size)
    b = {"tokens": toks}
    s1 = jax.jit(make_lm_train_step(qwen, lb, regime, optimizer="adam"))
    s2 = jax.jit(make_lm_train_step(qwen, lb, regime, mesh=mesh,
                                    params=params, optimizer="adam",
                                    tp=True, fsdp=True))
    o = adam.init(params)
    _, o1, _ = s1(params, o, b, jnp.int32(0), jax.random.PRNGKey(2))
    _, o2, _ = s2(params, o, b, jnp.int32(0), jax.random.PRNGKey(2))
    for a, c in zip(jax.tree.leaves(o1.mu), jax.tree.leaves(o2.mu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-5, atol=1e-6)
    # per-device moment memory shrinks ~dp_size under FSDP
    pspecs = PAR.mesh_param_specs(params, mesh, cfg=qwen, fsdp=True)
    ospecs = adam.AdamState(mu=pspecs, nu=pspecs, step=P())
    full = sum(l.nbytes for l in jax.tree.leaves(adam.init(params)))
    per_dev = PAR.state_bytes_per_device(adam.init(params), ospecs, mesh)
    ratio = full / per_dev
    assert 1.9 < ratio <= 2.0, ratio
    print("ADAM_FSDP_OK")
    print("TP_FSDP_2D_OK")
""")


def test_tp_fsdp_2d_matches_single_device_subprocess():
    """(2 data, 2 model): the Megatron-TP step, the FSDP step, and the
    combined TP+FSDP step (dense, MoE, and Pallas-kernel paths) produce
    multi-step params exactly equal to the unsharded step; adam first
    moments match after one step and its per-device state bytes shrink by
    the dp size."""
    proc = _run_multidev(TP_FSDP_2D_SCRIPT)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for tag in ("TP_OK", "FSDP_OK", "TP_FSDP_MOE_OK", "ADAM_FSDP_OK",
                "TP_FSDP_2D_OK"):
        assert tag in proc.stdout, proc.stdout
