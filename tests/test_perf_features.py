"""Beyond-paper optimization features: exactness guarantees.

- vocab-chunked streaming CE == dense CE (values and gradients)
- grouped (no-repeat) decode attention == repeated-head attention
- int8 momentum last-axis layout roundtrips multi-dim leaves
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.models.layers import _sdpa, _sdpa_grouped
from repro.optim.sgd import _dequantize_int8, _quantize_int8


def test_chunked_ce_matches_dense():
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                          cfg.vocab_size)}
    l0, _ = T.lm_loss(params, cfg, batch)
    l1, _ = T.lm_loss(params, cfg, batch, ce_chunk=128)
    assert abs(float(l0 - l1)) < 2e-5
    g0 = jax.grad(lambda p: T.lm_loss(p, cfg, batch)[0])(params)
    g1 = jax.grad(lambda p: T.lm_loss(p, cfg, batch, ce_chunk=128)[0])(params)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)))
    assert err < 1e-5


def test_chunked_ce_respects_vocab_padding():
    """Padded vocab rows must not receive probability mass."""
    cfg = dataclasses.replace(get_config("seamless-m4t-large-v2").reduced(),
                              dtype="float32", vocab_size=500)
    assert cfg.padded_vocab != cfg.vocab_size
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                          cfg.vocab_size),
             "frames": 0.1 * jax.random.normal(
                 jax.random.PRNGKey(2), (2, 3, cfg.encoder.d_model))}
    l0, _ = T.lm_loss(params, cfg, batch)
    l1, _ = T.lm_loss(params, cfg, batch, ce_chunk=128)
    assert abs(float(l0 - l1)) < 2e-5


def test_grouped_decode_attention_matches_repeated():
    rng = jax.random.PRNGKey(0)
    B, T_, h, kv, hd, S = 2, 1, 8, 2, 32, 40
    q = jax.random.normal(rng, (B, T_, h, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, kv, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, kv, hd))
    mask = (jnp.arange(S) <= 25)[None, None, :]
    out_g = _sdpa_grouped(q, k, v, mask)
    out_r = _sdpa(q, k, v, mask)
    np.testing.assert_allclose(out_g, out_r, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(1000,), (7, 300), (3, 5, 512), (2, 256)])
def test_int8_momentum_multidim_roundtrip(shape):
    x = jnp.sin(jnp.arange(np.prod(shape), dtype=jnp.float32)).reshape(shape)
    q = _quantize_int8(x)
    back = _dequantize_int8(q, shape, jnp.float32)
    assert back.shape == x.shape
    # blockwise absmax quantization: error bounded by scale/2 per block
    np.testing.assert_allclose(back, x, atol=float(jnp.abs(x).max()) / 100)
    assert q["q"].shape[:-2] == x.shape[:-1]


def test_mamba_kernel_grads_match_reference():
    from repro.kernels import ops, ref
    B, c, di, ds = 1, 8, 128, 8
    rng = jax.random.PRNGKey(0)
    xc = jax.random.normal(rng, (B, c, di))
    dt = 0.1 * jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(rng, 1), (B, c, di)))
    Bm = jax.random.normal(jax.random.fold_in(rng, 2), (B, c, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 3), (B, c, ds))
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(rng, 4), (di, ds)))
    h0 = jnp.zeros((B, di, ds))

    def f(op):
        return lambda *a: op(*a)[0].sum()

    g_k = jax.grad(f(ops.mamba_chunk), argnums=(0, 1, 4))(xc, dt, Bm, Cm, A,
                                                          h0)
    g_r = jax.grad(f(ref.mamba_chunk_ref), argnums=(0, 1, 4))(xc, dt, Bm, Cm,
                                                              A, h0)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
