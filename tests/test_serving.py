"""Serving fast path: flash-decode kernel vs oracle, fused prefill vs the
token-at-a-time fallback, sampling semantics, ragged left-padded batches,
and the padded-vocab / max_len regression fixes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels import ref
from repro.kernels.flash_decode import (flash_decode_blockwise,
                                        flash_decode_pallas)
from repro.models import transformer as T
from repro.serving import (generate, prefill, prefill_fused, sample_tokens,
                           mask_padded_vocab)


def _cfg(arch, **overrides):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    if cfg.moe is not None:
        # dropless so fused prefill and token-at-a-time decode route
        # identically (see moe.py notes)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return dataclasses.replace(cfg, **overrides)


# ---------------------------------------------------------------------------
# flash-decode kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.tier1
@pytest.mark.parametrize("B,H,KV,S,hd,window,ring,offs", [
    (2, 4, 4, 257, 64, None, False, None),       # MHA, ragged S
    (2, 4, 2, 100, 64, None, False, None),       # GQA
    (2, 8, 2, 333, 64, 48, False, None),         # window mask on a full cache
    (2, 4, 2, 16, 64, 16, True, None),           # SWA ring buffer
    (3, 4, 1, 64, 32, None, False, (0, 5, 63)),  # left-padded ragged prompts
    (2, 4, 2, 16, 64, 16, True, (0, 3)),         # ring + ragged
])
def test_flash_decode_vs_oracle(B, H, KV, S, hd, window, ring, offs):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    off = None if offs is None else jnp.array(offs, jnp.int32)
    lo = 0 if offs is None else max(offs)
    # ragged pos sweep: early, mid, last slot, and past the ring wrap
    for pos in {max(lo, 0), max(lo, S // 2), S - 1, (S + 7) if ring else S - 1}:
        o_ref = ref.flash_decode_ref(q, k, v, jnp.int32(pos), window=window,
                                     ring=ring, offsets=off)
        o_ker = flash_decode_pallas(q, k, v, jnp.int32(pos), window=window,
                                    ring=ring, offsets=off, interpret=True)
        np.testing.assert_allclose(o_ker, o_ref, atol=3e-6, rtol=1e-5)
        # the off-TPU serving lowering runs the same blockwise program
        o_blk = flash_decode_blockwise(q, k, v, jnp.int32(pos),
                                       window=window, ring=ring,
                                       offsets=off, block_k=64)
        np.testing.assert_allclose(o_blk, o_ref, atol=3e-6, rtol=1e-5)


@pytest.mark.tier1
def test_flash_decode_bf16_cache():
    """f32 queries against a bf16 cache (the production decode dtype mix)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 4, 64))
    k = jax.random.normal(ks[1], (2, 2, 200, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 2, 200, 64)).astype(jnp.bfloat16)
    o_ref = ref.flash_decode_ref(q, k, v, jnp.int32(150))
    o_ker = flash_decode_pallas(q, k, v, jnp.int32(150), interpret=True)
    np.testing.assert_allclose(o_ker, o_ref, atol=2e-6, rtol=1e-5)


def test_flash_decode_traced_pos_jit():
    """pos/offsets are dynamic (SMEM) scalars: one compile serves every
    decode position — the property the serving scan depends on."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 4, 64))
    k = jax.random.normal(ks[1], (2, 2, 96, 64))
    v = jax.random.normal(ks[2], (2, 2, 96, 64))
    f = jax.jit(lambda p: flash_decode_pallas(q, k, v, p, interpret=True))
    for pos in (0, 17, 95):
        np.testing.assert_allclose(
            f(jnp.int32(pos)),
            ref.flash_decode_ref(q, k, v, jnp.int32(pos)),
            atol=3e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused prefill vs token-at-a-time prefill
# ---------------------------------------------------------------------------


def _prefill_pair(cfg, P, total, dtype, use_kernels=False):
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, P), 0,
                                 cfg.vocab_size)
    layout = "head" if use_kernels else "seq"
    mk = lambda: T.init_cache(cfg, 2, total, dtype=dtype, layout=layout)
    l_step, c_step = prefill(params, cfg, prompts, mk(),
                             use_kernels=use_kernels)
    l_fused, c_fused = prefill_fused(params, cfg, prompts, mk(),
                                     use_kernels=use_kernels)
    return (l_step, c_step), (l_fused, c_fused)


@pytest.mark.tier1
@pytest.mark.parametrize("arch", ["qwen3-1.7b", "h2o-danube-3-4b",
                                  "jamba-v0.1-52b"])
def test_fused_prefill_matches_stepwise_f32(arch):
    """Cache AND last-position logits equality, f32. h2o-danube's prompt
    (20) exceeds its reduced ring (16), so the ring-wrap scatter is on the
    tested path; jamba covers ssm + moe + attn blocks in one stack."""
    cfg = _cfg(arch)
    (l_s, c_s), (l_f, c_f) = _prefill_pair(cfg, P=20, total=24,
                                           dtype=jnp.float32)
    np.testing.assert_allclose(l_f, l_s, atol=5e-5, rtol=1e-4)
    for (path_s, leaf_s), (path_f, leaf_f) in zip(
            jax.tree_util.tree_leaves_with_path(c_s),
            jax.tree_util.tree_leaves_with_path(c_f)):
        assert path_s == path_f
        np.testing.assert_allclose(
            np.asarray(leaf_f, np.float32), np.asarray(leaf_s, np.float32),
            atol=5e-5, rtol=1e-4, err_msg=str(path_s))


def test_fused_prefill_matches_stepwise_bf16():
    cfg = dataclasses.replace(_cfg("qwen3-1.7b"), dtype="bfloat16")
    (l_s, c_s), (l_f, c_f) = _prefill_pair(cfg, P=16, total=20,
                                           dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(l_f, np.float32),
                               np.asarray(l_s, np.float32),
                               atol=0.15, rtol=0.05)
    for leaf_s, leaf_f in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_f)):
        np.testing.assert_allclose(np.asarray(leaf_f, np.float32),
                                   np.asarray(leaf_s, np.float32),
                                   atol=0.15, rtol=0.05)


@pytest.mark.tier1
def test_fused_prefill_kernels_matches_stepwise():
    """use_kernels=True prefill (fused flash forward) against the stepwise
    flash-decode loop, on a head-major cache."""
    cfg = _cfg("qwen3-1.7b")
    (l_s, c_s), (l_f, c_f) = _prefill_pair(cfg, P=12, total=16,
                                           dtype=jnp.float32,
                                           use_kernels=True)
    np.testing.assert_allclose(l_f, l_s, atol=5e-5, rtol=1e-4)
    for leaf_s, leaf_f in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_f)):
        np.testing.assert_allclose(np.asarray(leaf_f), np.asarray(leaf_s),
                                   atol=5e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# generate: kernels, sampling, ragged batches, regressions
# ---------------------------------------------------------------------------


@pytest.mark.tier1
@pytest.mark.parametrize("arch", ["qwen3-1.7b", "h2o-danube-3-4b"])
def test_generate_kernels_equals_nonkernel(arch):
    """Acceptance: flash-decode + fused flash prefill produce IDENTICAL
    greedy f32 token ids (dense GQA + GQA sliding-window archs)."""
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0,
                                 cfg.vocab_size)
    o_plain = generate(params, cfg, prompts, max_new_tokens=12,
                       use_kernels=False)
    o_kern = generate(params, cfg, prompts, max_new_tokens=12,
                      use_kernels=True)
    np.testing.assert_array_equal(o_plain, o_kern)


def test_greedy_equals_temperature_zero():
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    o_greedy = generate(params, cfg, prompts, max_new_tokens=8)
    o_t0 = generate(params, cfg, prompts, max_new_tokens=8, temperature=0.0,
                    rng=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(o_greedy, o_t0)


def test_temperature_sampling_valid_and_seeded():
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    kw = dict(max_new_tokens=8, temperature=0.9, top_k=16)
    o1 = generate(params, cfg, prompts, rng=jax.random.PRNGKey(3), **kw)
    o2 = generate(params, cfg, prompts, rng=jax.random.PRNGKey(3), **kw)
    o3 = generate(params, cfg, prompts, rng=jax.random.PRNGKey(4), **kw)
    np.testing.assert_array_equal(o1, o2)        # same seed -> same tokens
    assert (o1 != o3).any()                      # different seed differs
    assert (o1 < cfg.vocab_size).all()
    with pytest.raises(ValueError, match="rng"):
        generate(params, cfg, prompts, max_new_tokens=4, temperature=0.5)


@pytest.mark.parametrize("arch,use_kernels", [
    ("qwen3-1.7b", False), ("qwen3-1.7b", True),       # dense GQA
    ("h2o-danube-3-4b", False), ("h2o-danube-3-4b", True),  # SWA ring
    ("falcon-mamba-7b", False),                         # SSM state masking
])
def test_ragged_matches_unpadded(arch, use_kernels):
    """A left-padded ragged batch must generate, row for row, exactly what
    each sequence generates alone unpadded (validity mask + per-row RoPE
    offsets through prefill and decode; SSM rows see identity updates
    through the padding; h2o-danube's P=20 > ring 16 crosses the wrap)."""
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    P, lens = 20, (4, 20, 13)
    full = jax.random.randint(jax.random.PRNGKey(1), (3, P), 0,
                              cfg.vocab_size)
    lens_a = jnp.array(lens, jnp.int32)
    padded = jnp.where(jnp.arange(P)[None] >= P - lens_a[:, None], full, 0)
    rag = generate(params, cfg, padded, max_new_tokens=6,
                   prompt_lens=lens_a, use_kernels=use_kernels)
    for b, L in enumerate(lens):
        solo = generate(params, cfg, padded[b:b + 1, P - L:],
                        max_new_tokens=6, use_kernels=use_kernels)
        np.testing.assert_array_equal(rag[b, P:], solo[0, L:])


def test_generate_rejects_bad_prompt_lens():
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jnp.zeros((2, 8), jnp.int32)
    for lens in ((0, 8), (3, 9)):
        with pytest.raises(ValueError, match="prompt_lens"):
            generate(params, cfg, prompts, max_new_tokens=4,
                     prompt_lens=jnp.array(lens, jnp.int32))


def test_prefill_masks_padded_vocab():
    """Regression: prefill used to argmax RAW logits — with
    padded_vocab != vocab_size the first generated token could be an
    out-of-vocab id. Both prefill paths share mask_padded_vocab now."""
    cfg = _cfg("qwen3-1.7b", vocab_size=500)
    assert cfg.padded_vocab == 512
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    # boost the padded rows so the unmasked argmax WOULD pick them
    params["embed"] = params["embed"].at[cfg.vocab_size:].set(5.0)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 cfg.vocab_size)
    for fused in (True, False):
        out = generate(params, cfg, prompts, max_new_tokens=5,
                       fused_prefill=fused)
        assert (out < cfg.vocab_size).all(), f"fused={fused}"


@pytest.mark.tier1
def test_generate_max_new_tokens_zero_and_one():
    """Regression: ``max_new_tokens=0`` used to run the prefill anyway and
    concatenate a phantom first token; it must return the prompts
    unchanged. ``max_new_tokens=1`` must be exactly prefill + greedy
    argmax of the last-position logits."""
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 cfg.vocab_size)
    out0 = generate(params, cfg, prompts, max_new_tokens=0)
    assert out0.shape == prompts.shape
    np.testing.assert_array_equal(out0, prompts)
    out1 = generate(params, cfg, prompts, max_new_tokens=1)
    assert out1.shape == (2, 7)
    np.testing.assert_array_equal(out1[:, :6], prompts)
    cache = T.init_cache(cfg, 2, 8, dtype=jnp.float32)
    last, _ = prefill_fused(params, cfg, prompts, cache)
    expect = sample_tokens(cfg, last, temperature=0.0, top_k=0, rng=None)
    np.testing.assert_array_equal(out1[:, 6],
                                  np.asarray(expect).reshape(-1))


@pytest.mark.tier1
def test_sample_tokens_top_k_at_least_vocab():
    """Regression: ``top_k >= vocab_size`` used to index the sorted logits
    at position V - top_k < 0, wrapping around and truncating to an
    arbitrary cutoff. Clamped, it must equal untruncated sampling and stay
    in-vocab."""
    cfg = _cfg("qwen3-1.7b", vocab_size=500)
    logits = jax.random.normal(jax.random.PRNGKey(0),
                               (4, 1, cfg.padded_vocab))
    rng = jax.random.PRNGKey(1)
    for k in (cfg.vocab_size, cfg.vocab_size + 37, 10_000):
        got = sample_tokens(cfg, logits, temperature=0.7, top_k=k, rng=rng)
        want = sample_tokens(cfg, logits, temperature=0.7, top_k=0, rng=rng)
        np.testing.assert_array_equal(got, want)
        assert (got < cfg.vocab_size).all()


def test_generate_max_len_zero_raises():
    """Regression: ``max_len=0`` used to silently fall back to the default
    depth (`max_len or ...`); an explicit zero-depth cache must raise."""
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                 cfg.vocab_size)
    with pytest.raises(ValueError, match="cache depth"):
        generate(params, cfg, prompts, max_new_tokens=4, max_len=0)
