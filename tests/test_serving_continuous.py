"""Continuous-batching serving: paged flash-decode kernels vs oracle and
vs the contiguous cache, per-row decode positions, and the
ContinuousEngine's core guarantee — every request's tokens are bit-exact
vs running that request alone greedily, through EOS retirement, slot
reuse, and mid-flight admission."""
import dataclasses
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels import ref
from repro.kernels.flash_decode import (flash_decode_blockwise,
                                        flash_decode_paged_blockwise,
                                        flash_decode_paged_pallas,
                                        flash_decode_pallas)
from repro.models import transformer as T
from repro.serving import ContinuousEngine, Request, generate


def _cfg(arch, **overrides):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return dataclasses.replace(cfg, **overrides)


def _paged_from_contiguous(k, v, ps, seed=0):
    """Scatter a contiguous (B, KV, S, hd) cache into a page pool with a
    shuffled block table (page 0 reserved as the trash page)."""
    B, KV, S, hd = k.shape
    NB = S // ps
    perm = np.random.RandomState(seed).permutation(
        np.arange(1, 1 + B * NB)).astype(np.int32)
    pt = jnp.asarray(perm.reshape(B, NB))
    def pool(x):
        blocks = x.reshape(B, KV, NB, ps, hd).transpose(0, 2, 1, 3, 4)
        p = jnp.zeros((1 + B * NB, KV, ps, hd), x.dtype)
        return p.at[pt.reshape(-1)].set(blocks.reshape(B * NB, KV, ps, hd))
    return pool(k), pool(v), pt


# ---------------------------------------------------------------------------
# paged flash-decode kernels vs oracle / vs contiguous
# ---------------------------------------------------------------------------


@pytest.mark.tier1
@pytest.mark.parametrize("B,H,KV,NB,ps,hd,window,offs", [
    (2, 4, 4, 4, 16, 64, None, None),        # MHA causal
    (2, 4, 2, 4, 16, 64, None, None),        # GQA
    (2, 8, 2, 4, 16, 64, 24, None),          # window mask over pages
    (3, 4, 1, 2, 32, 32, None, (0, 5, 40)),  # ragged left padding
])
def test_flash_decode_paged_vs_contiguous(B, H, KV, NB, ps, hd, window,
                                          offs):
    """Paged kernel (shuffled block table) == contiguous oracle at per-row
    positions, for pallas-interpret, blockwise, and the paged ref."""
    S = NB * ps
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    off = None if offs is None else jnp.array(offs, jnp.int32)
    lo = 0 if offs is None else max(offs)
    # per-row positions at different depths (incl. one mid-page)
    pos = jnp.asarray([max(lo, S - 1 - 7 * i) for i in range(B)], jnp.int32)
    kp, vp, pt = _paged_from_contiguous(k, v, ps)
    o_ref = ref.flash_decode_ref(q, k, v, pos, window=window, offsets=off)
    for name, o in [
        ("paged_ref", ref.flash_decode_paged_ref(
            q, kp, vp, pt, pos, window=window, offsets=off)),
        ("pallas", flash_decode_paged_pallas(
            q, kp, vp, pt, pos, window=window, offsets=off,
            interpret=True)),
        ("blockwise", flash_decode_paged_blockwise(
            q, kp, vp, pt, pos, window=window, offsets=off)),
    ]:
        np.testing.assert_allclose(o, o_ref, atol=3e-6, rtol=1e-5,
                                   err_msg=name)


@pytest.mark.tier1
def test_flash_decode_paged_trash_page_is_noop():
    """Table entries for blocks beyond pos may point at the trash page 0:
    their slots are fully masked, which must be an exact no-op under the
    online softmax. An all-trash row still yields finite output."""
    B, H, KV, NB, ps, hd = 2, 4, 2, 4, 16, 64
    S = NB * ps
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    pos = jnp.asarray([ps + 3, 2 * ps - 1], jnp.int32)   # rows use 2 blocks
    kp, vp, pt = _paged_from_contiguous(k, v, ps)
    full = flash_decode_paged_pallas(q, kp, vp, pt, pos, interpret=True)
    trashed = pt.at[:, 2:].set(0)                        # unbacked tail
    for fn in (lambda *a: flash_decode_paged_pallas(*a, interpret=True),
               flash_decode_paged_blockwise):
        got = fn(q, kp, vp, trashed, pos)
        np.testing.assert_allclose(got, full, atol=3e-6, rtol=1e-5)
        dead = fn(q, kp, vp, jnp.zeros_like(pt), pos)    # retired rows
        assert np.isfinite(np.asarray(dead)).all()


@pytest.mark.tier1
@pytest.mark.parametrize("ring", [False, True])
def test_flash_decode_per_row_pos_matches_scalar(ring):
    """A (B,) pos vector == B independent scalar-pos calls, for the
    contiguous pallas kernel and its blockwise serving lowering."""
    B, H, KV, S, hd = 3, 4, 2, 64, 32
    window = S if ring else None
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    pos = jnp.asarray([5, S // 2, S + 9 if ring else S - 1], jnp.int32)
    for fn in (lambda *a, **kw: flash_decode_pallas(*a, interpret=True,
                                                    **kw),
               flash_decode_blockwise):
        vec = fn(q, k, v, pos, window=window, ring=ring)
        for b in range(B):
            one = fn(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                     jnp.int32(int(pos[b])), window=window, ring=ring)
            np.testing.assert_allclose(vec[b:b + 1], one, atol=3e-6,
                                       rtol=1e-5)


@pytest.mark.tier1
@pytest.mark.parametrize("use_kernels", [False, True])
def test_decode_step_vector_pos_matches_scalar(use_kernels):
    """Model-level: decode_step with pos as a (B,) vector (all rows equal)
    is bit-identical to the scalar-pos training/generate path."""
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    layout = "head" if use_kernels else "seq"
    B, S, p = 2, 16, 7
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                             cfg.vocab_size)
    mk = lambda: T.init_cache(cfg, B, S, dtype=jnp.float32, layout=layout)
    l_s, c_s = T.decode_step(params, cfg, tok, mk(), jnp.int32(p),
                             use_kernels=use_kernels)
    l_v, c_v = T.decode_step(params, cfg, tok, mk(),
                             jnp.full((B,), p, jnp.int32),
                             use_kernels=use_kernels)
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_v))
    for (ps_, a), (pv, b) in zip(jax.tree_util.tree_leaves_with_path(c_s),
                                 jax.tree_util.tree_leaves_with_path(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(ps_))


# ---------------------------------------------------------------------------
# ContinuousEngine vs solo generate
# ---------------------------------------------------------------------------


def _trace(cfg, n, seed=0):
    """Staggered arrivals, 2 prompt lengths, one budget — bounds the
    distinct compile shapes while still forcing mid-flight admission."""
    r = np.random.RandomState(seed)
    out = []
    for i in range(n):
        L = int(r.choice([4, 8]))
        prompt = r.randint(0, cfg.vocab_size, size=(L,)).astype("int32")
        out.append(Request(id=i, prompt=prompt, max_new_tokens=6,
                           arrival=0.9 * i))
    return out


def _solo(params, cfg, req, max_len, uk):
    prompt = jnp.asarray(req.prompt, jnp.int32)
    out = generate(params, cfg, prompt[None],
                   max_new_tokens=req.max_new_tokens, max_len=max_len,
                   use_kernels=uk)
    return np.asarray(out[0, prompt.shape[0]:])


@pytest.mark.tier1
@pytest.mark.parametrize("arch,use_kernels", [
    ("qwen3-1.7b", False),        # GQA full attention, einsum decode
    ("qwen3-1.7b", True),         # paged flash-decode kernel path
    ("h2o-danube-3-4b", False),   # all-SWA: ring fallback under "paged"
    ("falcon-mamba-7b", False),   # SSM state rows ride the slot scatter
])
def test_continuous_engine_matches_solo(arch, use_kernels):
    """Every completion == running that request alone greedily: per-row
    pos, paged gather, admission scatter, and retirement must all be
    invisible to the numerics. 5 requests through 2 slots forces slot
    reuse and mid-flight admission."""
    cfg = _cfg(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg, 5)
    eng = ContinuousEngine(params, cfg, num_slots=2, max_len=16,
                           layout="paged", page_size=8,
                           use_kernels=use_kernels)
    comps = eng.run(reqs)
    assert sorted(comps) == [r.id for r in reqs]
    for r in reqs:
        want = _solo(params, cfg, r, 16, use_kernels)
        np.testing.assert_array_equal(
            np.asarray(comps[r.id].tokens), want,
            err_msg=f"request {r.id} (L={len(r.prompt)})")


@pytest.mark.tier1
def test_eos_retirement_and_slot_reuse():
    """A row that emits eos_id retires early (tokens end at the first
    EOS), its slot is re-admitted mid-flight, and the newcomer in the
    recycled slot is still bit-exact vs a fresh solo run."""
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg, 4, seed=3)
    solo = {r.id: _solo(params, cfg, r, 16, False) for r in reqs}
    eos = int(solo[0][2])             # force req 0 to EOS mid-stream
    eng = ContinuousEngine(params, cfg, num_slots=2, max_len=16,
                           layout="paged", page_size=8, eos_id=eos)
    comps = eng.run(reqs)
    retired_early = False
    for r in reqs:
        want = list(solo[r.id])
        if eos in want:               # truncate at first EOS, inclusive
            want = want[:want.index(eos) + 1]
            retired_early = retired_early or len(want) < r.max_new_tokens
        np.testing.assert_array_equal(np.asarray(comps[r.id].tokens),
                                      np.asarray(want),
                                      err_msg=f"request {r.id}")
    assert retired_early              # the EOS path actually fired
    assert not eng.active.any() and not eng.free_pages == []


@pytest.mark.tier1
def test_paged_engine_matches_contiguous_engine():
    """layout='paged' vs the contiguous layouts: same trace, identical
    completions — the block-table indirection is numerically invisible."""
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _trace(cfg, 4, seed=5)
    outs = {}
    for layout in ("paged", "seq", "head"):
        eng = ContinuousEngine(params, cfg, num_slots=2, max_len=16,
                               layout=layout, page_size=8)
        outs[layout] = {i: c.tokens for i, c in eng.run(reqs).items()}
    assert outs["paged"] == outs["seq"] == outs["head"]


def test_engine_validation():
    cfg = _cfg("qwen3-1.7b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="multiple of"):
        ContinuousEngine(params, cfg, num_slots=2, max_len=20,
                         layout="paged", page_size=8)
    with pytest.raises(ValueError, match="cannot hold"):
        ContinuousEngine(params, cfg, num_slots=2, max_len=16,
                         layout="paged", page_size=8, total_pages=2)
    eng = ContinuousEngine(params, cfg, num_slots=2, max_len=16,
                           layout="paged", page_size=8)
    long = np.zeros((14,), np.int32)
    with pytest.raises(ValueError, match="does not fit"):
        eng.run([Request(id=0, prompt=long, max_new_tokens=8)])
    with pytest.raises(ValueError, match="does not fit"):
        eng.run([Request(id=0, prompt=long[:4], max_new_tokens=0)])


# ---------------------------------------------------------------------------
# model-sharded serving (subprocess: 4 devices as a (2 data, 2 model) mesh)
# ---------------------------------------------------------------------------


SHARDED_ENGINE_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, numpy as np
    assert jax.device_count() == 4, jax.device_count()
    from repro.configs.registry import get_config
    from repro.launch.mesh import MODEL_AXIS, make_2d_mesh
    from repro.models import transformer as T
    from repro.serving import ContinuousEngine, Request

    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    r = np.random.RandomState(0)
    reqs = []
    for i in range(5):
        L = int(r.choice([4, 8]))
        prompt = r.randint(0, cfg.vocab_size, size=(L,)).astype("int32")
        reqs.append(Request(id=i, prompt=prompt, max_new_tokens=6,
                            arrival=0.9 * i))

    kw = dict(num_slots=2, max_len=16, layout="paged", page_size=8)
    solo = ContinuousEngine(params, cfg, **kw).run(reqs)

    mesh = make_2d_mesh()
    eng = ContinuousEngine(params, cfg, mesh=mesh, **kw)
    # the page pool really is sharded over kv heads per rules.cache_specs
    kp = eng.cache["body"][0]["attn"]["kp"]
    spec = tuple(kp.sharding.spec)
    assert MODEL_AXIS in spec, spec
    sharded = eng.run(reqs)
    assert sorted(sharded) == sorted(solo)
    for i in solo:
        assert sharded[i].tokens == solo[i].tokens, (
            i, sharded[i].tokens, solo[i].tokens)
    print("SERVING_SHARDED_OK")
""")


@pytest.mark.tier1
def test_sharded_engine_matches_unsharded_subprocess():
    """ContinuousEngine on the (2 data, 2 model) serving mesh — params per
    rules.param_specs, paged KV pool sharded over kv heads per
    rules.cache_specs — emits greedy tokens bit-exact vs the unsharded
    engine on the same trace."""
    import os
    import subprocess
    import sys
    from pathlib import Path
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(repo / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-c", SHARDED_ENGINE_SCRIPT],
                          capture_output=True, text=True, env=env,
                          cwd=str(repo), timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "SERVING_SHARDED_OK" in proc.stdout
