"""Sharding rules + hints: spec shapes are consistent, divisibility fallback
works, a full train step runs under a host mesh (1x1) with the same code
path the production mesh uses."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.core import LargeBatchConfig, Regime
from repro.launch.mesh import dp_axes, fsdp_axes, make_host_mesh
from repro.models import transformer as T
from repro.optim import sgd
from repro.sharding import rules
from repro.sharding.hints import current_mesh, hint
from repro.train.trainer import make_lm_train_step


def test_param_specs_cover_tree():
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    params = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    mesh = make_host_mesh()
    specs = rules.param_specs(params, mesh, cfg)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= len(p.shape)


def test_divisibility_fallback():
    """Dims not divisible by the mesh axis size are replicated."""
    class FakeLeaf:
        def __init__(self, shape):
            self.shape = shape

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 60 experts % 16 != 0 on a real 16-way mesh would fall back; on the 1x1
    # host mesh everything divides — check the rule helper directly instead.
    from repro.sharding.rules import _fits
    class M:
        shape = {"data": 16, "model": 16}
    assert _fits(64, M, "model")
    assert not _fits(60, M, "model")
    assert _fits(60, M, None)
    assert not _fits(60, M, ("data", "model"))


def test_hint_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = hint(x, "dp", "model")
    np.testing.assert_array_equal(x, y)
    assert current_mesh() is None


def test_hint_rank_mismatch_raises():
    with make_host_mesh():
        with pytest.raises(ValueError):
            hint(jnp.ones((2, 2)), "dp")


def test_train_step_under_host_mesh():
    """The exact production code path (hints + EP + remat + SP) on a 1x1
    mesh: one jitted train step with sharded params."""
    cfg = dataclasses.replace(get_config("jamba-v0.1-52b").reduced(),
                              dtype="float32")
    mesh = make_host_mesh()
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    opt = sgd.init(params)
    pshard = rules.param_shardings(params, mesh, cfg)
    params = jax.device_put(params, pshard)
    lb = LargeBatchConfig(batch_size=2, base_batch_size=2, grad_clip=1.0)
    regime = Regime(base_lr=0.01, total_steps=5, drop_every=5)
    step = make_lm_train_step(cfg, lb, regime, remat=True, seq_parallel=True)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    with mesh:
        p2, o2, m = jax.jit(step)(params, opt, batch, jnp.int32(0),
                                  jax.random.PRNGKey(2))
    assert not jnp.isnan(m["loss"])


def test_cache_specs_structure():
    cfg = dataclasses.replace(get_config("gemma3-27b").reduced(),
                              dtype="float32")
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 4, 64))
    mesh = make_host_mesh()
    specs = rules.cache_specs(cache, mesh, 4)
    ncache = len(jax.tree.leaves(cache))
    nspecs = len(jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)))
    assert ncache == nspecs


def test_mesh_axis_helpers():
    single = make_host_mesh()
    assert dp_axes(single) == ("data",)
    assert fsdp_axes(single) == ("data",)
