"""End-to-end behaviour tests for the paper's system.

Fast versions of the paper's qualitative claims:
1. log-diffusion: ||w_t - w_0|| grows ~ log t during the high-LR phase
   (paper Fig. 2 / §3.1).
2. regime adaptation gives the large batch the same *step* budget and the
   weight distance catches up to the small-batch run (paper §5).
3. the LM driver trains end-to-end with the full large-batch recipe.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import F1_MNIST
from repro.configs.registry import get_config
from repro.core import LargeBatchConfig, Regime, presets
from repro.data.synthetic import (lm_sequences, teacher_classification,
                                  token_lm)
from repro.models.cnn import model_fns
from repro.models import transformer as T
from repro.optim import sgd
from repro.train.trainer import make_lm_train_step, train_vision


@pytest.fixture(scope="module")
def data():
    return teacher_classification(1, n_train=1024, n_test=256,
                                  input_shape=(8, 8, 1), n_classes=10)


@pytest.fixture(scope="module")
def vis_cfg():
    return dataclasses.replace(F1_MNIST, input_shape=(8, 8, 1),
                               hidden_sizes=(64, 64), ghost_batch_size=16)


def test_log_diffusion_in_training(data, vis_cfg):
    """During the constant-high-LR phase the distance fits log t well."""
    lb = LargeBatchConfig(batch_size=64, base_batch_size=64, grad_clip=0.0)
    regime = Regime(base_lr=0.1, total_steps=120, drop_every=10_000)  # no drop
    out = train_vision(model_fns(vis_cfg), vis_cfg, data, lb, regime)
    log_fit = out["log_fit"]
    assert log_fit["slope"] > 0
    assert log_fit["r2"] > 0.85, log_fit


def test_regime_adaptation_restores_step_count(data, vis_cfg):
    """LB+RA trains for the same number of steps as SB, and reaches a
    comparable weight distance (the mechanism behind closing the gap)."""
    steps_sb = 96
    small = Regime(base_lr=0.1, total_steps=steps_sb, drop_every=64)
    p = presets(large_batch=256, small_batch=64, ghost=16)

    run = {}
    for name in ("SB", "LB", "LB+LR+GBN+RA"):
        lb = p[name]
        regime = lb.build_regime(small)
        out = train_vision(model_fns(vis_cfg), vis_cfg, data, lb, regime,
                           seed=3)
        run[name] = out
    assert run["LB"]["steps"] == steps_sb // 4          # epoch budget
    assert run["LB+LR+GBN+RA"]["steps"] == steps_sb     # step budget (RA)
    d_sb = run["SB"]["history"]["distance"][-1]
    d_lb = run["LB"]["history"]["distance"][-1]
    d_ra = run["LB+LR+GBN+RA"]["history"]["distance"][-1]
    # RA ends much closer to the SB distance than the naive LB run
    assert abs(d_ra - d_sb) < abs(d_lb - d_sb), (d_sb, d_lb, d_ra)


def test_lm_driver_end_to_end():
    """Large-batch recipe on a reduced LM: loss decreases over 12 steps of
    real (Markov) synthetic data."""
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32", body_repeats=2)
    stream = token_lm(0, vocab_size=cfg.vocab_size, n_tokens=64 * 64 * 4)
    seqs = lm_sequences(stream, 64)
    lb = LargeBatchConfig(batch_size=16, base_batch_size=4, lr_rule="sqrt",
                          grad_clip=1.0, ghost_noise=0.1)
    regime = lb.build_regime(Regime(base_lr=0.02, total_steps=12,
                                    drop_every=12))
    step = jax.jit(make_lm_train_step(cfg, lb, regime))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = sgd.init(params)
    rng = np.random.RandomState(0)
    losses = []
    for i in range(12):
        idx = rng.randint(0, seqs.shape[0], 16)
        batch = {"tokens": jnp.asarray(seqs[idx])}
        params, opt, m = step(params, opt, batch, jnp.int32(i),
                              jax.random.PRNGKey(i))
        losses.append(float(m["ce"]))
    assert losses[-1] < losses[0], losses
    assert not any(np.isnan(losses))
