"""Paper-model training (F1 MLP / convnet / resnet) with GBN: learning works,
GBN state threads, the diffusion tracker sees log-like growth."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_models import (C1_CIFAR10, F1_MNIST,
                                        RESNET44_CIFAR10)
from repro.core import LargeBatchConfig, Regime
from repro.data.synthetic import teacher_classification
from repro.models.cnn import model_fns
from repro.train.trainer import train_vision

pytestmark = pytest.mark.tier1


def _small(cfg, **kw):
    return dataclasses.replace(cfg, input_shape=(8, 8, 1), **kw)


@pytest.fixture(scope="module")
def data():
    return teacher_classification(0, n_train=768, n_test=256,
                                  input_shape=(8, 8, 1), n_classes=10)


def test_mlp_gbn_learns(data):
    cfg = _small(F1_MNIST, hidden_sizes=(64, 64), ghost_batch_size=32)
    lb = LargeBatchConfig(batch_size=128, base_batch_size=64,
                          ghost_batch_size=32)
    regime = Regime(base_lr=0.1, total_steps=60, drop_every=40)
    out = train_vision(model_fns(cfg), cfg, data, lb, regime, eval_every=30)
    assert out["final_acc"] > 0.35     # well above 10% chance


def test_convnet_gbn_one_epoch(data):
    cfg = _small(C1_CIFAR10, channels=(8, 16), ghost_batch_size=32)
    lb = LargeBatchConfig(batch_size=128, base_batch_size=128,
                          ghost_batch_size=32)
    regime = Regime(base_lr=0.05, total_steps=12, drop_every=12)
    out = train_vision(model_fns(cfg), cfg, data, lb, regime)
    assert out["final_acc"] > 0.12


def test_resnet_builds_and_steps(data):
    cfg = _small(RESNET44_CIFAR10, channels=(8, 16), blocks_per_stage=1,
                 ghost_batch_size=32)
    lb = LargeBatchConfig(batch_size=64, base_batch_size=64,
                          ghost_batch_size=32)
    regime = Regime(base_lr=0.05, total_steps=6, drop_every=6)
    out = train_vision(model_fns(cfg), cfg, data, lb, regime)
    assert out["steps"] == 6
    assert not jnp.isnan(out["history"]["distance"][-1])


def test_gbn_vs_fullbatch_bn_paths_differ(data):
    """use_gbn toggles a real behavioural difference at large batch."""
    cfg = _small(F1_MNIST, hidden_sizes=(32,), ghost_batch_size=16)
    init_fn, apply_fn = model_fns(cfg)
    params, state = init_fn(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(data.x_train[:128])
    y_g, _ = apply_fn(params, state, cfg, x, training=True, use_gbn=True,
                      ghost_batch_size=16)
    y_b, _ = apply_fn(params, state, cfg, x, training=True, use_gbn=False)
    assert float(jnp.abs(y_g - y_b).max()) > 1e-6


def test_diffusion_logged(data):
    cfg = _small(F1_MNIST, hidden_sizes=(32,), ghost_batch_size=32)
    lb = LargeBatchConfig(batch_size=128, base_batch_size=128)
    regime = Regime(base_lr=0.1, total_steps=40, drop_every=40)
    out = train_vision(model_fns(cfg), cfg, data, lb, regime)
    assert len(out["history"]["distance"]) > 10
    # distances increase overall
    d = out["history"]["distance"]
    assert d[-1] > d[0]
    assert "slope" in out["log_fit"]
